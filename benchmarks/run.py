"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  Table 2  -> crossover            (N0/N1 transition points)
  Fig. 2   -> attention_scaling    (attn speed/memory vs N)
  Fig. 3   -> transformer_efficiency (full-model speed vs N)
  Table 3  -> accuracy_parity      (taylor vs softmax accuracy)
  Table 4  -> norm_ablation        (normalization => stability)
  Table 5  -> heads_sweep          (more heads => faster efficient)
  §Roofline-> roofline             (dry-run derived terms)
  serving  -> serving_throughput   (decode-heavy speculative decoding
                                    + shared-prefix cache TTFT)

docs/benchmarks.md is the book: what each module measures, how to run
it alone, and the current measured baselines (BENCH_serving.json).
"""

import sys
import time


def main() -> None:
    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    t0 = time.time()

    from benchmarks import (accuracy_parity, attention_scaling, crossover,
                            heads_sweep, norm_ablation, roofline,
                            serving_throughput, transformer_efficiency)

    crossover.run()
    norm_ablation.run()
    heads_sweep.run()
    attention_scaling.run(d_values=(16,) if fast else (16, 32),
                          n_values=(256, 512, 1024) if fast
                          else (256, 512, 1024, 2048, 4096))
    transformer_efficiency.run(seq_lens=(256, 512) if fast
                               else (256, 512, 1024, 2048))
    accuracy_parity.run(steps=40 if fast else 800)
    roofline.run()
    serving_throughput.run_decode_heavy(batches=(1,) if fast else (1, 2),
                                        gen=48 if fast else 256,
                                        ks=(4,) if fast else (4, 8))
    serving_throughput.run_shared_prefix(
        overlaps=(0.75,) if fast else (0.5, 0.75, 1.0),
        plen=256 if fast else 512,
        prefill_chunk=64 if fast else 128)
    print(f"benchmarks_total,{(time.time() - t0) * 1e6:.0f},", flush=True)


if __name__ == "__main__":
    main()
