"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

  Table 2  -> crossover            (N0/N1 transition points)
  Fig. 2   -> attention_scaling    (attn speed/memory vs N)
  Fig. 3   -> transformer_efficiency (full-model speed vs N)
  Table 3  -> accuracy_parity      (taylor vs softmax accuracy)
  Table 4  -> norm_ablation        (normalization => stability)
  Table 5  -> heads_sweep          (more heads => faster efficient)
  §Roofline-> roofline             (dry-run derived terms)
  serving  -> serving_throughput   (decode-heavy speculative decoding
                                    + shared-prefix cache TTFT)

docs/benchmarks.md is the book: what each module measures, how to run
it alone, and the current measured baselines (BENCH_serving.json).

``validate_serving_doc`` schema-checks a serving benchmark document
(required keys per cell, every number finite — no NaN/Inf) so the perf
trajectory in BENCH_serving.json stays machine-readable for the
ROADMAP's autotuning pass; ``serving_throughput --json`` runs it before
writing, and ``python -m benchmarks.run --validate PATH`` re-checks an
existing file (the CI ``obs`` job does).

``validate_training_doc`` is the training-side twin for
BENCH_training.json (train_step_memory --composed --json): beyond the
structural checks it enforces the paper's memory claim as a regression
gate — the composed path's activation-bytes log-log slope must stay
sub-linear (≤ 0.6 measured; gate at < 1.2) while the direct baseline is
quadratic (> 1.7). ``--validate`` dispatches on the document's name.

``compare_docs`` / ``--compare OLD NEW`` is the perf-regression
sentinel: cells are matched by their identity keys, each curated
metric (direction-aware — tok/s up is good, tail latency up is bad) is
compared with a relative tolerance band, and every regressed cell is
listed; the CLI exits nonzero when any regressed. CI runs it with a
fresh benchmark doc against the committed BENCH_* baselines.
"""

import json
import math
import sys
import time

# required keys per cell, by document name. Latency percentiles are part
# of the schema: the autotuning pass consumes tail latency, not means.
SERVING_CELL_KEYS = {
    "serving_throughput": (
        "batch", "prompt_len", "gen_len", "naive_tok_s", "engine_tok_s",
        "engine_kv_tok_s", "speedup_vs_naive", "ttft_mean_s", "ttft_p50_s",
        "ttft_p95_s", "ttft_p99_s", "itl_p50_s", "itl_p95_s", "itl_p99_s"),
    "serving_decode_heavy": ("batch", "drafter", "speculate_k", "tok_s",
                             "speedup"),
    "serving_shared_prefix": (
        "overlap", "shared_len", "ttft_cold_s", "ttft_cached_s",
        "ttft_speedup", "prefill_tokens_cold", "prefill_tokens_cached",
        "cached_prefix_tokens"),
    "serving_router": (
        "requests", "shared_len", "ttft_blind_s", "ttft_affine_s",
        "ttft_speedup", "cached_tokens_blind", "cached_tokens_affine",
        "prefix_routed", "bit_identical"),
}


def _finite(value, path, problems):
    if isinstance(value, float) and not math.isfinite(value):
        problems.append(f"{path}: non-finite value {value!r}")
    elif isinstance(value, dict):
        for k, v in value.items():
            _finite(v, f"{path}.{k}", problems)
    elif isinstance(value, (list, tuple)):
        for i, v in enumerate(value):
            _finite(v, f"{path}[{i}]", problems)


def validate_serving_doc(doc: dict) -> list[str]:
    """Problems in a serving benchmark document ([] = valid)."""
    problems: list[str] = []
    name = doc.get("name")
    if name not in SERVING_CELL_KEYS:
        return [f"unknown doc name {name!r}"]
    if not isinstance(doc.get("config"), dict):
        problems.append(f"{name}: missing config")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        problems.append(f"{name}: cells missing or empty")
        cells = []
    for i, cell in enumerate(cells):
        missing = [k for k in SERVING_CELL_KEYS[name] if k not in cell]
        # decode-heavy baseline rows (speculate_k=0) carry no
        # acceptance ledger; percentile keys only exist on cells whose
        # engine emitted >1 token per stream — the schema requires the
        # keys the cell's own mode produces
        if name == "serving_decode_heavy" and cell.get("speculate_k"):
            missing += [k for k in ("acceptance_rate", "rollbacks",
                                    "mean_speculate_k") if k not in cell]
        if missing:
            problems.append(f"{name}.cells[{i}]: missing keys {missing}")
    if name == "serving_router":
        mig = doc.get("migration")
        if not isinstance(mig, dict):
            problems.append(f"{name}: missing migration sub-record")
        else:
            for k in ("wire_bytes", "roundtrip_s", "bit_identical"):
                if k not in mig:
                    problems.append(f"{name}.migration: missing key {k!r}")
            if mig.get("bit_identical") is not True:
                problems.append(f"{name}.migration: stream not bit-identical"
                                " after migration")
        for i, cell in enumerate(doc.get("cells") or []):
            if cell.get("bit_identical") is not True:
                problems.append(f"{name}.cells[{i}]: routed streams not "
                                "bit-identical to the solo reference")
    _finite(doc, name or "doc", problems)
    # nested sub-documents (full serving_throughput runs embed them)
    for sub in ("decode_heavy", "shared_prefix", "router"):
        if sub in doc:
            problems += validate_serving_doc(doc[sub])
    return problems


def check_serving_doc(doc: dict) -> None:
    problems = validate_serving_doc(doc)
    if problems:
        raise ValueError("BENCH_serving schema violation:\n  "
                         + "\n  ".join(problems))


TRAINING_CELL_KEYS = {
    "training_composed": (
        "seq_len", "mesh_data", "mesh_pipe", "mesh_seq", "microbatches",
        "composed_temp_bytes", "step_time_s", "tokens_per_s"),
}

# the memory claim as numbers: composed per-device activation bytes must
# grow sub-linearly in N (weak scaling shards the sequence as it grows),
# the direct-attention baseline quadratically
TRAINING_SLOPE_GATES = {"composed_activation": (None, 0.8),
                        "direct_activation": (1.7, None)}


def validate_training_doc(doc: dict) -> list[str]:
    """Problems in a training benchmark document ([] = valid)."""
    problems: list[str] = []
    name = doc.get("name")
    if name not in TRAINING_CELL_KEYS:
        return [f"unknown doc name {name!r}"]
    if not isinstance(doc.get("config"), dict):
        problems.append(f"{name}: missing config")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        problems.append(f"{name}: cells missing or empty")
        cells = []
    for i, cell in enumerate(cells):
        missing = [k for k in TRAINING_CELL_KEYS[name] if k not in cell]
        if missing:
            problems.append(f"{name}.cells[{i}]: missing keys {missing}")
    slopes = doc.get("slopes")
    if not isinstance(slopes, dict):
        problems.append(f"{name}: missing slopes")
        slopes = {}
    for key, (lo, hi) in TRAINING_SLOPE_GATES.items():
        s = slopes.get(key)
        if not isinstance(s, (int, float)) or not math.isfinite(s):
            problems.append(f"{name}.slopes.{key}: missing or non-finite")
        elif lo is not None and s < lo:
            problems.append(f"{name}.slopes.{key}={s:.2f} below gate {lo}")
        elif hi is not None and s > hi:
            problems.append(f"{name}.slopes.{key}={s:.2f} above gate {hi}"
                            " — composed activation memory regressed")
    _finite(doc, name or "doc", problems)
    return problems


def check_training_doc(doc: dict) -> None:
    problems = validate_training_doc(doc)
    if problems:
        raise ValueError("BENCH_training schema violation:\n  "
                         + "\n  ".join(problems))


# ---------------------------------------------------------------------------
# Perf-regression sentinel (--compare)
# ---------------------------------------------------------------------------

# per document: how cells are identified, and which metrics regress in
# which direction. Curated rather than exhaustive — keys like
# prompt_len are identity, means duplicate the percentiles, and
# "naive_tok_s" regressing is not *our* regression.
COMPARE_SPEC = {
    "serving_throughput": {
        "key": ("batch", "prompt_len", "gen_len"),
        "higher": ("engine_tok_s", "speedup_vs_naive"),
        "lower": ("ttft_p95_s", "itl_p95_s"),
    },
    "serving_decode_heavy": {
        "key": ("batch", "drafter", "speculate_k"),
        "higher": ("tok_s", "speedup"),
        "lower": (),
    },
    "serving_shared_prefix": {
        "key": ("overlap", "shared_len"),
        "higher": ("ttft_speedup",),
        "lower": ("ttft_cached_s",),
    },
    "serving_router": {
        "key": ("requests", "shared_len"),
        "higher": ("ttft_speedup", "cached_tokens_affine"),
        "lower": (),
    },
    "training_composed": {
        "key": ("seq_len", "mesh_data", "mesh_pipe", "mesh_seq",
                "microbatches"),
        "higher": ("tokens_per_s",),
        "lower": ("step_time_s", "composed_temp_bytes"),
    },
}


def compare_docs(old: dict, new: dict, *, tolerance: float = 0.25
                 ) -> list[str]:
    """Regressed cells of ``new`` vs baseline ``old`` ([] = clean).

    A higher-is-better metric regresses when ``new < old*(1-tol)``; a
    lower-is-better one when ``new > old*(1+tol)``. Cells present only
    on one side are reported (coverage loss is a regression too — a
    silently dropped cell would otherwise read as "no regression").
    Nested sub-documents (``decode_heavy``/``shared_prefix``/``router``)
    recurse.
    """
    name = old.get("name")
    if name != new.get("name"):
        return [f"document name changed: {name!r} -> {new.get('name')!r}"]
    spec = COMPARE_SPEC.get(name)
    problems: list[str] = []
    if spec is not None:
        def cell_key(cell):
            return tuple(cell.get(k) for k in spec["key"])

        def key_str(key):
            return ",".join(f"{k}={v}" for k, v in zip(spec["key"], key))

        old_cells = {cell_key(c): c for c in old.get("cells", [])}
        new_cells = {cell_key(c): c for c in new.get("cells", [])}
        for key in old_cells.keys() - new_cells.keys():
            problems.append(f"{name}[{key_str(key)}]: cell missing from "
                            "the new document")
        for key, nc in new_cells.items():
            oc = old_cells.get(key)
            if oc is None:
                continue    # new coverage is never a regression
            for metric, better in [(m, "higher") for m in spec["higher"]] \
                    + [(m, "lower") for m in spec["lower"]]:
                ov, nv = oc.get(metric), nc.get(metric)
                if not isinstance(ov, (int, float)) \
                        or not isinstance(nv, (int, float)):
                    continue
                if better == "higher" and nv < ov * (1 - tolerance):
                    problems.append(
                        f"{name}[{key_str(key)}].{metric}: "
                        f"{ov:.4g} -> {nv:.4g} "
                        f"({(nv / ov - 1) * 100:+.1f}% < -{tolerance:.0%})")
                elif better == "lower" and nv > ov * (1 + tolerance):
                    problems.append(
                        f"{name}[{key_str(key)}].{metric}: "
                        f"{ov:.4g} -> {nv:.4g} "
                        f"({(nv / ov - 1) * 100:+.1f}% > +{tolerance:.0%})")
    for sub in ("decode_heavy", "shared_prefix", "router"):
        if sub in old:
            if sub not in new:
                problems.append(f"{name}: sub-document {sub!r} missing "
                                "from the new document")
            else:
                problems += compare_docs(old[sub], new[sub],
                                         tolerance=tolerance)
    return problems


def main() -> None:
    if "--validate" in sys.argv:
        path = sys.argv[sys.argv.index("--validate") + 1]
        with open(path) as f:
            doc = json.load(f)
        if doc.get("name") in TRAINING_CELL_KEYS:
            check_training_doc(doc)
            print(f"{path}: training benchmark schema OK")
        else:
            check_serving_doc(doc)
            print(f"{path}: serving benchmark schema OK")
        return
    if "--compare" in sys.argv:
        i = sys.argv.index("--compare")
        old_path, new_path = sys.argv[i + 1], sys.argv[i + 2]
        tolerance = (float(sys.argv[sys.argv.index("--tolerance") + 1])
                     if "--tolerance" in sys.argv else 0.25)
        with open(old_path) as f:
            old = json.load(f)
        with open(new_path) as f:
            new = json.load(f)
        problems = compare_docs(old, new, tolerance=tolerance)
        if problems:
            print(f"{new_path} regressed vs {old_path} "
                  f"(tolerance {tolerance:.0%}):")
            for p in problems:
                print(f"  {p}")
            raise SystemExit(1)
        print(f"{new_path}: no regressions vs {old_path} "
              f"(tolerance {tolerance:.0%})")
        return
    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    t0 = time.time()

    from benchmarks import (accuracy_parity, attention_scaling, crossover,
                            heads_sweep, norm_ablation, roofline,
                            serving_throughput, transformer_efficiency)

    crossover.run()
    norm_ablation.run()
    heads_sweep.run()
    attention_scaling.run(d_values=(16,) if fast else (16, 32),
                          n_values=(256, 512, 1024) if fast
                          else (256, 512, 1024, 2048, 4096))
    transformer_efficiency.run(seq_lens=(256, 512) if fast
                               else (256, 512, 1024, 2048))
    accuracy_parity.run(steps=40 if fast else 800)
    roofline.run()
    serving_throughput.run_decode_heavy(batches=(1,) if fast else (1, 2),
                                        gen=48 if fast else 256,
                                        ks=(4,) if fast else (4, 8))
    serving_throughput.run_shared_prefix(
        overlaps=(0.75,) if fast else (0.5, 0.75, 1.0),
        plen=256 if fast else 512,
        prefill_chunk=64 if fast else 128)
    serving_throughput.run_router(n_requests=4 if fast else 8,
                                  plen=128 if fast else 256,
                                  chunk=32 if fast else 64)
    print(f"benchmarks_total,{(time.time() - t0) * 1e6:.0f},", flush=True)


if __name__ == "__main__":
    main()
