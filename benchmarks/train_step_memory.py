"""Backward peak memory vs sequence length: the training-side version of
the paper's §4.2 memory claim.

Measures XLA's compiled temp-buffer allocation (``memory_analysis()`` of
the lowered grad function — buffer-assignment bytes, not a simulator)
for one attention-backward at growing N:

  * ``reference direct``  — jax.grad through the O(N²) jnp oracle: the
    backward keeps N×N score/cotangent buffers alive, so temp bytes grow
    quadratically;
  * ``efficient custom-VJP`` — the fused kernel path
    (kernels/taylor_grad.py): residuals are O(N·d), A_mod/KV̂ are
    recomputed, gradients stream through (cf·d, d+1) chunks — temp bytes
    grow linearly;
  * ``causal chunked custom-VJP`` — the two-scan recompute backward in
    core/taylor.py: O(N·d + d³).

Prints per-N temp bytes and the fitted log-log slope over the top half
of the sweep. The efficient/causal slopes must be sub-quadratic (~1);
the reference slope ~2 beyond the crossover.

Run:  PYTHONPATH=src python -m benchmarks.train_step_memory [--fast]
"""

from __future__ import annotations

import math
import sys

import jax
import jax.numpy as jnp

from repro.core import taylor as T
from repro.kernels import ops

from benchmarks.common import emit


def _bwd_temp_bytes(loss_fn, *shapes) -> int:
    """Temp-buffer bytes of the compiled gradient of ``loss_fn``."""
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    compiled = jax.jit(jax.grad(loss_fn, argnums=(0, 1, 2))) \
        .lower(*args).compile()
    return int(compiled.memory_analysis().temp_size_in_bytes)


def _slope(ns, bys) -> float:
    """log-log slope over the top half of the sweep (asymptotic regime)."""
    pts = [(math.log(n), math.log(max(b, 1))) for n, b in zip(ns, bys)]
    pts = pts[len(pts) // 2 - 1:]
    n = len(pts)
    mx = sum(p[0] for p in pts) / n
    my = sum(p[1] for p in pts) / n
    num = sum((p[0] - mx) * (p[1] - my) for p in pts)
    den = sum((p[0] - mx) ** 2 for p in pts)
    return num / den


def run(d: int = 16, n_values=(128, 256, 512, 1024), heads: int = 2):
    interp = jax.default_backend() != "tpu"

    def loss_ref(q, k, v):
        return jnp.sum(T.direct_taylorshift(q, k, v) ** 2)

    def loss_eff(q, k, v):
        return jnp.sum(ops.taylor_attention_kernel(
            q, k, v, mode="efficient", interpret=interp) ** 2)

    def loss_causal(q, k, v):
        return jnp.sum(T.causal_taylorshift(q, k, v, chunk=64) ** 2)

    rows = {"ref_direct": [], "eff_vjp": [], "causal_vjp": []}
    for n in n_values:
        shape = (1, heads, n, d)
        b_ref = _bwd_temp_bytes(loss_ref, shape, shape, shape)
        b_eff = _bwd_temp_bytes(loss_eff, shape, shape, shape)
        b_cau = _bwd_temp_bytes(loss_causal, shape, shape, shape)
        rows["ref_direct"].append(b_ref)
        rows["eff_vjp"].append(b_eff)
        rows["causal_vjp"].append(b_cau)
        emit(f"bwd_temp_d{d}_n{n}", 0.0,
             f"ref_direct_B={b_ref};efficient_vjp_B={b_eff};"
             f"causal_vjp_B={b_cau}")

    slopes = {name: _slope(n_values, bys) for name, bys in rows.items()}
    for name, s in slopes.items():
        growth = ("quadratic" if s > 1.7
                  else "sub-quadratic" if s > 1.2 else "~linear")
        emit(f"bwd_temp_slope_{name}", 0.0,
             f"loglog_slope={s:.2f};growth={growth}")
    print(f"# backward peak-memory growth (temp bytes, d={d}): "
          f"reference direct slope {slopes['ref_direct']:.2f} vs "
          f"efficient custom-VJP slope {slopes['eff_vjp']:.2f} "
          f"(sub-quadratic: {slopes['eff_vjp'] < 1.7}), "
          f"causal custom-VJP slope {slopes['causal_vjp']:.2f}",
          flush=True)
    return slopes


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    run(n_values=(128, 256, 512) if fast else (128, 256, 512, 1024))
