"""Backward peak memory vs sequence length: the training-side version of
the paper's §4.2 memory claim.

Measures XLA's compiled temp-buffer allocation (``memory_analysis()`` of
the lowered grad function — buffer-assignment bytes, not a simulator)
for one attention-backward at growing N:

  * ``reference direct``  — jax.grad through the O(N²) jnp oracle: the
    backward keeps N×N score/cotangent buffers alive, so temp bytes grow
    quadratically;
  * ``efficient custom-VJP`` — the fused kernel path
    (kernels/taylor_grad.py): residuals are O(N·d), A_mod/KV̂ are
    recomputed, gradients stream through (cf·d, d+1) chunks — temp bytes
    grow linearly;
  * ``causal chunked custom-VJP`` — the two-scan recompute backward in
    core/taylor.py: O(N·d + d³).

Prints per-N temp bytes and the fitted log-log slope over the top half
of the sweep. The efficient/causal slopes must be sub-quadratic (~1);
the reference slope ~2 beyond the crossover.

``--composed`` runs the full-model version: the composed 3D-parallel
train step (distributed/composed.py) swept over N ∈ {4k, 16k, 64k} with
weak-scaling mesh shapes on 8 host devices — the sequence axis absorbs
the growth (4k→(2,2,2), 16k→(1,2,4), 64k→(1,1,8)), so per-device
activation bytes grow sub-linearly (slope ≤ 0.6) while the
direct-attention single-device baseline grows quadratically (~2.2,
measured compile-only — the O(N²) step never has to run). Measured step
time + tokens/s at every runnable size. ``--json PATH`` writes the
schema-checked BENCH_training.json document
(benchmarks.run.validate_training_doc; the CI train-parallel job
re-validates the committed file).

Run:  PYTHONPATH=src python -m benchmarks.train_step_memory [--fast]
      PYTHONPATH=src python -m benchmarks.train_step_memory \
          --composed --json BENCH_training.json
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

if __name__ == "__main__":
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--fast", action="store_true")
    _ap.add_argument("--composed", action="store_true",
                     help="composed 3D train-step sweep (forces a "
                          "host-platform device mesh before jax loads)")
    _ap.add_argument("--devices", type=int, default=8)
    _ap.add_argument("--seq-lens", type=int, nargs="+",
                     default=[4096, 16384, 65536])
    _ap.add_argument("--global-batch", type=int, default=4)
    _ap.add_argument("--steps", type=int, default=2,
                     help="measured steps per composed cell")
    _ap.add_argument("--json", default="",
                     help="write the BENCH_training.json document here")
    ARGS = _ap.parse_args()
    if ARGS.composed:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={ARGS.devices} "
            + os.environ.get("XLA_FLAGS", ""))

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402

from repro.core import taylor as T                          # noqa: E402
from repro.kernels import ops                               # noqa: E402

from benchmarks.common import emit                          # noqa: E402


def _bwd_temp_bytes(loss_fn, *shapes) -> int:
    """Temp-buffer bytes of the compiled gradient of ``loss_fn``."""
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    compiled = jax.jit(jax.grad(loss_fn, argnums=(0, 1, 2))) \
        .lower(*args).compile()
    return int(compiled.memory_analysis().temp_size_in_bytes)


def _slope(ns, bys) -> float:
    """log-log slope over the top half of the sweep (asymptotic regime)."""
    pts = [(math.log(n), math.log(max(b, 1))) for n, b in zip(ns, bys)]
    pts = pts[len(pts) // 2 - 1:]
    n = len(pts)
    mx = sum(p[0] for p in pts) / n
    my = sum(p[1] for p in pts) / n
    num = sum((p[0] - mx) * (p[1] - my) for p in pts)
    den = sum((p[0] - mx) ** 2 for p in pts)
    return num / den


def run(d: int = 16, n_values=(128, 256, 512, 1024), heads: int = 2):
    interp = jax.default_backend() != "tpu"

    def loss_ref(q, k, v):
        return jnp.sum(T.direct_taylorshift(q, k, v) ** 2)

    def loss_eff(q, k, v):
        return jnp.sum(ops.taylor_attention_kernel(
            q, k, v, mode="efficient", interpret=interp) ** 2)

    def loss_causal(q, k, v):
        return jnp.sum(T.causal_taylorshift(q, k, v, chunk=64) ** 2)

    rows = {"ref_direct": [], "eff_vjp": [], "causal_vjp": []}
    for n in n_values:
        shape = (1, heads, n, d)
        b_ref = _bwd_temp_bytes(loss_ref, shape, shape, shape)
        b_eff = _bwd_temp_bytes(loss_eff, shape, shape, shape)
        b_cau = _bwd_temp_bytes(loss_causal, shape, shape, shape)
        rows["ref_direct"].append(b_ref)
        rows["eff_vjp"].append(b_eff)
        rows["causal_vjp"].append(b_cau)
        emit(f"bwd_temp_d{d}_n{n}", 0.0,
             f"ref_direct_B={b_ref};efficient_vjp_B={b_eff};"
             f"causal_vjp_B={b_cau}")

    slopes = {name: _slope(n_values, bys) for name, bys in rows.items()}
    for name, s in slopes.items():
        growth = ("quadratic" if s > 1.7
                  else "sub-quadratic" if s > 1.2 else "~linear")
        emit(f"bwd_temp_slope_{name}", 0.0,
             f"loglog_slope={s:.2f};growth={growth}")
    print(f"# backward peak-memory growth (temp bytes, d={d}): "
          f"reference direct slope {slopes['ref_direct']:.2f} vs "
          f"efficient custom-VJP slope {slopes['eff_vjp']:.2f} "
          f"(sub-quadratic: {slopes['eff_vjp'] < 1.7}), "
          f"causal custom-VJP slope {slopes['causal_vjp']:.2f}",
          flush=True)
    return slopes


# ---------------------------------------------------------------------------
# Composed 3D-parallel full-model sweep (BENCH_training.json)
# ---------------------------------------------------------------------------

# Weak scaling: the device pool grows with N (2 → 4 → 8) and each cell
# uses the measured-best layout for its device count — seq-dominant,
# because pipeline layouts cost 2.5–2.9× the temp bytes at equal device
# count (GPipe tick buffers; e.g. (1,2,4) at N=64k measured 1.66 GB vs
# 0.57 GB for (1,1,8)).  The full (data,pipe,seq) composition is proven
# by tests/test_composed_parallel.py and the CI train smoke at (2,2,2);
# this sweep isolates the activation-memory slope.
COMPOSED_MESHES = {4096: (1, 1, 2), 16384: (1, 1, 4), 65536: (1, 1, 8)}


def _composed_cfg(n: int, *, d_model: int, n_layers: int, mode: str):
    import dataclasses

    from repro.configs import get_config

    cfg = get_config("taylorshift-lra").reduced()
    cfg = cfg.with_(n_layers=n_layers, d_model=d_model, n_heads=2,
                    n_kv_heads=2, d_ff=2 * d_model, max_seq_len=n,
                    dtype="float32", causal=True, remat=True)
    return cfg.with_(taylor=dataclasses.replace(
        cfg.taylor, mode=mode, use_kernel=False))


def _direct_step_temp_bytes(n: int, global_batch: int, *, d_model: int,
                            n_layers: int) -> int:
    """Single-device direct-attention train step, compile-only: the
    O(N²) step never has to run to report its buffer assignment."""
    from repro.launch.steps import (build_train_step, default_opt_config,
                                    param_shapes)
    from repro.optim import make_optimizer

    cfg = _composed_cfg(n, d_model=d_model, n_layers=n_layers,
                        mode="direct")
    opt_cfg = default_opt_config(cfg)
    init_opt, _ = make_optimizer(opt_cfg)
    pshapes = param_shapes(cfg)
    oshapes = jax.eval_shape(init_opt, pshapes)
    batch = {k: jax.ShapeDtypeStruct((global_batch, n), jnp.int32)
             for k in ("tokens", "labels")}
    compiled = jax.jit(build_train_step(cfg, opt_cfg)) \
        .lower(pshapes, oshapes, batch).compile()
    return int(compiled.memory_analysis().temp_size_in_bytes)


def run_composed(seq_lens, *, global_batch: int = 4, d_model: int = 64,
                 n_layers: int = 2, steps: int = 2, json_path: str = ""):
    import time

    import numpy as np

    from repro.data.pipeline import device_put_batch
    from repro.distributed import composed as Cmp
    from repro.launch import mesh as MESH
    from repro.launch.steps import default_opt_config

    n_dev = len(jax.devices())
    cells = []
    comp_bytes, direct_bytes, ns = [], [], []
    for n in seq_lens:
        dd, pp, ss = COMPOSED_MESHES.get(n, (1, 1, n_dev))
        if dd * pp * ss > n_dev:
            print(f"# skip N={n}: mesh ({dd},{pp},{ss}) needs "
                  f"{dd * pp * ss} devices, have {n_dev}", file=sys.stderr)
            continue
        cfg = _composed_cfg(n, d_model=d_model, n_layers=n_layers,
                            mode="efficient")
        mesh = MESH.make_composed_mesh(data=dd, pipe=pp, seq=ss)
        # One sequence per microbatch: under remat the peak working set
        # scales with B/mb (measured: mb 1 → 4 cuts the N=64k cell 3×),
        # and with S=1 stages the pipeline bubble is zero regardless.
        mb = max(1, global_batch // dd)
        init_fn, step_fn, _ = Cmp.build_composed_train_step(
            cfg, default_opt_config(cfg), mesh, global_batch=global_batch,
            seq_len=n, n_microbatches=mb, fsdp=True)
        params, opt_state = init_fn(jax.random.PRNGKey(0))
        rng = np.random.default_rng(n)
        tok = rng.integers(0, cfg.vocab, (global_batch, n), dtype=np.int32)
        batch = device_put_batch(
            {"tokens": tok,
             "labels": np.roll(tok, -1, axis=1).astype(np.int32)}, mesh)

        compiled = step_fn.lower(params, opt_state, batch).compile()
        temp = int(compiled.memory_analysis().temp_size_in_bytes)
        times = []
        for _ in range(steps):
            t0 = time.perf_counter()
            params, opt_state, metrics = compiled(params, opt_state, batch)
            float(metrics["loss"])          # block
            times.append(time.perf_counter() - t0)
        dt = min(times)

        d_temp = _direct_step_temp_bytes(n, global_batch,
                                         d_model=d_model,
                                         n_layers=n_layers)
        cells.append({
            "seq_len": n, "mesh_data": dd, "mesh_pipe": pp, "mesh_seq": ss,
            "microbatches": mb, "composed_temp_bytes": temp,
            "direct_temp_bytes": d_temp, "step_time_s": dt,
            "tokens_per_s": global_batch * n / dt,
            "loss": float(metrics["loss"]),
        })
        ns.append(n)
        comp_bytes.append(temp)
        direct_bytes.append(d_temp)
        emit(f"composed_step_n{n}_mesh{dd}x{pp}x{ss}", dt * 1e6,
             f"composed_temp_B={temp};direct_temp_B={d_temp};"
             f"tok_s={global_batch * n / dt:.0f}")

    if len(ns) < 2:
        print("# need >= 2 sequence lengths for slopes; no document "
              "written", flush=True)
        return {"cells": cells}
    slopes = {"composed_activation": _slope(ns, comp_bytes),
              "direct_activation": _slope(ns, direct_bytes)}
    emit("composed_memory_slopes", 0.0,
         f"composed={slopes['composed_activation']:.2f};"
         f"direct={slopes['direct_activation']:.2f}")
    print(f"# composed activation-memory slope "
          f"{slopes['composed_activation']:.2f} (gate < 0.8) vs direct "
          f"{slopes['direct_activation']:.2f} (gate > 1.7)", flush=True)

    doc = {
        "name": "training_composed",
        "config": {"arch": "taylorshift-lra", "d_model": d_model,
                   "n_layers": n_layers, "heads": 2,
                   "global_batch": global_batch, "devices": n_dev,
                   "fsdp": True, "backend": jax.default_backend()},
        "cells": cells,
        "slopes": slopes,
    }
    from benchmarks.run import check_training_doc
    check_training_doc(doc)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return doc


if __name__ == "__main__":
    if ARGS.composed:
        run_composed(ARGS.seq_lens, global_batch=ARGS.global_batch,
                     steps=ARGS.steps, json_path=ARGS.json)
    else:
        run(n_values=(128, 256, 512) if ARGS.fast
            else (128, 256, 512, 1024))
