"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Emits one row per (arch × shape × mesh): the three roofline terms, the
dominant bottleneck, and MODEL_FLOPS/HLO_FLOPS. Also writes the markdown
table EXPERIMENTS.md §Roofline embeds."""

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def load_records(mesh="single"):
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("variant"):
            continue
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def run(mesh="single"):
    recs = load_records(mesh)
    n_ok = 0
    for r in recs:
        tag = f"{r['arch']}__{r['shape']}"
        if r["status"] != "ok":
            emit(f"roofline_{tag}", 0.0, "status=FAIL")
            continue
        n_ok += 1
        t = r["roofline"]
        emit(f"roofline_{tag}", t["roofline_bound_s"] * 1e6,
             f"dominant={t['dominant']};t_c={t['t_compute_s']:.3e};"
             f"t_m={t['t_memory_s']:.3e};t_x={t['t_collective_s']:.3e};"
             f"model/hlo={r['model_to_hlo_flops']:.3f}")
    emit("roofline_cells_ok", 0.0, f"{n_ok}/{len(recs)}")
    return recs


def markdown_table(mesh="single") -> str:
    rows = ["| arch | shape | t_compute | t_memory (lo–hi) | t_collective |"
            " dominant | model/HLO flops | HBM fit (args+temp GB) |",
            "|---|---|---|---|---|---|---|---|"]
    for r in load_records(mesh):
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | FAIL | — | — |")
            continue
        t = r["roofline"]
        mem = r["memory"]
        gb = (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9
        lo = t.get("t_memory_lower_s", t["t_memory_s"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.2e}s "
            f"| {lo:.2e}–{t['t_memory_s']:.2e}s "
            f"| {t['t_collective_s']:.2e}s | **{t['dominant']}** "
            f"| {r['model_to_hlo_flops']:.2f} | {gb:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    run()
