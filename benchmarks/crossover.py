"""Paper Table 2: transition points N0 (speed) and N1 (memory) vs d.

Validates Eq. (7)/(9) against the paper's printed values and against the
operation/entry counters (Eqs. 5, 6, 8).

``--decision-log PATH`` audits a recorded ``select_backend`` decision
log (src/repro/obs/decisions.py JSONL, written by ``launch/serve.py
--decision-log`` or embedded in dry-run records) against these analytic
crossovers: every record's stored N0/N1 must match Eq. (7)/(9)
recomputed from its d, and every efficient/direct mode choice is
checked against which side of N0 its N falls on — divergences must
carry an explaining ``reason`` (causal decode, memory cap, forced
backend). This is the calibration hook: when measured crossovers drift
from analytic ones, the diff shows exactly which serving sites moved.
"""

from repro.core import taylor as T

from benchmarks.common import emit

PAPER_TABLE2 = {128: (16513, 8446)}  # printed row; other columns cropped in
                                     # the source PDF — recomputed from Eq.7/9


def run():
    ok = True
    for d in (8, 16, 32, 64, 128):
        n0 = T.crossover_n0(d)
        n1 = T.crossover_n1(d)
        if d in PAPER_TABLE2:
            p0, p1 = PAPER_TABLE2[d]
            ok &= (round(n0) == p0 and round(n1) == p1)
        # FLOP/entry models must actually cross at N0/N1
        lo, hi = int(n0 * 0.9), int(n0 * 1.1) + 2
        ok &= T.ops_direct(lo, d) < T.ops_efficient(lo, d)
        ok &= T.ops_direct(hi, d) > T.ops_efficient(hi, d)
        emit(f"crossover_d{d}", 0.0,
             f"N0={n0:.0f};N1={n1:.0f};bound_ok={n1 < n0}")
    emit("crossover_table2_match", 0.0, f"paper_match={ok}")
    return ok


def audit_decision_log(records) -> dict:
    """Diff recorded ``select_backend`` decisions against Eq. (7)/(9).

    Returns ``{"records", "calibrated", "n0_n1_mismatches",
    "divergences", "sites"}``. ``n0_n1_mismatches`` (stored crossover
    != analytic recompute) are hard errors — the recorded log disagrees
    with the paper's model; records whose ``provenance`` is
    ``"calibrated"`` are exempt (their stored N0/N1 are *measured*
    overrides from a repro.tune table, and their choice is audited
    against the stored threshold instead) and counted separately.
    ``divergences`` are dispatch *cells* — deduped on (site, backend,
    mode, N, d), with a ``count`` of how many replayed records hit the
    cell — whose direct/efficient choice sits on the other side of N0
    than the governing threshold predicts; each carries its recorded
    ``reason`` (mode pinned by config, kv-cache readout, …) so a human
    can tell calibration drift from deliberate policy. Deduping
    matters: a serving run replays the same shapes thousands of times,
    and per-record reports drown the real signal the calibration pass
    feeds on.
    """
    mismatches, calibrated = [], 0
    divergences: dict[tuple, dict] = {}
    sites: dict[str, dict[str, int]] = {}
    for r in records:
        is_cal = r.get("provenance") == "calibrated"
        n0, n1 = T.crossover_n0(r["d"]), T.crossover_n1(r["d"])
        if is_cal:
            calibrated += 1
            n0, n1 = r["n0"], r["n1"]   # audit against the measured values
        elif abs(r["n0"] - n0) > 0.5 or abs(r["n1"] - n1) > 0.5:
            mismatches.append(
                {"seq": r["seq"], "site": r["site"], "d": r["d"],
                 "stored": (r["n0"], r["n1"]), "analytic": (n0, n1)})
        choice = f"{r['backend']}/{r['mode'] or '-'}"
        sites.setdefault(r["site"], {})
        sites[r["site"]][choice] = sites[r["site"]].get(choice, 0) + 1
        # Eq. (7) predicts direct iff N <= N0; only records that made an
        # explicit direct/efficient call are comparable (causal-scan
        # prefill/verify is the linear path by construction, and the
        # kv-cache 'and Back' readout is governed by N1, not N0)
        if r["mode"] in ("direct", "efficient") and r["cache_kind"] != "kv":
            predicted = "direct" if r["N"] <= n0 else "efficient"
            if r["mode"] != predicted:
                cell = (r["site"], r["backend"], r["mode"], r["N"], r["d"])
                dv = divergences.get(cell)
                if dv is None:
                    divergences[cell] = {
                        "seq": r["seq"], "site": r["site"], "N": r["N"],
                        "d": r["d"], "n0": n0, "chose": r["mode"],
                        "predicted": predicted, "reason": r["reason"],
                        "count": 1}
                else:
                    dv["count"] += 1
    return {"records": len(records), "calibrated": calibrated,
            "n0_n1_mismatches": mismatches,
            "divergences": list(divergences.values()), "sites": sites}


def main():
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--decision-log", default=None, metavar="PATH",
                    help="audit a select_backend decision log (JSONL) "
                         "against the analytic crossovers")
    args = ap.parse_args()
    if args.decision_log is None:
        raise SystemExit(0 if run() else 1)

    from repro.obs.decisions import read_jsonl
    from repro.obs.validate import check_decision_log

    records = read_jsonl(args.decision_log)
    check_decision_log(records)
    audit = audit_decision_log(records)
    print(json.dumps(audit, indent=2))
    for dv in audit["divergences"]:
        print(f"# diverges from Eq.(7) at {dv['site']} N={dv['N']} "
              f"(x{dv['count']}): chose {dv['chose']} "
              f"(predicted {dv['predicted']}): {dv['reason']}")
    if audit["n0_n1_mismatches"]:
        raise SystemExit(
            f"{len(audit['n0_n1_mismatches'])} records store N0/N1 that "
            "disagree with Eq. (7)/(9) — recorded log predates a "
            "crossover-model change; re-record it")
    print(f"# {audit['records']} decisions audited "
          f"({audit['calibrated']} on measured crossovers): analytic "
          f"records match Eq. (7)/(9); {len(audit['divergences'])} "
          "divergent dispatch cells (each explained by its recorded "
          "reason)")


if __name__ == "__main__":
    main()
