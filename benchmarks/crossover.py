"""Paper Table 2: transition points N0 (speed) and N1 (memory) vs d.

Validates Eq. (7)/(9) against the paper's printed values and against the
operation/entry counters (Eqs. 5, 6, 8)."""

from repro.core import taylor as T

from benchmarks.common import emit

PAPER_TABLE2 = {128: (16513, 8446)}  # printed row; other columns cropped in
                                     # the source PDF — recomputed from Eq.7/9


def run():
    ok = True
    for d in (8, 16, 32, 64, 128):
        n0 = T.crossover_n0(d)
        n1 = T.crossover_n1(d)
        if d in PAPER_TABLE2:
            p0, p1 = PAPER_TABLE2[d]
            ok &= (round(n0) == p0 and round(n1) == p1)
        # FLOP/entry models must actually cross at N0/N1
        lo, hi = int(n0 * 0.9), int(n0 * 1.1) + 2
        ok &= T.ops_direct(lo, d) < T.ops_efficient(lo, d)
        ok &= T.ops_direct(hi, d) > T.ops_efficient(hi, d)
        emit(f"crossover_d{d}", 0.0,
             f"N0={n0:.0f};N1={n1:.0f};bound_ok={n1 < n0}")
    emit("crossover_table2_match", 0.0, f"paper_match={ok}")
    return ok


if __name__ == "__main__":
    run()
