"""Paper Table 3 (scaled): accuracy parity of TaylorShift vs softmax.

Trains the paper's encoder (ListOps hyperparameters, reduced for this
host) on the ListOps-style synthetic task with both attention backends
and identical seeds/hyperparameters. The paper's claim: TaylorShift
matches or beats softmax accuracy; we assert parity within 5 points at
smoke scale."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, lm_synthetic, listops_like
from repro.models import classifier as C
from repro.optim import OptConfig, make_optimizer

from benchmarks.common import emit


def train_one(backend: str, *, steps=400, batch=32, seq=128, d_model=64,
              n_layers=2, mode="auto", normalize=True, seed=0):
    cfg = get_config("taylorshift-lra").with_(
        attn_backend=backend, d_model=d_model, n_layers=n_layers,
        n_heads=4, n_kv_heads=4, d_ff=2 * d_model, vocab=16,
        max_seq_len=seq + 1, remat=False, dtype="float32")
    # tau_init = sqrt(2): the Taylor numerator's max-selectivity point
    cfg = cfg.with_(taylor=dataclasses.replace(cfg.taylor, mode=mode,
                                               normalize_inputs=normalize,
                                               tau_init=1.414))
    data_cfg = DataConfig(vocab=16, global_batch=batch, seq_len=seq,
                          kind="listops", seed=seed)
    params = C.classifier_init(cfg, 10, jax.random.PRNGKey(seed))
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=steps,
                        weight_decay=1e-3)
    init_opt, update = make_optimizer(opt_cfg)
    opt_state = init_opt(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: C.classifier_loss(p, cfg, batch))(params)
        params, opt_state, _ = update(params, grads, opt_state)
        return params, opt_state, loss

    losses = []
    for s in range(steps):
        b = listops_like(data_cfg, s)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, loss = step_fn(params, opt_state, b)
        losses.append(float(loss))

    accs = []
    for s in range(steps, steps + 8):
        b = listops_like(data_cfg, s)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        accs.append(float(C.classifier_accuracy(params, cfg, b)))
    return float(np.mean(accs)), losses


def run(steps=800):
    acc_taylor, l_t = train_one("taylor", steps=steps)
    acc_softmax, l_s = train_one("softmax", steps=steps)
    emit("accuracy_taylor", 0.0, f"acc={acc_taylor:.3f};"
         f"loss0={l_t[0]:.3f};lossN={np.mean(l_t[-10:]):.3f}")
    emit("accuracy_softmax", 0.0, f"acc={acc_softmax:.3f};"
         f"loss0={l_s[0]:.3f};lossN={np.mean(l_s[-10:]):.3f}")
    emit("accuracy_parity", 0.0,
         f"delta={acc_taylor - acc_softmax:+.3f};"
         f"parity_ok={abs(acc_taylor - acc_softmax) < 0.05 or acc_taylor > acc_softmax}")
    return acc_taylor, acc_softmax


if __name__ == "__main__":
    run()
