"""Paper Figure 3: full-transformer speed/memory, direct vs efficient vs
softmax (ListOps hyperparameters, scaled to this host)."""

import jax

from repro.configs import get_config
from repro.models import model as M

from benchmarks.common import emit, timeit


def run(seq_lens=(256, 512, 1024, 2048), d_model=128, n_layers=2):
    base = get_config("taylorshift-lra").with_(
        d_model=d_model, n_layers=n_layers, n_heads=8, n_kv_heads=8,
        d_ff=2 * d_model, max_seq_len=max(seq_lens) + 1, remat=False,
        dtype="float32")
    out = {}
    for backend, mode in (("taylor", "direct"), ("taylor", "efficient"),
                          ("softmax", "")):
        cfg = base.with_(attn_backend=backend)
        if mode:
            import dataclasses
            cfg = cfg.with_(taylor=dataclasses.replace(cfg.taylor, mode=mode))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        for n in seq_lens:
            tokens = jax.random.randint(jax.random.PRNGKey(n), (4, n), 0,
                                        cfg.vocab)
            fwd = jax.jit(lambda p, t, c=cfg: M.forward(p, c, {"tokens": t})[0])
            t, _ = timeit(fwd, params, tokens, warmup=1, iters=3)
            name = backend + (f"_{mode}" if mode else "")
            emit(f"transformer_{name}_n{n}", t * 1e6, "")
            out[(name, n)] = t
    # derived: crossover sequence length where efficient beats softmax
    for n in seq_lens:
        if out.get(("taylor_efficient", n), 1e9) < out.get(("softmax", n), 0):
            emit("transformer_eff_beats_softmax_at", 0.0, f"n={n}")
            break
    return out


if __name__ == "__main__":
    run()
