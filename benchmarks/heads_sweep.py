"""Paper Table 5 / §4.3: more heads make efficient-TaylorShift FASTER.

With d_embed fixed, ops_eff[MHSA] strictly decreases in h while
ops_direct[MHSA] increases — the paper's counterintuitive headline. We
verify both the analytic counters and wall-clock on this host."""

import jax

from repro.core import taylor as T

from benchmarks.common import emit, timeit


def run(d_embed=256, n=1024, hs=(4, 8, 16, 32)):
    prev_eff = None
    analytic_monotone = True
    for h in hs:
        d = d_embed // h
        ops_dir = h * T.ops_direct(n, d)
        ops_eff = h * T.ops_efficient(n, d)
        ent_dir = h * T.entries_direct(n, d)
        ent_eff = h * T.entries_efficient(n, d)
        key = jax.random.PRNGKey(h)
        q, k, v = (jax.random.normal(kk, (1, h, n, d))
                   for kk in jax.random.split(key, 3))
        t_eff, _ = timeit(jax.jit(T.efficient_taylorshift), q, k, v,
                          warmup=1, iters=3)
        t_dir, _ = timeit(jax.jit(T.direct_taylorshift), q, k, v,
                          warmup=1, iters=3)
        emit(f"heads_h{h}_d{d}", t_eff * 1e6,
             f"dir_us={t_dir * 1e6:.1f};ops_eff={ops_eff:.3e};"
             f"ops_dir={ops_dir:.3e};entries_eff={ent_eff};entries_dir={ent_dir}")
        if prev_eff is not None and ops_eff >= prev_eff:
            analytic_monotone = False
        prev_eff = ops_eff
    emit("heads_eff_ops_decrease_with_h", 0.0,
         f"monotone={analytic_monotone}")  # paper §4.3 claim


if __name__ == "__main__":
    run()
