"""Paper Table 4 / Appendix B.1: the normalization ablation.

The paper's finding: WITHOUT the §3.3 normalization scheme the efficient
implementation numerically explodes (overflow → NaN) while direct stays
usable; WITH it both are stable and interchangeable. We reproduce the
mechanism directly: feed realistic-magnitude activations through both
implementations with normalization on/off and measure overflow rates in
float16 (the paper trains in mixed precision) plus intermediate norms."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import taylor as T

from benchmarks.common import emit


def overflow_rate(fn, q, k, v, dtype):
    y = fn(q.astype(dtype), k.astype(dtype), v.astype(dtype))
    y = np.asarray(y, np.float32)
    return float(np.mean(~np.isfinite(y)))


def naive_efficient(q, k, v, *, normalize: bool):
    """The paper's Alg. 1 *without* our fp32-internal policy: every
    intermediate stays in the input dtype, as in a plain mixed-precision
    port. This is the implementation App. B.1 shows failing."""
    d = q.shape[-1]
    alpha = jnp.asarray(d ** 0.25, q.dtype)
    if normalize:
        q = q / jnp.linalg.norm(q.astype(q.dtype), axis=-1, keepdims=True)
        k = k / jnp.linalg.norm(k.astype(k.dtype), axis=-1, keepdims=True)
        q, k = q * alpha, k * alpha
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    n = q.shape[-2]
    scale = (1.0 / n) if normalize else 1.0
    vh = jnp.concatenate([ones * jnp.asarray(jnp.sqrt(d / n), v.dtype), v],
                         -1) * jnp.asarray(scale, v.dtype)
    a_mod = jnp.einsum("...me,...mf->...ef", T.boxtimes(k, k), vh)
    y = 0.5 * jnp.einsum("...ne,...ef->...nf", T.boxtimes(q, q), a_mod)
    coef_lin = alpha ** 2 if normalize else jnp.asarray(1.0, q.dtype)
    coef_const = alpha ** 4 if normalize else jnp.asarray(1.0, q.dtype)
    y += coef_lin * jnp.einsum(
        "...nd,...df->...nf", q, jnp.einsum("...md,...mf->...df", k, vh))
    y += coef_const * jnp.sum(vh, -2, keepdims=True)
    return y[..., 1:] / y[..., :1]


def run(n=1024, d=32, scale=8.0):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    # trained-network magnitudes: activations are not unit-norm
    q = jax.random.normal(kq, (1, 2, n, d)) * scale
    k = jax.random.normal(kk, (1, 2, n, d)) * scale
    v = jax.random.normal(kv, (1, 2, n, d))

    rows = []
    for name, fn in (
        # paper App. B.1 setting: plain mixed-precision implementation
        ("naive_efficient_plain",
         lambda q, k, v: naive_efficient(q, k, v, normalize=False)),
        ("naive_efficient_norm",
         lambda q, k, v: naive_efficient(q, k, v, normalize=True)),
        # our shipped implementations (Alg.1 normalization + fp32 states)
        ("shipped_direct",
         lambda q, k, v: T.direct_taylorshift(q, k, v)),
        ("shipped_efficient",
         lambda q, k, v: T.efficient_taylorshift(q, k, v)),
        ("shipped_efficient_nonorm",
         lambda q, k, v: T.efficient_taylorshift(q, k, v,
                                                 normalize_inputs=False)),
    ):
        r16 = overflow_rate(fn, q, k, v, jnp.float16)
        r32 = overflow_rate(fn, q, k, v, jnp.float32)
        emit(f"norm_ablation_{name}", 0.0,
             f"overflow_f16={r16:.3f};overflow_f32={r32:.3f}")
        rows.append((name, r16))

    # paper Table 1 growth laws: |A_mod| ~ (N+1)/sqrt(d) (linear in N),
    # |Y| ~ sqrt(d/N). We validate the *scaling exponents* (App. B.2 fits
    # them empirically too; the absolute constant depends on the norm
    # convention).
    def amod_norm(nn):
        kq2, kk2, kv2 = jax.random.split(jax.random.PRNGKey(nn), 3)
        kk_ = T.l2_normalize(jax.random.normal(kk2, (1, 2, nn, d)))
        vv_ = T.l2_normalize(jax.random.normal(kv2, (1, 2, nn, d)))
        vh = jnp.concatenate([jnp.ones((1, 2, nn, 1)), vv_], -1)
        am = jnp.einsum("...me,...mf->...ef", T.boxtimes(kk_, kk_), vh)
        return float(jnp.mean(jnp.sqrt(jnp.sum(am * am, axis=(-1, -2)))))

    g = amod_norm(2 * n) / amod_norm(n)
    emit("norm_scaling_amod_growth", 0.0,
         f"N->2N_ratio={g:.2f};paper_model=2.0;ok={abs(g - 2.0) < 0.3}")
    # the headline reproduction (paper Table 4 / App. B.1): the naive
    # mixed-precision efficient form overflows; Alg. 1 normalization
    # rescues it; our fp32-state policy is immune either way.
    plain = dict(rows)["naive_efficient_plain"]
    fixed = dict(rows)["naive_efficient_norm"]
    shipped = dict(rows)["shipped_efficient"]
    emit("norm_ablation_conclusion", 0.0,
         f"naive_f16_overflow={plain:.3f};normalized_f16={fixed:.3f};"
         f"shipped_f16={shipped:.3f};reproduced={plain > 0 >= max(fixed, shipped) - 1e-9}")


if __name__ == "__main__":
    run()
