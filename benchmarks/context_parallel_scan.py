"""Sequence-parallel causal-scan wall-clock vs number of `seq` shards.

Measures the chunked causal TaylorShift scan at N ∈ {4k, 16k, 64k} on a
host-platform device mesh (XLA_FLAGS is set *before* the jax import, the
same trick launch/dryrun.py uses), sweeping the size of the `seq` axis:
S=1 is the streaming single-device `lax.scan`; S>1 runs the associative
scan with the shard_map chunk-boundary state exchange
(distributed/seqscan.py). Reports forward and grad wall-clock per call.

CPU host-platform "devices" share the same silicon, so absolute speedups
understate a real mesh — the point of the sweep is (a) the exchange
costs O(S·d³) regardless of N and (b) wall-clock does not *grow* with S
the way a sequential scan's chunk count does.

  PYTHONPATH=src python -m benchmarks.context_parallel_scan \
      --devices 8 --shards 1 2 4 8
"""

import argparse
import os
import sys

if __name__ == "__main__":
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=8)
    _ap.add_argument("--shards", type=int, nargs="+", default=[1, 2, 4, 8])
    _ap.add_argument("--seq-lens", type=int, nargs="+",
                     default=[4096, 16384, 65536])
    _ap.add_argument("--d", type=int, default=32)
    _ap.add_argument("--heads", type=int, default=2)
    _ap.add_argument("--chunk", type=int, default=256)
    _ap.add_argument("--grad", action="store_true",
                     help="also time the backward (custom-VJP recompute)")
    _ap.add_argument("--composed", nargs="*", default=None,
                     metavar="D,P,S",
                     help="also time the composed 3D train gradient "
                          "(distributed/composed.py) on these "
                          "(data, pipe, seq) mesh triplets, e.g. "
                          "--composed 2,2,2 1,2,4")
    _ap.add_argument("--global-batch", type=int, default=4)
    ARGS = _ap.parse_args()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ARGS.devices} "
        + os.environ.get("XLA_FLAGS", ""))

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402

from repro.core import taylor as T                          # noqa: E402
from repro.distributed import seqscan                       # noqa: E402
from repro.launch.mesh import make_seq_mesh                 # noqa: E402
from repro.models import backend as B                       # noqa: E402

from benchmarks.common import emit, timeit                  # noqa: E402


def scan_call(n, d, heads, chunk, shards, mesh, grad=False):
    key = jax.random.PRNGKey(n + shards)
    q, k, v = (jax.random.normal(kk, (1, heads, n, d))
               for kk in jax.random.split(key, 3))
    kwargs = {"chunk": B.plan_chunk(n, chunk, seq_shards=shards)}
    if shards > 1:
        kwargs["scan_fn"] = seqscan.make_seq_scan(mesh)

    def fwd(q, k, v):
        return T.causal_taylorshift(q, k, v, **kwargs)

    fn = (jax.jit(jax.grad(lambda *a: jnp.sum(fwd(*a) ** 2),
                           argnums=(0, 1, 2)))
          if grad else jax.jit(fwd))
    return fn, (q, k, v)


def run(seq_lens, shards_list, *, d, heads, chunk, grad=False):
    results = {}
    for n in seq_lens:
        base = None                      # the measured s=1 timing, if any
        for s in shards_list:
            if n % s:
                continue
            mesh = make_seq_mesh(s) if s > 1 else None
            fn, args = scan_call(n, d, heads, chunk, s, mesh, grad=grad)
            if mesh is not None:
                with mesh:
                    dt, _ = timeit(fn, *args, warmup=1, iters=3)
            else:
                dt, _ = timeit(fn, *args, warmup=1, iters=3)
            if s == 1:
                base = dt
            tag = "grad" if grad else "fwd"
            derived = (f"speedup_vs_s1={base / dt:.2f}" if base is not None
                       else "speedup_vs_s1=n/a")
            emit(f"ctx_scan_{tag}_n{n}_s{s}", dt * 1e6, derived)
            results[(n, s, grad)] = dt
    return results


def run_composed(seq_lens, triplets, *, global_batch=4, d_model=64):
    """Wall-clock of the composed 3D loss+grad (one fully-manual
    shard_map: FSDP gather + GPipe ring + seq-sharded scan) across mesh
    shapes — same model at every shape, so rows are comparable."""
    import dataclasses

    from repro.configs import get_config
    from repro.distributed import composed as Cmp
    from repro.launch.mesh import make_composed_mesh
    from repro.models import model as M

    results = {}
    for n in seq_lens:
        for dd, pp, ss in triplets:
            if dd * pp * ss > len(jax.devices()) or n % max(ss, 1):
                continue
            cfg = get_config("taylorshift-lra").reduced().with_(
                n_layers=2, d_model=d_model, n_heads=2, n_kv_heads=2,
                d_ff=2 * d_model, max_seq_len=n, dtype="float32",
                causal=True, remat=True)
            cfg = cfg.with_(taylor=dataclasses.replace(
                cfg.taylor, mode="efficient", use_kernel=False))
            mesh = make_composed_mesh(data=dd, pipe=pp, seq=ss)
            mb = max(1, min(2 * pp, global_batch // dd))
            grad_fn, _ = Cmp.build_composed_grad_fn(
                cfg, mesh, global_batch=global_batch, seq_len=n,
                n_microbatches=mb, fsdp=True)
            split = Cmp.split_params(
                cfg, M.init_params(cfg, jax.random.PRNGKey(0)), pp)
            pshard = Cmp.composed_param_shardings(split, mesh, fsdp=True)
            split = jax.device_put(split, pshard)
            tok = jax.random.randint(jax.random.PRNGKey(n),
                                     (global_batch, n), 0, cfg.vocab)
            batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
            with mesh:
                dt, _ = timeit(jax.jit(grad_fn), split, batch,
                               warmup=1, iters=2)
            emit(f"composed_grad_n{n}_mesh{dd}x{pp}x{ss}", dt * 1e6,
                 f"microbatches={mb};tok_s={global_batch * n / dt:.0f}")
            results[(n, dd, pp, ss)] = dt
    return results


if __name__ == "__main__":
    shards = [s for s in ARGS.shards if s <= len(jax.devices())]
    if shards != ARGS.shards:
        print(f"# clipped shard list to device count: {shards}",
              file=sys.stderr)
    run(ARGS.seq_lens, shards, d=ARGS.d, heads=ARGS.heads,
        chunk=ARGS.chunk)
    if ARGS.grad:
        run(ARGS.seq_lens, shards, d=ARGS.d, heads=ARGS.heads,
            chunk=ARGS.chunk, grad=True)
    if ARGS.composed is not None:
        triplets = [tuple(int(x) for x in t.split(","))
                    for t in (ARGS.composed or ["2,2,2", "1,2,4"])]
        run_composed(ARGS.seq_lens, triplets,
                     global_batch=ARGS.global_batch)
