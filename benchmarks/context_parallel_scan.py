"""Sequence-parallel causal-scan wall-clock vs number of `seq` shards.

Measures the chunked causal TaylorShift scan at N ∈ {4k, 16k, 64k} on a
host-platform device mesh (XLA_FLAGS is set *before* the jax import, the
same trick launch/dryrun.py uses), sweeping the size of the `seq` axis:
S=1 is the streaming single-device `lax.scan`; S>1 runs the associative
scan with the shard_map chunk-boundary state exchange
(distributed/seqscan.py). Reports forward and grad wall-clock per call.

CPU host-platform "devices" share the same silicon, so absolute speedups
understate a real mesh — the point of the sweep is (a) the exchange
costs O(S·d³) regardless of N and (b) wall-clock does not *grow* with S
the way a sequential scan's chunk count does.

  PYTHONPATH=src python -m benchmarks.context_parallel_scan \
      --devices 8 --shards 1 2 4 8
"""

import argparse
import os
import sys

if __name__ == "__main__":
    _ap = argparse.ArgumentParser()
    _ap.add_argument("--devices", type=int, default=8)
    _ap.add_argument("--shards", type=int, nargs="+", default=[1, 2, 4, 8])
    _ap.add_argument("--seq-lens", type=int, nargs="+",
                     default=[4096, 16384, 65536])
    _ap.add_argument("--d", type=int, default=32)
    _ap.add_argument("--heads", type=int, default=2)
    _ap.add_argument("--chunk", type=int, default=256)
    _ap.add_argument("--grad", action="store_true",
                     help="also time the backward (custom-VJP recompute)")
    ARGS = _ap.parse_args()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={ARGS.devices} "
        + os.environ.get("XLA_FLAGS", ""))

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402

from repro.core import taylor as T                          # noqa: E402
from repro.distributed import seqscan                       # noqa: E402
from repro.launch.mesh import make_seq_mesh                 # noqa: E402
from repro.models import backend as B                       # noqa: E402

from benchmarks.common import emit, timeit                  # noqa: E402


def scan_call(n, d, heads, chunk, shards, mesh, grad=False):
    key = jax.random.PRNGKey(n + shards)
    q, k, v = (jax.random.normal(kk, (1, heads, n, d))
               for kk in jax.random.split(key, 3))
    kwargs = {"chunk": B.plan_chunk(n, chunk, seq_shards=shards)}
    if shards > 1:
        kwargs["scan_fn"] = seqscan.make_seq_scan(mesh)

    def fwd(q, k, v):
        return T.causal_taylorshift(q, k, v, **kwargs)

    fn = (jax.jit(jax.grad(lambda *a: jnp.sum(fwd(*a) ** 2),
                           argnums=(0, 1, 2)))
          if grad else jax.jit(fwd))
    return fn, (q, k, v)


def run(seq_lens, shards_list, *, d, heads, chunk, grad=False):
    results = {}
    for n in seq_lens:
        base = None                      # the measured s=1 timing, if any
        for s in shards_list:
            if n % s:
                continue
            mesh = make_seq_mesh(s) if s > 1 else None
            fn, args = scan_call(n, d, heads, chunk, s, mesh, grad=grad)
            if mesh is not None:
                with mesh:
                    dt, _ = timeit(fn, *args, warmup=1, iters=3)
            else:
                dt, _ = timeit(fn, *args, warmup=1, iters=3)
            if s == 1:
                base = dt
            tag = "grad" if grad else "fwd"
            derived = (f"speedup_vs_s1={base / dt:.2f}" if base is not None
                       else "speedup_vs_s1=n/a")
            emit(f"ctx_scan_{tag}_n{n}_s{s}", dt * 1e6, derived)
            results[(n, s, grad)] = dt
    return results


if __name__ == "__main__":
    shards = [s for s in ARGS.shards if s <= len(jax.devices())]
    if shards != ARGS.shards:
        print(f"# clipped shard list to device count: {shards}",
              file=sys.stderr)
    run(ARGS.seq_lens, shards, d=ARGS.d, heads=ARGS.heads,
        chunk=ARGS.chunk)
    if ARGS.grad:
        run(ARGS.seq_lens, shards, d=ARGS.d, heads=ARGS.heads,
            chunk=ARGS.chunk, grad=True)
