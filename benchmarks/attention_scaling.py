"""Paper Figure 2: attention speed & memory vs sequence length.

Times direct-TaylorShift, efficient-TaylorShift, and softmax attention
(single head, like the paper's Fig. 2) on this host and reports the
empirical speed crossover N̂0 alongside the theoretical N0. Peak-entry
memory is computed from the paper's §4.2 counters (exact, hardware-free).
"""

import functools

import jax
import jax.numpy as jnp

from repro.core import taylor as T

from benchmarks.common import emit, timeit


def softmax_attn(q, k, v):
    x = jnp.einsum("...nd,...md->...nm", q, k) / jnp.sqrt(q.shape[-1])
    return jnp.einsum("...nm,...md->...nd", jax.nn.softmax(x, -1), v)


def run(d_values=(16, 32), n_values=(256, 512, 1024, 2048, 4096)):
    results = {}
    for d in d_values:
        crossing = None
        for n in n_values:
            key = jax.random.PRNGKey(n * d)
            q, k, v = (jax.random.normal(kk, (1, 1, n, d))
                       for kk in jax.random.split(key, 3))
            t_dir, _ = timeit(jax.jit(functools.partial(
                T.direct_taylorshift)), q, k, v)
            t_eff, _ = timeit(jax.jit(functools.partial(
                T.efficient_taylorshift)), q, k, v)
            t_sm, _ = timeit(jax.jit(softmax_attn), q, k, v)
            mem_dir = T.entries_direct(n, d)
            mem_eff = T.entries_efficient(n, d)
            emit(f"attn_d{d}_n{n}", t_dir * 1e6,
                 f"eff_us={t_eff * 1e6:.1f};softmax_us={t_sm * 1e6:.1f};"
                 f"entries_dir={mem_dir};entries_eff={mem_eff}")
            if crossing is None and t_eff < t_dir:
                crossing = n
        n0 = T.crossover_n0(d)
        results[d] = (crossing, n0)
        emit(f"attn_crossover_d{d}", 0.0,
             f"empirical_N0_bucket={crossing};theory_N0={n0:.0f}")
    return results


if __name__ == "__main__":
    run()
