"""Serving throughput: continuous-batching engine vs the naive loop.

Sweeps (batch, prompt_len, gen_len) over three serving paths:

  * ``naive``      — the old token-by-token loop (prefill AND decode
                     through single-token ``decode_step`` calls);
  * ``engine``     — chunked prefill + pooled decode, Taylor state;
  * ``engine_kv``  — same engine over a classic KV cache pool.

plus a prefill-only microbench at prompt length 512 (the chunked-prefill
headline: one full-intensity forward per chunk instead of P dispatches).

Emits the repo-standard ``name,us_per_call,derived`` rows (see
benchmarks/common.py) and a final JSON document on stdout; ``--json
PATH`` also writes the document to a file for the perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.serve import Engine, EngineConfig, Request

from benchmarks.common import emit


def _cfg(d_model=64, n_layers=2):
    return get_config("stablelm-1.6b").reduced().with_(
        d_model=d_model, n_layers=n_layers)


def _prompts(cfg, batch, plen, seed=0):
    p = jax.random.randint(jax.random.PRNGKey(seed), (batch, plen),
                           0, cfg.vocab)
    return [[int(t) for t in row] for row in p]


def time_naive(cfg, params, prompts, gen, step_fn, cache_kind="taylor"):
    """Token-by-token loop with a pre-jitted step (compile excluded)."""
    B, P = len(prompts), len(prompts[0])
    toks = jnp.asarray(prompts, jnp.int32)

    def run():
        cache = M.init_decode_state(cfg, B, cache_len=P + gen + 1,
                                    cache_kind=cache_kind,
                                    dtype=jnp.float32)
        logits = None
        t_pref = time.perf_counter()
        for t in range(P):
            logits, cache = step_fn({"tokens": toks[:, t:t+1]}, cache)
        jax.block_until_ready(logits)
        t_pref = time.perf_counter() - t_pref
        for _ in range(gen):
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            logits, cache = step_fn({"tokens": cur}, cache)
        jax.block_until_ready(logits)
        return t_pref

    run()                                   # warmup/compile
    t0 = time.perf_counter()
    t_pref = run()
    return time.perf_counter() - t0, t_pref


def time_engine(cfg, params, prompts, gen, cache_kind):
    B, P = len(prompts), len(prompts[0])
    eng = Engine(cfg, params, EngineConfig(
        n_slots=B, prefill_chunk=128, token_budget=128 + B,
        max_seq_len=P + gen + 1, cache_kind=cache_kind))

    def run(tag):
        from repro.serve.scheduler import EngineStats
        eng.stats = EngineStats()
        for i, p in enumerate(prompts):
            eng.submit(Request(f"{tag}{i}", p, max_new_tokens=gen))
        t0 = time.perf_counter()
        for _ in eng.run():
            pass
        dt = time.perf_counter() - t0
        s = eng.stats.summary()
        return dt, s

    run("warm")                             # warmup/compile
    return run("timed")


def run(cells=((2, 64, 16), (4, 64, 16), (4, 128, 16), (2, 128, 32)),
        prefill_len=512, d_model=64, n_layers=2):
    cfg = _cfg(d_model, n_layers)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c))
    doc = {"name": "serving_throughput",
           "config": {"d_model": d_model, "n_layers": n_layers,
                      "backend": jax.default_backend()},
           "cells": [], "prefill": {}}

    for B, P, G in cells:
        prompts = _prompts(cfg, B, P)
        total = B * (P + G)
        t_naive, _ = time_naive(cfg, params, prompts, G, step_fn)
        row = {"batch": B, "prompt_len": P, "gen_len": G,
               "naive_tok_s": total / t_naive}
        for kind in ("taylor", "kv"):
            dt, s = time_engine(cfg, params, prompts, G, kind)
            key = "engine_tok_s" if kind == "taylor" else "engine_kv_tok_s"
            row[key] = total / dt
            if kind == "taylor":
                row["ttft_mean_s"] = s["ttft_mean_s"]
        row["speedup_vs_naive"] = row["engine_tok_s"] / row["naive_tok_s"]
        doc["cells"].append(row)
        emit(f"serve_b{B}_p{P}_g{G}", t_naive * 1e6,
             f"naive_tok_s={row['naive_tok_s']:.1f};"
             f"engine_tok_s={row['engine_tok_s']:.1f};"
             f"engine_kv_tok_s={row['engine_kv_tok_s']:.1f};"
             f"speedup={row['speedup_vs_naive']:.2f}")

    # prefill-only: P=512 prompt, 1 generated token
    prompts = _prompts(cfg, 1, prefill_len, seed=7)
    _, t_pref_naive = time_naive(cfg, params, prompts, 1, step_fn)
    dt, s = time_engine(cfg, params, prompts, 1, "taylor")
    pref_naive = prefill_len / t_pref_naive
    pref_engine = s["prefill_tokens"] / dt if dt else 0.0
    doc["prefill"] = {
        "prompt_len": prefill_len,
        "naive_prefill_tok_s": pref_naive,
        "engine_prefill_tok_s": pref_engine,
        "speedup": pref_engine / pref_naive,
    }
    emit(f"serve_prefill_p{prefill_len}", t_pref_naive * 1e6,
         f"naive_tok_s={pref_naive:.1f};engine_tok_s={pref_engine:.1f};"
         f"speedup={pref_engine / pref_naive:.2f}")
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, help="also write JSON here")
    args = ap.parse_args()
    cells = ((2, 64, 8),) if args.fast else \
        ((2, 64, 16), (4, 64, 16), (4, 128, 16), (2, 128, 32))
    doc = run(cells=cells, prefill_len=512)
    print(json.dumps(doc, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)


if __name__ == "__main__":
    main()
