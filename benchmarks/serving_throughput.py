"""Serving throughput: continuous-batching engine vs the naive loop.

Sweeps (batch, prompt_len, gen_len) over three serving paths:

  * ``naive``      — the old token-by-token loop (prefill AND decode
                     through single-token ``decode_step`` calls);
  * ``engine``     — chunked prefill + pooled decode, Taylor state;
  * ``engine_kv``  — same engine over a classic KV cache pool.

plus a prefill-only microbench at prompt length 512 (the chunked-prefill
headline: one full-intensity forward per chunk instead of P dispatches),
plus a *decode-heavy* mode (short prefill, long generation — the regime
where decode throughput is bounded by step latency, not verification
bandwidth) comparing one-token-per-step decoding against speculative
decoding (src/repro/spec/) at several draft lengths, reporting tokens/s,
acceptance rate, and rollback count per cell,

plus a *shared-prefix* mode (``--shared-prefix``): requests opening
with one common system-prompt prefix, prefix cache
(serve/prefix_cache.py, ``EngineConfig.prefix_cache_mb``) on vs off,
reporting TTFT and reused tokens per overlap fraction.

Every cell reports latency percentiles (TTFT and ITL p50/p95/p99 from
the engine's metrics histograms) next to the means, and ``--trace
PREFIX`` writes one Chrome-trace JSON per standard cell
(``PREFIX_b{B}_p{P}_g{G}.json``, warmup included so first dispatches
are tagged ``compile=true`` — see docs/observability.md).

Emits the repo-standard ``name,us_per_call,derived`` rows (see
benchmarks/common.py) and a final JSON document on stdout; ``--json
PATH`` also writes the document to a file for the perf trajectory,
schema-checked by ``benchmarks.run.check_serving_doc`` first.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import SpecConfig, get_config
from repro.models import model as M
from repro.obs.trace import tracer
from repro.serve import Engine, EngineConfig, Request

from benchmarks.common import emit
from benchmarks.run import check_serving_doc

_PCTL_KEYS = ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
              "itl_p50_s", "itl_p95_s", "itl_p99_s")


def _cfg(d_model=64, n_layers=2):
    return get_config("stablelm-1.6b").reduced().with_(
        d_model=d_model, n_layers=n_layers)


def _prompts(cfg, batch, plen, seed=0):
    p = jax.random.randint(jax.random.PRNGKey(seed), (batch, plen),
                           0, cfg.vocab)
    return [[int(t) for t in row] for row in p]


def time_naive(cfg, params, prompts, gen, step_fn, cache_kind="taylor"):
    """Token-by-token loop with a pre-jitted step (compile excluded)."""
    B, P = len(prompts), len(prompts[0])
    toks = jnp.asarray(prompts, jnp.int32)

    def run():
        cache = M.init_decode_state(cfg, B, cache_len=P + gen + 1,
                                    cache_kind=cache_kind,
                                    dtype=jnp.float32)
        logits = None
        t_pref = time.perf_counter()
        for t in range(P):
            logits, cache = step_fn({"tokens": toks[:, t:t+1]}, cache)
        jax.block_until_ready(logits)
        t_pref = time.perf_counter() - t_pref
        for _ in range(gen):
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            logits, cache = step_fn({"tokens": cur}, cache)
        jax.block_until_ready(logits)
        return t_pref

    run()                                   # warmup/compile
    t0 = time.perf_counter()
    t_pref = run()
    return time.perf_counter() - t0, t_pref


def time_engine(cfg, params, prompts, gen, cache_kind):
    B, P = len(prompts), len(prompts[0])
    eng = Engine(cfg, params, EngineConfig(
        n_slots=B, prefill_chunk=128, token_budget=128 + B,
        max_seq_len=P + gen + 1, cache_kind=cache_kind))

    def run(tag):
        eng.reset_metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(f"{tag}{i}", p, max_new_tokens=gen))
        t0 = time.perf_counter()
        for _ in eng.run():
            pass
        dt = time.perf_counter() - t0
        s = eng.stats.summary()
        return dt, s

    run("warm")                             # warmup/compile
    return run("timed")


def run(cells=((2, 64, 16), (4, 64, 16), (4, 128, 16), (2, 128, 32)),
        prefill_len=512, d_model=64, n_layers=2, trace_prefix=None):
    cfg = _cfg(d_model, n_layers)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c))
    doc = {"name": "serving_throughput",
           "config": {"d_model": d_model, "n_layers": n_layers,
                      "backend": jax.default_backend()},
           "cells": [], "prefill": {}}

    for B, P, G in cells:
        prompts = _prompts(cfg, B, P)
        total = B * (P + G)
        t_naive, _ = time_naive(cfg, params, prompts, G, step_fn)
        row = {"batch": B, "prompt_len": P, "gen_len": G,
               "naive_tok_s": total / t_naive}
        if trace_prefix:
            tracer.clear()
            tracer.enable()
        for kind in ("taylor", "kv"):
            dt, s = time_engine(cfg, params, prompts, G, kind)
            key = "engine_tok_s" if kind == "taylor" else "engine_kv_tok_s"
            row[key] = total / dt
            if kind == "taylor":
                row["ttft_mean_s"] = s["ttft_mean_s"]
                for pk in _PCTL_KEYS:
                    row[pk] = s[pk]
        if trace_prefix:
            path = f"{trace_prefix}_b{B}_p{P}_g{G}.json"
            tracer.write(path)
            tracer.disable()
            tracer.clear()
            print(f"# trace -> {path}")
        row["speedup_vs_naive"] = row["engine_tok_s"] / row["naive_tok_s"]
        doc["cells"].append(row)
        emit(f"serve_b{B}_p{P}_g{G}", t_naive * 1e6,
             f"naive_tok_s={row['naive_tok_s']:.1f};"
             f"engine_tok_s={row['engine_tok_s']:.1f};"
             f"engine_kv_tok_s={row['engine_kv_tok_s']:.1f};"
             f"speedup={row['speedup_vs_naive']:.2f}")

    # prefill-only: P=512 prompt, 1 generated token
    prompts = _prompts(cfg, 1, prefill_len, seed=7)
    _, t_pref_naive = time_naive(cfg, params, prompts, 1, step_fn)
    dt, s = time_engine(cfg, params, prompts, 1, "taylor")
    pref_naive = prefill_len / t_pref_naive
    pref_engine = s["prefill_tokens"] / dt if dt else 0.0
    doc["prefill"] = {
        "prompt_len": prefill_len,
        "naive_prefill_tok_s": pref_naive,
        "engine_prefill_tok_s": pref_engine,
        "speedup": pref_engine / pref_naive,
    }
    emit(f"serve_prefill_p{prefill_len}", t_pref_naive * 1e6,
         f"naive_tok_s={pref_naive:.1f};engine_tok_s={pref_engine:.1f};"
         f"speedup={pref_engine / pref_naive:.2f}")
    return doc


# ---------------------------------------------------------------------------
# Shared-prefix mode: prefix cache vs cold prefill under system-prompt reuse
# ---------------------------------------------------------------------------

def _shared_prefix_prompts(cfg, batch, plen, shared_len, salt, seed=21):
    """``batch`` prompts opening with one common ``shared_len``-token
    prefix (the shared system prompt) and per-(salt, request) random
    tails — a distinct ``salt`` per run keeps warm-run tails out of the
    timed runs, so a timed engine can only reuse the *shared* prefix,
    never a whole earlier prompt (except at shared_len == plen, the
    identical-repeated-prompt limit)."""
    shared = jax.random.randint(jax.random.PRNGKey(seed), (shared_len,),
                                0, cfg.vocab)
    head = [int(t) for t in shared]
    out = []
    for b in range(batch):
        tail = jax.random.randint(
            jax.random.PRNGKey(seed + 1009 * (salt + 1) + b),
            (plen - shared_len,), 0, cfg.vocab)
        out.append(head + [int(t) for t in tail])
    return out


def time_shared_prefix(cfg, params, *, batch, plen, shared_len, gen,
                       prefill_chunk, prefix_cache_mb, reps=3):
    """One engine, warm + ``reps`` timed runs over the shared-prefix
    workload; the best (min-TTFT) rep is reported.

    The warm run compiles every shape AND (when the cache is on)
    populates the trie with the shared prefix; every timed rep uses
    fresh tails, so its hits are exactly the cross-request shared
    prefix — the production system-prompt-reuse pattern. Returns
    (wall_s, stats summary) of the best rep."""
    eng = Engine(cfg, params, EngineConfig(
        n_slots=batch, prefill_chunk=prefill_chunk,
        token_budget=prefill_chunk + batch,
        max_seq_len=plen + gen + 1, prefix_cache_mb=prefix_cache_mb))

    def once(tag, salt):
        eng.reset_metrics()
        prompts = _shared_prefix_prompts(cfg, batch, plen, shared_len, salt)
        for i, p in enumerate(prompts):
            eng.submit(Request(f"{tag}{i}", p, max_new_tokens=gen))
        t0 = time.perf_counter()
        for _ in eng.run():
            pass
        return time.perf_counter() - t0, eng.stats.summary()

    once("warm", 0)
    return min((once(f"timed{r}", r + 1) for r in range(reps)),
               key=lambda ws: ws[1]["ttft_mean_s"])


def run_shared_prefix(overlaps=(0.5, 0.75, 1.0), batch=4, plen=512,
                      gen=4, prefill_chunk=128, cache_mb=256,
                      d_model=64, n_layers=2):
    """Shared-prefix serving: TTFT and prefill throughput with the
    prefix cache on vs off, per prefix-overlap fraction.

    Overlap fractions are chunk-grid-aligned (the trie keys whole
    prefill chunks); at overlap f the cache skips f·P of every timed
    prompt, so TTFT should improve ~1/(1-f) when prefill dominates —
    the ≥3× acceptance line at f=0.75 (docs/benchmarks.md). f=1.0 is
    the identical-repeated-prompt limit: a full-prompt hit runs zero
    prefill dispatches and samples its first token from the cached
    boundary logits."""
    cfg = _cfg(d_model, n_layers)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    doc = {"name": "serving_shared_prefix",
           "config": {"batch": batch, "prompt_len": plen, "gen_len": gen,
                      "prefill_chunk": prefill_chunk,
                      "prefix_cache_mb": cache_mb, "d_model": d_model,
                      "n_layers": n_layers,
                      "backend": jax.default_backend()},
           "cells": []}
    for f in overlaps:
        shared_len = int(plen * f // prefill_chunk) * prefill_chunk
        _, s_cold = time_shared_prefix(
            cfg, params, batch=batch, plen=plen, shared_len=shared_len,
            gen=gen, prefill_chunk=prefill_chunk, prefix_cache_mb=0.0)
        wall, s_hot = time_shared_prefix(
            cfg, params, batch=batch, plen=plen, shared_len=shared_len,
            gen=gen, prefill_chunk=prefill_chunk, prefix_cache_mb=cache_mb)
        row = {"overlap": shared_len / plen,
               "shared_len": shared_len,
               "ttft_cold_s": s_cold["ttft_mean_s"],
               "ttft_cached_s": s_hot["ttft_mean_s"],
               "ttft_speedup": (s_cold["ttft_mean_s"]
                                / max(s_hot["ttft_mean_s"], 1e-9)),
               "prefill_tokens_cold": s_cold["prefill_tokens"],
               "prefill_tokens_cached": s_hot["prefill_tokens"],
               "cached_prefix_tokens": s_hot.get("cached_prefix_tokens", 0),
               "cache": s_hot.get("prefix_cache", {})}
        doc["cells"].append(row)
        emit(f"shared_prefix_f{int(row['overlap'] * 100)}", wall * 1e6,
             f"ttft_cold_s={row['ttft_cold_s']:.4f};"
             f"ttft_cached_s={row['ttft_cached_s']:.4f};"
             f"ttft_speedup={row['ttft_speedup']:.2f};"
             f"reused_tok={row['cached_prefix_tokens']}")
    return doc


# ---------------------------------------------------------------------------
# Router mode: prefix-affine placement vs affinity-blind, + migration cost
# ---------------------------------------------------------------------------

def _router_fleet(cfg, params, n, plen, gen, chunk):
    def mk(rid):
        return Engine(cfg, params, EngineConfig(
            replica_id=rid, n_slots=max(2, n // 2),
            prefill_chunk=chunk, token_budget=chunk + n,
            max_seq_len=plen + gen + 1, prefix_cache_mb=256))
    return [mk("r0"), mk("r1")]


def _router_reqs(cfg, n, plen, shared_len, salt, gen, seed=51):
    """``n`` requests alternating between two system prompts (A on even,
    B on odd), each with a fresh per-(salt, i) tail — the two-tenant
    workload where placement decides whether the shared prefix is a
    cache hit or a cold prefill."""
    heads = [
        [int(t) for t in jax.random.randint(
            jax.random.PRNGKey(seed + h), (shared_len,), 0, cfg.vocab)]
        for h in range(2)]
    out = []
    for i in range(n):
        tail = jax.random.randint(
            jax.random.PRNGKey(seed + 100 + 1009 * salt + i),
            (plen - shared_len,), 0, cfg.vocab)
        out.append(Request(f"s{salt}q{i}", heads[i % 2] + [int(t) for t in tail],
                           max_new_tokens=gen))
    return out


def _drive_assigned(engines, pairs):
    """Submit each (engine, request) pair and step all engines to
    completion; returns (mean TTFT, cache-served tokens, token lists)."""
    for eng, r in pairs:
        eng.reset_metrics()
    for eng, r in pairs:
        eng.submit(r)
    while not all(e.idle for e in engines):
        for e in engines:
            if not e.idle:
                e.step()
    seqs = [e.results[r.request_id] for e, r in pairs]
    ttft = sum(s.ttft for s in seqs) / len(seqs)
    toks = {s.request_id: s.out_tokens for s in seqs}
    return ttft, sum(s.cached_tokens for s in seqs), toks


def run_router(n_requests=8, plen=256, gen=4, chunk=64,
               d_model=64, n_layers=2):
    """Two-replica fleet serving a two-tenant shared-prefix workload:
    TTFT under prefix-affine routing (serve/router.py scores prompts
    against every replica's advertised trie boundaries) vs an
    affinity-blind round-robin that strands half the requests on the
    replica *not* holding their prefix — plus one measured live
    migration round trip (export → wire blob → import) and the
    bit-identity check across all three placements."""
    from repro.serve.router import Router

    cfg = _cfg(d_model, n_layers)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    shared_len = (3 * plen // 4 // chunk) * chunk
    doc = {"name": "serving_router",
           "config": {"replicas": 2, "requests": n_requests,
                      "prompt_len": plen, "shared_len": shared_len,
                      "gen_len": gen, "prefill_chunk": chunk,
                      "d_model": d_model, "n_layers": n_layers,
                      "backend": jax.default_backend()},
           "cells": []}

    # reference streams: one solo engine, no cache — the ground truth
    # every placement must reproduce bit-for-bit
    ref = Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=chunk, token_budget=chunk + n_requests,
        max_seq_len=plen + gen + 1))
    want = {}
    for salt in (1, 2):
        for r in _router_reqs(cfg, n_requests, plen, shared_len, salt, gen):
            want.update(ref.generate([r]))

    def warmed_fleet():
        """Fresh pair, warmed so tenant A's prefix is cached on r0 and
        B's on r1 (and every shape is compiled) before the clock runs."""
        fleet = _router_fleet(cfg, params, n_requests, plen, gen, chunk)
        warm = _router_reqs(cfg, n_requests, plen, shared_len, 0, gen)
        _drive_assigned(fleet, [(fleet[i % 2], r)
                                for i, r in enumerate(warm)])
        return fleet

    # arm 1: affinity-blind — requests alternate A,B but placement
    # pairs them off so exactly half land on the wrong replica
    fleet = warmed_fleet()
    reqs = _router_reqs(cfg, n_requests, plen, shared_len, 1, gen)
    t0 = time.perf_counter()
    ttft_blind, cached_blind, toks_blind = _drive_assigned(
        fleet, [(fleet[(i // 2) % 2], r) for i, r in enumerate(reqs)])
    wall_blind = time.perf_counter() - t0

    # arm 2: prefix-affine — the router scores each prompt against the
    # replicas' trie summaries and follows the longest cached prefix
    rt = Router(warmed_fleet())
    reqs = _router_reqs(cfg, n_requests, plen, shared_len, 2, gen)
    t0 = time.perf_counter()
    for r in reqs:
        rt.submit(r)
    for _ in rt.run():
        pass
    wall_affine = time.perf_counter() - t0
    seqs = [rt.results[r.request_id] for r in reqs]
    ttft_affine = sum(s.ttft for s in seqs) / len(seqs)
    cached_affine = sum(s.cached_tokens for s in seqs)
    prefix_routed = int(rt._prefix_c.value)

    bit_identical = (
        all(toks_blind[f"s1q{i}"] == want[f"s1q{i}"]
            for i in range(n_requests))
        and all(rt.results[f"s2q{i}"].out_tokens == want[f"s2q{i}"]
                for i in range(n_requests)))

    row = {"requests": n_requests, "shared_len": shared_len,
           "ttft_blind_s": ttft_blind, "ttft_affine_s": ttft_affine,
           "ttft_speedup": ttft_blind / max(ttft_affine, 1e-9),
           "cached_tokens_blind": cached_blind,
           "cached_tokens_affine": cached_affine,
           "prefix_routed": prefix_routed,
           "bit_identical": bit_identical}
    doc["cells"].append(row)
    emit(f"router_affine_r{n_requests}_p{plen}", wall_affine * 1e6,
         f"ttft_blind_s={ttft_blind:.4f};ttft_affine_s={ttft_affine:.4f};"
         f"ttft_speedup={row['ttft_speedup']:.2f};"
         f"cached_affine={cached_affine};cached_blind={cached_blind}")

    # migration round trip: drain a decoding stream, ship it, restore it
    # on the peer, finish there — timed, sized, and checked bit-exact
    rt2 = Router(_router_fleet(cfg, params, 2, plen, gen + 12, chunk))
    mreq = Request("mig0", _prompts(cfg, 1, plen, seed=77)[0],
                   max_new_tokens=gen + 12)
    mwant = Engine(cfg, params, EngineConfig(
        n_slots=1, prefill_chunk=chunk, token_budget=chunk + 1,
        max_seq_len=plen + gen + 13)).generate([mreq])["mig0"]
    rt2.submit(mreq)
    emitted = 0
    while emitted < 2:
        emitted += sum(e.request_id == "mig0" for e in rt2.step())
    src = rt2._owner["mig0"]
    dst = "r1" if src == "r0" else "r0"
    t0 = time.perf_counter()
    nbytes = rt2.migrate("mig0", dst)
    mig_wall = time.perf_counter() - t0
    for _ in rt2.run():
        pass
    doc["migration"] = {
        "wire_bytes": nbytes,
        "roundtrip_s": mig_wall,
        "tokens_before": 2, "tokens_total": gen + 12,
        "bit_identical": rt2.results["mig0"].out_tokens == mwant}
    emit(f"router_migrate_p{plen}", mig_wall * 1e6,
         f"wire_bytes={nbytes};"
         f"bit_identical={int(doc['migration']['bit_identical'])}")
    return doc


# ---------------------------------------------------------------------------
# Decode-heavy mode: one-token-per-step vs speculative decoding
# ---------------------------------------------------------------------------

def _loopy_prompts(cfg, batch, plen, period=6, seed=11):
    """Short prompts tiled from a random period — the prompt-lookup
    sweet spot, and a workload whose greedy continuations tend to cycle
    (which is what decode-heavy serving of extractive/templated traffic
    looks like)."""
    out = []
    for b in range(batch):
        pat = jax.random.randint(jax.random.PRNGKey(seed + b), (period,),
                                 0, cfg.vocab)
        row = [int(pat[i % period]) for i in range(plen)]
        out.append(row)
    return out


def time_spec_engine(cfg, params, prompts, gen, *, speculate_k, drafter,
                     draft_layers=1, cache_kind="taylor"):
    """Run the decode-heavy workload once warm, once timed. The metrics
    reset between runs also resets the adaptive draft controller, so
    both runs follow the same k trajectory and every verify shape is
    compiled before the clock starts. Returns (wall_s, stats summary)."""
    B = len(prompts)
    P = max(len(p) for p in prompts)
    eng = Engine(cfg, params, EngineConfig(
        n_slots=B, prefill_chunk=64, token_budget=64 + B * (speculate_k + 1),
        max_seq_len=P + gen + 1, cache_kind=cache_kind,
        speculate_k=speculate_k,
        spec=SpecConfig(drafter=drafter, draft_layers=draft_layers)))

    def once(tag):
        eng.reset_metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(f"{tag}{i}", p, max_new_tokens=gen))
        t0 = time.perf_counter()
        for _ in eng.run():
            pass
        return time.perf_counter() - t0, eng.stats.summary()

    once("warm")
    return once("timed")


def run_decode_heavy(batches=(1, 2), prompt_len=24, gen=256, ks=(4, 8),
                     d_model=128, n_layers=4):
    """Decode-heavy serving (short prefill, long generation): tokens/s
    with and without speculation, plus acceptance/rollback ledgers.

    The workload is templated/extractive-style traffic (periodic
    prompts; the untrained model's greedy continuations settle into
    cycles between output-scale-driven transients) — the regime
    prompt-lookup drafting targets. Acceptance therefore *oscillates*:
    ~1 inside a cyclic run, ~0 during a transient; the adaptive
    controller rides those swings and the reported acceptance rate is
    the honest average over both phases. batch=1 is the classic
    single-stream latency case; at batch>1 each prompt cycles with a
    different pattern, so transients interleave and the engine-global
    draft length pays an interference cost — both are reported.
    """
    cfg = _cfg(d_model, n_layers)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    doc = {"name": "serving_decode_heavy",
           "config": {"batches": list(batches), "prompt_len": prompt_len,
                      "gen_len": gen, "d_model": d_model,
                      "n_layers": n_layers,
                      "backend": jax.default_backend()},
           "cells": []}

    for batch in batches:
        prompts = _loopy_prompts(cfg, batch, prompt_len)
        wall0, s0 = time_spec_engine(cfg, params, prompts, gen,
                                     speculate_k=0, drafter="ngram")
        base_tok_s = s0["decode_tokens"] / wall0
        emit(f"decode_heavy_b{batch}_g{gen}_base", wall0 * 1e6,
             f"tok_s={base_tok_s:.1f}")
        doc["cells"].append({"batch": batch, "drafter": None,
                             "speculate_k": 0, "tok_s": base_tok_s,
                             "speedup": 1.0})
        for drafter in ("ngram", "self"):
            for k in ks:
                wall, s = time_spec_engine(cfg, params, prompts, gen,
                                           speculate_k=k, drafter=drafter)
                tok_s = s["decode_tokens"] / wall
                row = {"batch": batch, "drafter": drafter, "speculate_k": k,
                       "tok_s": tok_s, "speedup": tok_s / base_tok_s,
                       "acceptance_rate": s.get("acceptance_rate", 0.0),
                       "rollbacks": s.get("rollbacks", 0),
                       "mean_speculate_k": s.get("mean_speculate_k", 0)}
                doc["cells"].append(row)
                emit(f"decode_heavy_b{batch}_g{gen}_{drafter}_k{k}",
                     wall * 1e6,
                     f"tok_s={tok_s:.1f};speedup={row['speedup']:.2f};"
                     f"accept={row['acceptance_rate']:.2f};"
                     f"rollbacks={row['rollbacks']}")
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, help="also write JSON here")
    ap.add_argument("--decode-heavy", action="store_true",
                    help="only run the decode-heavy speculation cells")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="only run the shared-prefix prefix-cache cells")
    ap.add_argument("--router", action="store_true",
                    help="only run the two-replica router cells "
                         "(prefix-affine vs affinity-blind TTFT, one "
                         "timed live-migration round trip)")
    ap.add_argument("--trace", default=None, metavar="PREFIX",
                    help="write one Chrome-trace JSON per standard cell "
                         "to PREFIX_b{B}_p{P}_g{G}.json")
    args = ap.parse_args()
    if args.decode_heavy:
        doc = run_decode_heavy(batches=(1,) if args.fast else (1, 2),
                               gen=48 if args.fast else 256,
                               ks=(4,) if args.fast else (4, 8))
    elif args.shared_prefix:
        doc = run_shared_prefix(
            overlaps=(0.75,) if args.fast else (0.5, 0.75, 1.0),
            plen=256 if args.fast else 512,
            prefill_chunk=64 if args.fast else 128)
    elif args.router:
        doc = run_router(n_requests=4 if args.fast else 8,
                         plen=128 if args.fast else 256,
                         chunk=32 if args.fast else 64)
    else:
        cells = ((2, 64, 8),) if args.fast else \
            ((2, 64, 16), (4, 64, 16), (4, 128, 16), (2, 128, 32))
        doc = run(cells=cells, prefill_len=512, trace_prefix=args.trace)
        doc["decode_heavy"] = run_decode_heavy(
            batches=(1,) if args.fast else (1, 2),
            gen=48 if args.fast else 256,
            ks=(4,) if args.fast else (4, 8))
        doc["shared_prefix"] = run_shared_prefix(
            overlaps=(0.75,) if args.fast else (0.5, 0.75, 1.0),
            plen=256 if args.fast else 512,
            prefill_chunk=64 if args.fast else 128)
        doc["router"] = run_router(n_requests=4 if args.fast else 8,
                                   plen=128 if args.fast else 256,
                                   chunk=32 if args.fast else 64)
    check_serving_doc(doc)
    print(json.dumps(doc, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)


if __name__ == "__main__":
    main()
