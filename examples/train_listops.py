"""End-to-end training driver: the paper's evaluation setting (§5.3).

Trains the TaylorShift Transformer encoder on ListOps-style sequences
for a few hundred steps with the full substrate: sharded train step,
AdamW, cosine schedule, checkpointing, straggler detection. Sized for a
CPU smoke run; pass --scale paper for the paper's ListOps config
(depth 4, d_embed 512, 8 heads — Appendix C Table 6).

Run:  PYTHONPATH=src python examples/train_listops.py --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, listops_like
from repro.distributed.ft import StragglerDetector
from repro.checkpoint.manager import CheckpointManager
from repro.models import backend as B
from repro.models import classifier as C
from repro.optim import OptConfig, make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "paper"])
    ap.add_argument("--backend", default="taylor",
                    choices=["taylor", "softmax"])
    ap.add_argument("--no-kernels", action="store_true",
                    help="use the pure-jnp reference attention instead of "
                         "the fused Pallas kernels (custom-VJP training)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every loss is finite and the "
                         "trend decreases (CI training-smoke gate)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config("taylorshift-lra")
    if args.scale == "smoke":
        cfg = cfg.with_(d_model=64, n_layers=2, n_heads=4, n_kv_heads=4,
                        d_ff=128)
    cfg = cfg.with_(attn_backend=args.backend, vocab=16,
                    max_seq_len=args.seq + 1, remat=False, dtype="float32",
                    taylor=dataclasses.replace(cfg.taylor, tau_init=1.414))
    # kernel/mode routing resolves through models/backend.py:select_backend
    cfg = B.configure_for_training(cfg, use_kernels=not args.no_kernels)

    data_cfg = DataConfig(vocab=16, global_batch=args.batch,
                          seq_len=args.seq, kind="listops")
    params = C.classifier_init(cfg, 10, jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                        weight_decay=1e-3)
    init_opt, update = make_optimizer(opt_cfg)
    opt_state = init_opt(params)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    det = StragglerDetector()

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: C.classifier_loss(p, cfg, batch))(params)
        params, opt_state, m = update(params, grads, opt_state)
        return params, opt_state, loss

    losses = []
    for s in range(args.steps):
        t0 = time.time()
        b = {k: jnp.asarray(v) for k, v in listops_like(data_cfg, s).items()}
        params, opt_state, loss = step_fn(params, opt_state, b)
        det.observe(time.time() - t0)
        losses.append(float(loss))
        if s % 25 == 0:
            print(f"step {s:4d} loss {float(loss):.4f}")
        if mgr and s and s % 100 == 0:
            mgr.save(s, (params, opt_state))

    if args.check:
        third = max(len(losses) // 3, 1)
        head, tail = np.mean(losses[:third]), np.mean(losses[-third:])
        ok = np.all(np.isfinite(losses)) and tail < head
        print(f"check: finite={bool(np.all(np.isfinite(losses)))} "
              f"trend {head:.4f} -> {tail:.4f} "
              f"({'decreasing' if tail < head else 'NOT decreasing'})")
        if not ok:
            raise SystemExit("training smoke check failed")

    accs = [float(C.classifier_accuracy(
        params, cfg, {k: jnp.asarray(v)
                      for k, v in listops_like(data_cfg, args.steps + i).items()}))
            for i in range(8)]
    if mgr:
        mgr.wait()
    print(f"final eval accuracy: {np.mean(accs):.3f} "
          f"(chance 0.1) backend={args.backend} "
          f"stragglers={det.stragglers}")


if __name__ == "__main__":
    main()
