"""Long-context serving: constant-memory TaylorShift decode vs KV cache.

The paper's memory crossover (N1) applied to serving: a KV cache grows
O(N) with context; the Taylor state is O(d²) — constant. This example
decodes with both cache kinds, checks they produce the same logits (the
model is the same), and prints the cache-size ledger that makes the
``long_500k`` dry-run cell feasible.

Run:  PYTHONPATH=src python examples/long_context_serve.py --context 256
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.taylor import crossover_n1
from repro.models import model as M


def cache_bytes(tree):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config("stablelm-1.6b").reduced().with_(d_model=64, head_dim=32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.context), 0, cfg.vocab)

    logits = {}
    for kind in ("taylor", "kv"):
        cache = M.init_decode_state(cfg, args.batch, cache_len=args.context,
                                    cache_kind=kind, dtype=jnp.float32)
        step = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c))
        for t in range(args.context):
            lg, cache = step({"tokens": tokens[:, t:t+1]}, cache)
        logits[kind] = lg
        print(f"cache={kind:6s}: {cache_bytes(cache) / 1e6:8.2f} MB after "
              f"{args.context} tokens")

    err = float(jnp.max(jnp.abs(logits["taylor"] - logits["kv"])))
    print(f"taylor-state vs kv-cache logits max|Δ| = {err:.2e} "
          f"(same attention, different cache algebra)")

    d = cfg.dim_head
    print(f"\nmemory crossover N1(d={d}) = {crossover_n1(d):.0f} tokens;")
    for n in (1_000, 32_768, 524_288):
        kv = 2 * n * d * cfg.kv_heads * 2            # bf16 K+V per layer
        ts = (d * d + d + 1) * (d + 1) * 4           # fp32 taylor state
        print(f"  context {n:>7,}: KV cache {kv/1e6:10.1f} MB/layer vs "
              f"Taylor state {ts/1e6:6.2f} MB/layer "
              f"({kv/ts:7.1f}x)")


if __name__ == "__main__":
    main()
