"""Long-context serving: constant-memory TaylorShift decode vs KV cache.

The paper's memory crossover (N1) applied to serving: a KV cache grows
O(N) with context; the Taylor state is O(d²) — constant. This example
decodes with both cache kinds, checks they produce the same logits (the
model is the same), and prints the cache-size ledger that makes the
``long_500k`` dry-run cell feasible.

With ``--speculate K`` it then streams a generation from the long
prompt through the serving engine's speculative path: the constant-size
state is what makes draft rollback O(d²) even at this context length
(snapshotting a KV cache here would copy the whole O(N) history).
``--top-p`` switches the stream to nucleus sampling — per-request
sampling params ride on the ``Request``, not the engine.

Run:  PYTHONPATH=src python examples/long_context_serve.py --context 256 \
          --speculate 4 --gen 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SpecConfig, get_config
from repro.core.taylor import crossover_n1
from repro.models import model as M
from repro.serve import Engine, EngineConfig, Request


def cache_bytes(tree):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


def stream_speculative(cfg, params, prompt, *, gen, speculate, drafter,
                       top_p):
    """Stream one long-prompt generation through the engine, with and
    without speculation, printing per-token events and the accept/
    rollback ledger."""
    temp = 0.0 if top_p >= 1.0 else 0.8
    mk = lambda k: Engine(cfg, params, EngineConfig(
        n_slots=1, prefill_chunk=64, token_budget=128,
        max_seq_len=len(prompt) + gen + 1, temperature=temp,
        speculate_k=k, spec=SpecConfig(drafter=drafter, draft_layers=1)))
    req = lambda: Request("long", prompt, max_new_tokens=gen, top_p=top_p)

    eng = mk(speculate)
    eng.submit(req())
    t0, toks = time.perf_counter(), []
    for ev in eng.run():
        toks.append(ev.token)
        flags = ("FIRST " if ev.first else "") + ("DONE" if ev.finished else "")
        print(f"  t={time.perf_counter() - t0:6.2f}s "
              f"token[{ev.index:3d}] = {ev.token:6d} {flags}")
    s = eng.stats.summary()
    print(f"\nspeculate={speculate} drafter={drafter}: "
          f"{s['decode_tokens']} tokens in {s['wall_s']:.2f}s "
          f"({s['decode_tok_s']:.1f} tok/s)"
          + (f", acceptance={s['acceptance_rate']:.2f}, "
             f"rollbacks={s['rollbacks']}, "
             f"mean draft length={s['mean_speculate_k']:.1f}"
             if "acceptance_rate" in s else ""))
    if temp == 0.0:
        base = mk(0)
        ref = base.generate([req()])["long"]
        b = base.stats.summary()
        print(f"speculate=0 baseline: {b['decode_tok_s']:.1f} tok/s; "
              f"streams {'MATCH' if ref == toks else 'DIFFER'} "
              "(greedy speculation is exact)")
    else:
        print(f"nucleus sampling top_p={top_p}: speculation idles for an "
              "all-sampled stream (the engine falls back to plain decode; "
              "sampled rows always reject drafts — docs/serving.md)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--gen", type=int, default=24,
                    help="tokens to stream in the speculative demo")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="run the streamed speculative-generation demo "
                         "with draft length <= K")
    ap.add_argument("--drafter", default="ngram", choices=["ngram", "self"])
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling for the streamed demo "
                         "(1.0 = greedy, which verifies exactly)")
    args = ap.parse_args()

    cfg = get_config("stablelm-1.6b").reduced().with_(d_model=64, head_dim=32)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.context), 0, cfg.vocab)

    logits = {}
    for kind in ("taylor", "kv"):
        cache = M.init_decode_state(cfg, args.batch, cache_len=args.context,
                                    cache_kind=kind, dtype=jnp.float32)
        step = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c))
        for t in range(args.context):
            lg, cache = step({"tokens": tokens[:, t:t+1]}, cache)
        logits[kind] = lg
        print(f"cache={kind:6s}: {cache_bytes(cache) / 1e6:8.2f} MB after "
              f"{args.context} tokens")

    err = float(jnp.max(jnp.abs(logits["taylor"] - logits["kv"])))
    print(f"taylor-state vs kv-cache logits max|Δ| = {err:.2e} "
          f"(same attention, different cache algebra)")

    d = cfg.dim_head
    print(f"\nmemory crossover N1(d={d}) = {crossover_n1(d):.0f} tokens;")
    for n in (1_000, 32_768, 524_288):
        kv = 2 * n * d * cfg.kv_heads * 2            # bf16 K+V per layer
        ts = (d * d + d + 1) * (d + 1) * 4           # fp32 taylor state
        print(f"  context {n:>7,}: KV cache {kv/1e6:10.1f} MB/layer vs "
              f"Taylor state {ts/1e6:6.2f} MB/layer "
              f"({kv/ts:7.1f}x)")

    if args.speculate > 0:
        prompt = [int(t) for t in tokens[0]]
        print(f"\nstreaming {args.gen} tokens from the {len(prompt)}-token "
              f"prompt (speculate_k={args.speculate}, "
              f"drafter={args.drafter}, top_p={args.top_p}):")
        stream_speculative(cfg, params, prompt, gen=args.gen,
                           speculate=args.speculate, drafter=args.drafter,
                           top_p=args.top_p)


if __name__ == "__main__":
    main()
