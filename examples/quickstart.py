"""Quickstart: the TaylorShift public API in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import taylor as T
from repro.kernels import ops as K

key = jax.random.PRNGKey(0)
B, H, N, d = 2, 4, 512, 32
q, k, v = (jax.random.normal(kk, (B, H, N, d))
           for kk in jax.random.split(key, 3))

# 1. The paper's identity: direct and efficient compute the SAME attention
y_direct = T.direct_taylorshift(q, k, v, tau=1.4)
y_efficient = T.efficient_taylorshift(q, k, v, tau=1.4)
err = float(jnp.max(jnp.abs(y_direct - y_efficient)))
print(f"direct vs efficient max|Δ| = {err:.2e}   (same math, "
      f"O(N²d) vs O(Nd³))")

# 2. The crossover ("and Back"): pick the cheaper form per (N, d)
for n in (256, 1024, 4096):
    print(f"  N={n:5d} d={d}: paper picks {T.pick_mode(n, d)!r} "
          f"(N0={T.crossover_n0(d):.0f})")

# 3. Causal decoding with a CONSTANT-SIZE state — no KV cache
state = T.TaylorState.zeros((B, H), d)
for t in range(8):
    qt, kt, vt = q[:, :, t:t+1], k[:, :, t:t+1], v[:, :, t:t+1]
    y_t, state = T.taylor_decode_step(state, qt, kt, vt, tau=1.4)
print(f"decode state after 8 tokens: s2 {state.s2.shape} "
      f"(size never grows with context — this is what makes 500k-token "
      f"decoding feasible)")

# 4. The fused Pallas kernels (TPU target; interpret mode on CPU)
y_kernel = K.taylor_attention_kernel(q, k, v, tau=1.4, mode="efficient")
err = float(jnp.max(jnp.abs(y_kernel - y_efficient)))
print(f"pallas fused kernel vs reference max|Δ| = {err:.2e}")

# 5. A full model with TaylorShift as a first-class attention backend
from repro.configs import get_config
from repro.models import model as M

cfg = get_config("stablelm-1.6b").reduced()
params = M.init_params(cfg, key)
tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab)
hidden, _ = M.forward(params, cfg, {"tokens": tokens})
print(f"stablelm-1.6b (reduced) forward: {hidden.shape}, "
      f"params={M.count_params(params):,}")
print("OK")
