"""Shared neural-net building blocks (pure-functional, dict params).

Every layer is an (init, apply) pair over plain pytrees so the whole
framework stays framework-free (no flax/haiku dependency) and trivially
shardable with pjit: params are dicts of jnp arrays whose tree paths are
matched against sharding rules in repro/distributed/sharding.py.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: float | None = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return {"w": _normal(key, (d_in, d_out), scale, dtype)}


def dense(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    # Accumulation dtype matches activations: the MXU still accumulates
    # fp32 per-tile, but cross-shard partial sums (TP contractions) then
    # travel as bf16 — §Perf iteration 3 halved activation-collective
    # wire bytes this way. fp32 activations keep fp32 end-to-end.
    return jnp.einsum("...i,io->...o", x, params["w"],
                      preferred_element_type=x.dtype)


def embedding_init(key, vocab: int, d_model: int, dtype=jnp.bfloat16) -> Params:
    return {"emb": _normal(key, (vocab, d_model), 1.0, dtype)}


def embed(params: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["emb"], ids, axis=0)


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied read-out: logits = x @ embᵀ."""
    return jnp.einsum("...d,vd->...v", x, params["emb"],
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def make_norm(kind: str):
    if kind == "rms":
        return rmsnorm_init, rmsnorm
    if kind == "ln":
        return layernorm_init, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = True,
             dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {
        "up": dense_init(k1, d_model, d_ff, dtype),
        "down": dense_init(k2, d_ff, d_model, dtype),
    }
    if gated:
        p["gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def mlp(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    f = _ACTS[act]
    h = dense(params["up"], x)
    if "gate" in params:
        h = h * f(dense(params["gate"], x))
    else:
        h = f(h)
    return dense(params["down"], h)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., N, d_head) with d_head even; positions: (N,) or (..., N)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                     # (d/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., N, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def learned_pos_init(key, max_len: int, d_model: int,
                     dtype=jnp.bfloat16) -> Params:
    return {"pos": _normal(key, (max_len, d_model), 0.02, dtype)}


def add_learned_pos(params: Params, x: jnp.ndarray,
                    positions: jnp.ndarray) -> jnp.ndarray:
    return x + jnp.take(params["pos"], positions, axis=0).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)
