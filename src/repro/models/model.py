"""Model assembly: decoder LMs, hybrids, xLSTM, and encoder-decoder.

Layers are grouped by the config's ``layer_pattern`` (the repeating
heterogeneity unit) and scanned with ``lax.scan`` over stacked parameter
pytrees, so HLO size and compile time are O(pattern length), not
O(n_layers) — essential for 46–81-layer archs compiled 80× in the
dry-run sweep. A remainder of ``n_layers mod len(pattern)`` layers is
unrolled at the end.

Public surface:
  init_params(cfg, rng)                      -> params (or eval_shape'able)
  forward(params, cfg, batch, training)      -> (hidden, aux_loss)
  loss_fn(params, cfg, batch)                -> scalar (chunked xent)
  init_decode_state(cfg, batch, cache_len, cache_kind) -> cache
  decode_step(params, cfg, batch, cache)     -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import taylor as T
from repro.distributed import ctx
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import xlstm as XL

Params = dict[str, Any]

ATTN_KINDS = ("global", "local", "global_moe")


# ---------------------------------------------------------------------------
# Block init / apply / decode — dispatch on pattern kind
# ---------------------------------------------------------------------------

def _block_init(kind: str, key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    ninit, _ = L.make_norm(cfg.norm)
    ks = jax.random.split(key, 4)
    p: Params = {}
    if kind in ATTN_KINDS:
        p["norm1"] = ninit(cfg.d_model)
        p["attn"] = A.attn_init(ks[0], cfg)
        if cfg.post_norm:
            p["norm1_post"] = ninit(cfg.d_model)
        if cross:
            p["norm_x"] = ninit(cfg.d_model)
            p["cross"] = A.attn_init(ks[3], cfg)
        if cfg.d_ff:
            p["norm2"] = ninit(cfg.d_model)
            if kind == "global_moe":
                p["moe"] = MOE.moe_init(ks[1], cfg)
            else:
                p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                      gated=cfg.gated_mlp,
                                      dtype=cfg.param_dtype)
            if cfg.post_norm:
                p["norm2_post"] = ninit(cfg.d_model)
    elif kind == "mamba":
        p["norm1"] = ninit(cfg.d_model)
        p["mamba"] = M2.mamba2_init(ks[0], cfg)
    elif kind == "mamba_shared":
        # shared attention weights live at top level; only norms are local
        p["norm_shared"] = ninit(cfg.d_model)
        p["norm1"] = ninit(cfg.d_model)
        p["mamba"] = M2.mamba2_init(ks[0], cfg)
    elif kind == "mlstm":
        p["norm1"] = ninit(cfg.d_model)
        p["mlstm"] = XL.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["norm1"] = ninit(cfg.d_model)
        p["slstm"] = XL.slstm_init(ks[0], cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def _block_apply(kind: str, p: Params, cfg: ModelConfig, x, *, positions,
                 causal: bool, shared: Params | None,
                 cross_kv=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One residual block. Sequence-parallel discipline: the residual
    carry x stays d_model-sharded; each sub-layer's input is explicitly
    all-gathered in bf16 (ctx.gathered) and its output reduce-scattered
    back (ctx.activations)."""
    _, norm = L.make_norm(cfg.norm)
    aux = jnp.zeros((), jnp.float32)
    if kind in ATTN_KINDS:
        z = ctx.gathered(norm(p["norm1"], x))
        h = A.attn_apply(p["attn"], cfg, z, positions=positions,
                         kind="local" if kind == "local" else "global",
                         causal=causal)
        h = ctx.activations(h)
        if cfg.post_norm:
            h = norm(p["norm1_post"], h)
        x = x + h
        if cross_kv is not None:
            h = A.attn_apply(p["cross"], cfg,
                             ctx.gathered(norm(p["norm_x"], x)),
                             positions=positions, cross_kv=cross_kv)
            x = x + ctx.activations(h)
        if cfg.d_ff:
            z = ctx.gathered(norm(p["norm2"], x))
            if kind == "global_moe":
                h, aux = MOE.moe_apply(p["moe"], cfg, z)
            else:
                h = L.mlp(p["mlp"], z, act=cfg.act)
            h = ctx.activations(h)
            if cfg.post_norm:
                h = norm(p["norm2_post"], h)
            x = x + h
    elif kind in ("mamba", "mamba_shared"):
        if kind == "mamba_shared":
            assert shared is not None
            h = A.attn_apply(shared["attn"], cfg,
                             ctx.gathered(norm(p["norm_shared"], x)),
                             positions=positions, causal=causal)
            x = x + ctx.activations(h)
        h = M2.mamba2_apply(p["mamba"], cfg,
                            ctx.gathered(norm(p["norm1"], x)))
        x = x + ctx.activations(h)
    elif kind == "mlstm":
        h = XL.mlstm_apply(p["mlstm"], cfg, ctx.gathered(norm(p["norm1"], x)))
        x = x + ctx.activations(h)
    elif kind == "slstm":
        h = XL.slstm_apply(p["slstm"], cfg, ctx.gathered(norm(p["norm1"], x)))
        x = x + ctx.activations(h)
    return x, aux


def _block_init_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int,
                      cache_kind: str, dtype, per_slot: bool = False):
    if kind in ATTN_KINDS:
        return A.init_cache(cfg, batch, kind="global", cache_len=cache_len,
                            cache_kind=cache_kind, dtype=dtype,
                            per_slot=per_slot)
    if kind == "local":  # pragma: no cover — kind handled above
        raise AssertionError
    if kind == "mamba":
        return M2.mamba2_init_cache(cfg, batch)
    if kind == "mamba_shared":
        return {"attn": A.init_cache(cfg, batch, kind="global",
                                     cache_len=cache_len,
                                     cache_kind=cache_kind, dtype=dtype,
                                     per_slot=per_slot),
                "mamba": M2.mamba2_init_cache(cfg, batch)}
    if kind == "mlstm":
        return XL.mlstm_init_cache(cfg, batch)
    if kind == "slstm":
        return XL.slstm_init_cache(cfg, batch)
    raise ValueError(kind)


def _cache_kind_for(kind: str, cfg: ModelConfig, cache_kind: str, batch: int,
                    cache_len: int, dtype, per_slot: bool = False):
    if kind == "local":
        return A.init_cache(cfg, batch, kind="local", cache_len=cache_len,
                            cache_kind="kv", dtype=dtype, per_slot=per_slot)
    return _block_init_cache(kind, cfg, batch, cache_len, cache_kind, dtype,
                             per_slot)


def _block_decode(kind: str, p: Params, cfg: ModelConfig, x, cache, *,
                  shared: Params | None, cross_state=None):
    _, norm = L.make_norm(cfg.norm)
    if kind in ATTN_KINDS or kind == "local":
        akind = "local" if kind == "local" else "global"
        h, cache_a = A.attn_decode(
            p["attn"], cfg, norm(p["norm1"], x),
            cache["self"] if cross_state is not None else cache, kind=akind)
        if cfg.post_norm:
            h = norm(p["norm1_post"], h)
        x = x + h
        if cross_state is not None:
            h, _ = A.attn_decode(p["cross"], cfg, norm(p["norm_x"], x), None,
                                 cross_state=cross_state)
            x = x + h
            cache = {"self": cache_a}
        else:
            cache = cache_a
        if cfg.d_ff:
            z = norm(p["norm2"], x)
            if kind == "global_moe":
                h, _ = MOE.moe_apply(p["moe"], cfg, z)
            else:
                h = L.mlp(p["mlp"], z, act=cfg.act)
            if cfg.post_norm:
                h = norm(p["norm2_post"], h)
            x = x + h
    elif kind in ("mamba", "mamba_shared"):
        if kind == "mamba_shared":
            h, ca = A.attn_decode(shared["attn"], cfg,
                                  norm(p["norm_shared"], x), cache["attn"])
            x = x + h
            y, cm = M2.mamba2_decode(p["mamba"], cfg, norm(p["norm1"], x),
                                     cache["mamba"])
            x = x + y
            cache = {"attn": ca, "mamba": cm}
        else:
            y, cache = M2.mamba2_decode(p["mamba"], cfg, norm(p["norm1"], x),
                                        cache)
            x = x + y
    elif kind == "mlstm":
        y, cache = XL.mlstm_decode(p["mlstm"], cfg, norm(p["norm1"], x), cache)
        x = x + y
    elif kind == "slstm":
        y, cache = XL.slstm_decode(p["slstm"], cfg, norm(p["norm1"], x), cache)
        x = x + y
    return x, cache


PREFILL_KINDS = ("global", "global_moe")


def _block_absorb(kind: str, p: Params, cfg: ModelConfig, x, cache, *,
                  attend, what: str):
    """One residual block over a multi-token chunk that consumes and
    returns the decode cache — shared body of chunked prefill
    (``attend=attn_prefill``, per-sequence scalar counters) and
    speculative verify (``attend=attn_verify``, per-slot counters; the
    whole pool in one call). Global-attention kinds only: local
    ring-buffer windows and SSM/xLSTM blocks would need their own
    chunkwise state handoff."""
    if kind not in PREFILL_KINDS:
        raise NotImplementedError(f"{what}: unsupported block kind {kind!r}")
    _, norm = L.make_norm(cfg.norm)
    h, cache = attend(p["attn"], cfg, norm(p["norm1"], x), cache)
    if cfg.post_norm:
        h = norm(p["norm1_post"], h)
    x = x + h
    if cfg.d_ff:
        z = norm(p["norm2"], x)
        if kind == "global_moe":
            h, _ = MOE.moe_apply(p["moe"], cfg, z)
        else:
            h = L.mlp(p["mlp"], z, act=cfg.act)
        if cfg.post_norm:
            h = norm(p["norm2_post"], h)
        x = x + h
    return x, cache


def _block_prefill(kind: str, p: Params, cfg: ModelConfig, x, cache):
    return _block_absorb(kind, p, cfg, x, cache, attend=A.attn_prefill,
                         what="chunked prefill")


def _block_verify(kind: str, p: Params, cfg: ModelConfig, x, cache):
    return _block_absorb(kind, p, cfg, x, cache, attend=A.attn_verify,
                         what="speculative verify")


# ---------------------------------------------------------------------------
# Stacking machinery
# ---------------------------------------------------------------------------

def _pattern_layout(cfg: ModelConfig, n_layers: int | None = None):
    pattern = tuple(cfg.layer_pattern)
    n = n_layers if n_layers is not None else cfg.n_layers
    P = len(pattern)
    return pattern, n // P, tuple(pattern[i] for i in range(n % P))


def _stacked_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, rng) -> Params:
    pattern, n_groups, rem = _pattern_layout(cfg)
    keys = jax.random.split(rng, 8)
    p: Params = {"embed": L.embedding_init(keys[0], cfg.vocab, cfg.d_model,
                                           cfg.param_dtype)}
    ninit, _ = L.make_norm(cfg.norm)
    p["final_norm"] = ninit(cfg.d_model)
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_init(keys[6], cfg.d_model, cfg.vocab,
                                    cfg.param_dtype)
    if cfg.pos_embed == "learned":
        p["pos"] = L.learned_pos_init(keys[5], cfg.max_seq_len, cfg.d_model,
                                      cfg.param_dtype)
    if any(k == "mamba_shared" for k in cfg.layer_pattern):
        p["shared_attn"] = {"attn": A.attn_init(keys[4], cfg)}

    if n_groups:
        p["groups"] = [
            _stacked_init(lambda k, kind=kind: _block_init(kind, k, cfg),
                          jax.random.fold_in(keys[1], i), n_groups)
            for i, kind in enumerate(pattern)
        ]
    else:
        p["groups"] = []
    p["rem"] = [_block_init(kind, jax.random.fold_in(keys[2], i), cfg)
                for i, kind in enumerate(rem)]

    if cfg.family == "encdec":
        enc_cfg = cfg
        p["enc"] = {
            # STUB frontend (per assignment): linear mel->d_model projection
            "frontend_proj": L.dense_init(
                jax.random.fold_in(keys[7], 1), 128, cfg.d_model,
                cfg.param_dtype),
            "pos": L.learned_pos_init(keys[7], max(cfg.encoder_frames,
                                                   cfg.max_seq_len),
                                      cfg.d_model, cfg.param_dtype),
            "blocks": _stacked_init(
                lambda k: _block_init("global", k, enc_cfg),
                keys[3], cfg.n_encoder_layers),
            "final_norm": ninit(cfg.d_model),
        }
        # decoder blocks get cross-attention
        p["groups"] = [_stacked_init(
            lambda k: _block_init("global", k, cfg, cross=True),
            keys[1], n_groups)]
        p["rem"] = []
    return p


# ---------------------------------------------------------------------------
# Forward (train / full-sequence)
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch):
    """Token (+ frontend-stub) embedding. Returns (x, positions)."""
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens) * jnp.asarray(
        jnp.sqrt(cfg.d_model), cfg.param_dtype)
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    if cfg.pos_embed == "learned":
        x = L.add_learned_pos(params["pos"], x, positions)
    return ctx.activations(x), positions


def _run_blocks(params, cfg: ModelConfig, x, positions, *, causal: bool,
                cross_kv_list=None, n_layers: int | None = None):
    pattern, n_groups, rem = _pattern_layout(cfg, n_layers)
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)

    if n_groups:
        def group_body(x, sliced):
            aux = jnp.zeros((), jnp.float32)
            for kind, bp in zip(pattern, sliced):
                x, a = _block_apply(kind, bp, cfg, x, positions=positions,
                                    causal=causal, shared=shared)
                aux += a
            return ctx.activations(x), aux

        body = jax.checkpoint(group_body) if cfg.remat else group_body

        def scan_fn(x, sliced):
            return body(x, sliced)

        x, auxs = jax.lax.scan(scan_fn, x, tuple(params["groups"]))
        aux_total += jnp.sum(auxs)

    for kind, bp in zip(rem, params["rem"]):
        x, a = _block_apply(kind, bp, cfg, x, positions=positions,
                            causal=causal, shared=shared)
        aux_total += a
    return x, aux_total


def _encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over (stubbed) mel frames (B, M, n_mels) or
    precomputed embeddings (B, M, d_model)."""
    x = frames.astype(cfg.param_dtype)
    if x.shape[-1] != cfg.d_model:
        x = L.dense(params["enc"]["frontend_proj"], x)
    x = ctx.activations(x)
    pos = jnp.arange(x.shape[1])
    x = L.add_learned_pos(params["enc"]["pos"], x, pos)

    def body(x, bp):
        x, _ = _block_apply("global", bp, cfg, x, positions=pos,
                            causal=cfg.encoder_causal, shared=None)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"]["blocks"])
    _, norm = L.make_norm(cfg.norm)
    return norm(params["enc"]["final_norm"], x)


def forward(params, cfg: ModelConfig, batch, *, training: bool = False):
    """Returns (hidden (B,N,d), aux_loss). N includes any stub prefix."""
    _, norm = L.make_norm(cfg.norm)
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["frames"])
        x, positions = _embed_inputs(params, cfg, batch)
        pattern, n_groups, _ = _pattern_layout(cfg)

        def body(x, bp):
            cross_kv = A.project_cross_kv(bp["cross"], cfg, enc_out)
            h = x
            h, _ = _block_apply("global", bp, cfg, h, positions=positions,
                                causal=True, shared=None, cross_kv=cross_kv)
            return h, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["groups"][0])
        return norm(params["final_norm"], x), jnp.zeros((), jnp.float32)

    x, positions = _embed_inputs(params, cfg, batch)
    x, aux = _run_blocks(params, cfg, x, positions, causal=cfg.causal)
    return norm(params["final_norm"], x), aux


def logits_from_hidden(params, cfg: ModelConfig, hidden):
    if cfg.tie_embeddings:
        lg = L.unembed(params["embed"], hidden)
    else:
        lg = L.dense(params["unembed"], hidden).astype(jnp.float32)
    if cfg.softcap_final:
        lg = L.softcap(lg, cfg.softcap_final)
    return lg


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token cross-entropy, chunked over the sequence so the full
    (B, N, vocab) logits tensor never materializes (decisive for
    vocab=262k × 1M tokens)."""
    hidden, aux = forward(params, cfg, batch, training=True)
    labels = batch["labels"]
    if hidden.shape[1] != labels.shape[1]:      # vlm stub prefix
        hidden = hidden[:, hidden.shape[1] - labels.shape[1]:]
    B, N, _ = hidden.shape
    chunk = cfg.logits_chunk or max(min(N, (128 * 1024 * 1024)
                                        // max(cfg.vocab, 1)), 1)
    chunk = min(chunk, N)
    while N % chunk:
        chunk -= 1
    nc = N // chunk

    def xent(h, y):
        lg = logits_from_hidden(params, cfg, h)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, y[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    if nc <= 1:
        total = xent(hidden, labels)
    else:
        hs = hidden.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
        ys = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

        def body(acc, hy):
            h, y = hy
            return acc + xent(h, y), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ys))
    return total / (B * N) + aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      cache_kind: str = "taylor", dtype=jnp.bfloat16,
                      per_slot: bool = False):
    """Cache pytree mirroring the params' group/remainder structure.

    ``per_slot=True`` builds a continuous-batching slot pool: every batch
    row ("slot") carries its own position counter (``pos``/TaylorState
    ``n`` get shape (batch,)), so sequences at different context lengths
    decode in one fixed-shape batch. Slots are populated / recycled with
    :func:`cache_scatter_slot` / :func:`cache_reset_slot`.
    """
    pattern, n_groups, rem = _pattern_layout(cfg)
    if per_slot and cfg.family == "encdec":
        raise NotImplementedError("per-slot pools: decoder-only families")
    if cfg.family == "encdec":
        blk = A.init_cache(cfg, batch, kind="global", cache_len=cache_len,
                           cache_kind=cache_kind, dtype=dtype)
        self_caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)), {"self": blk})
        d = cfg.dim_head
        cross = T.TaylorState(
            s2=jnp.zeros((n_groups, batch, cfg.kv_heads, 1, d * d, d + 1),
                         jnp.float32),
            s1=jnp.zeros((n_groups, batch, cfg.kv_heads, 1, d, d + 1),
                         jnp.float32),
            s0=jnp.zeros((n_groups, batch, cfg.kv_heads, 1, 1, d + 1),
                         jnp.float32),
            n=jnp.zeros((n_groups,), jnp.int32),
        )
        return {"groups": [self_caches], "rem": [], "cross": cross,
                "pos": jnp.zeros((), jnp.int32)}

    def stack(kind):
        one = _cache_kind_for(kind, cfg, cache_kind, batch, cache_len, dtype,
                              per_slot)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)).copy(), one)

    groups = [stack(kind) for kind in pattern] if n_groups else []
    remc = [_cache_kind_for(kind, cfg, cache_kind, batch, cache_len, dtype,
                            per_slot)
            for kind in rem]
    pos = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return {"groups": groups, "rem": remc, "pos": pos}


def encode_for_decode(params, cfg: ModelConfig, frames, cache):
    """encdec: run the encoder once, fold K/V into per-layer Taylor states."""
    enc_out = _encode(params, cfg, frames)

    def per_layer(bp):
        k, v = A.project_cross_kv(bp["cross"], cfg, enc_out)
        return T.taylor_encode_state(k[:, :, None], v[:, :, None],
                                     normalize_inputs=cfg.taylor.normalize_inputs)

    cross = jax.vmap(per_layer)(params["groups"][0])
    return {**cache, "cross": cross}


def decode_step(params, cfg: ModelConfig, batch, cache):
    """One token for every sequence in the batch.

    batch: {"tokens": (B, 1)}. Returns (logits (B,1,V), new cache).
    """
    _, norm = L.make_norm(cfg.norm)
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens) * jnp.asarray(
        jnp.sqrt(cfg.d_model), cfg.param_dtype)
    if cfg.pos_embed == "learned":
        p = cache["pos"]
        x = L.add_learned_pos(params["pos"], x,
                              p[None] if p.ndim == 0 else p[:, None])
    pattern, n_groups, rem = _pattern_layout(cfg)
    shared = params.get("shared_attn")
    is_encdec = cfg.family == "encdec"
    eff_pattern = ("global",) if is_encdec else pattern

    new_groups = []
    if n_groups:
        if is_encdec:
            def body(x, sliced):
                bp, bc, cs = sliced
                x, nc = _block_decode("global", bp, cfg, x, bc, shared=None,
                                      cross_state=cs)
                return x, (nc,)

            x, (ncache,) = jax.lax.scan(
                body, x,
                (params["groups"][0], cache["groups"][0], cache["cross"]))
            new_groups.append(ncache)
        else:
            # One scan over groups; the body applies every pattern position
            # in order so the layer interleaving matches forward().
            def body(x, sliced):
                new_caches = []
                for kind, bp, bc in zip(eff_pattern, sliced[0], sliced[1]):
                    x, nc = _block_decode(kind, bp, cfg, x, bc, shared=shared)
                    new_caches.append(nc)
                return x, tuple(new_caches)

            x, ncaches = jax.lax.scan(
                body, x, (tuple(params["groups"]), tuple(cache["groups"])))
            new_groups = list(ncaches)

    new_rem = []
    for kind, bp, bc in zip(rem, params["rem"], cache["rem"]):
        x, nc = _block_decode(kind, bp, cfg, x, bc, shared=shared)
        new_rem.append(nc)

    x = norm(params["final_norm"], x)
    lg = logits_from_hidden(params, cfg, x)
    out = {"groups": new_groups, "rem": new_rem, "pos": cache["pos"] + 1}
    if is_encdec:
        out["cross"] = cache["cross"]
    return lg, out


# ---------------------------------------------------------------------------
# Chunked prefill — the serving prefill path (repro.serve)
# ---------------------------------------------------------------------------

def _chunk_apply(params, cfg: ModelConfig, batch, cache, block_fn,
                 what: str):
    """Shared teacher-forced forward over a (B, C) token block that
    consumes and returns a decode cache — the body of both
    :func:`prefill_chunk` and :func:`verify_chunk` (they differ only in
    which attention site each block runs). Position counters may be
    scalar (per-sequence) or per-slot (B,)."""
    if cfg.family == "encdec":
        raise NotImplementedError(f"{what}: decoder families only")
    _, norm = L.make_norm(cfg.norm)
    tokens = batch["tokens"]
    C = tokens.shape[1]
    x = L.embed(params["embed"], tokens) * jnp.asarray(
        jnp.sqrt(cfg.d_model), cfg.param_dtype)
    if cfg.pos_embed == "learned":
        p = cache["pos"]
        step = jnp.arange(C)
        x = L.add_learned_pos(params["pos"], x,
                              p + step if p.ndim == 0 else p[:, None] + step)
    pattern, n_groups, rem = _pattern_layout(cfg)

    new_groups = []
    if n_groups:
        def body(x, sliced):
            new_caches = []
            for kind, bp, bc in zip(pattern, sliced[0], sliced[1]):
                x, nc = block_fn(kind, bp, cfg, x, bc)
                new_caches.append(nc)
            return x, tuple(new_caches)

        x, ncaches = jax.lax.scan(
            body, x, (tuple(params["groups"]), tuple(cache["groups"])))
        new_groups = list(ncaches)

    new_rem = []
    for kind, bp, bc in zip(rem, params["rem"], cache["rem"]):
        x, nc = block_fn(kind, bp, cfg, x, bc)
        new_rem.append(nc)

    x = norm(params["final_norm"], x)
    lg = logits_from_hidden(params, cfg, x)
    return lg, {"groups": new_groups, "rem": new_rem,
                "pos": cache["pos"] + C}


def prefill_chunk(params, cfg: ModelConfig, batch, cache):
    """Teacher-forced forward over a (B, C) prompt chunk that consumes
    and returns the decode cache — the state-handoff path that replaces
    looping :func:`decode_step` over prompt tokens.

    Each attention layer runs ``causal_taylorshift(initial_state=...,
    return_state=True)`` (or a masked cache attend for kv caches), so a
    prompt is absorbed chunk by chunk at full-sequence arithmetic
    intensity and the final state drops straight into the recurrent
    decode step. Cache must carry a scalar position (per-sequence
    prefill — ``attn_prefill`` enforces it, and its `site="prefill"`
    routing supports seq-parallel chunk scans); the serve engine
    scatters the result into its slot pool.

    Returns (logits (B, C, vocab), new_cache).
    """
    return _chunk_apply(params, cfg, batch, cache, _block_prefill,
                        "chunked prefill")


def prefill_from_state(params, cfg: ModelConfig, batch, cache):
    """Absorb a (B, C) token block into a decode cache seeded from a
    cached prefix snapshot — the shared-prefix serving entry
    (serve/prefix_cache.py), dispatching on the counter layout and
    thereby generalizing the per-slot verify path (:func:`verify_chunk`).

    A *scalar*-counter cache — a private resumed prefill, i.e. a
    ``PrefixCache`` entry taken as the initial state — runs the exact
    :func:`prefill_chunk` body, so a resumed stream computes the same
    float ops in the same order as a cold prefill over the same chunk
    plan: bit-identical logits and tokens. A *per-slot* ``(B,)``-counter
    cache — a cold pool slot seeded straight from a snapshot via
    :func:`cache_scatter_slot` then gathered, or the whole pool at once
    — runs the verify body, each row absorbing from its own position;
    this is the entry batched cross-slot prefix prefill builds on.

    Returns (logits (B, C, vocab), new_cache).
    """
    scalar = cache["pos"].ndim == 0
    return _chunk_apply(params, cfg, batch, cache,
                        _block_prefill if scalar else _block_verify,
                        "prefill-from-state")


# ---------------------------------------------------------------------------
# Speculative verify — score k drafted tokens per slot (repro.spec)
# ---------------------------------------------------------------------------

def verify_chunk(params, cfg: ModelConfig, batch, cache):
    """Teacher-forced forward over a (B, C) token block that consumes and
    returns a *per-slot* decode cache — the speculative-verification path
    (src/repro/spec/).

    Where :func:`prefill_chunk` continues one sequence (scalar position),
    verify continues every slot of a continuous-batching pool at once:
    B = slots, C = speculate_k + 1, and ``cache["pos"]`` / TaylorState
    ``n`` are (B,) so each row attends from its own context length. The
    same function also serves the rollback re-absorb on a gathered
    batch-1 slot. Causality holds within the block, so ``logits[:, i]``
    is exactly the next-token distribution after absorbing tokens
    ``[0..i]`` — what greedy verification compares drafts against.

    Returns (logits (B, C, vocab), new_cache) with every slot advanced
    by C tokens; the caller snapshots/restores slots whose drafts are
    rejected (serve/pool.py: ``StatePool.snapshot/restore``).
    """
    return _chunk_apply(params, cfg, batch, cache, _block_verify,
                        "speculative verify")


def verify_rollback(params, cfg: ModelConfig, cache, snap, slot, batch):
    """Fused rejected-draft rollback: restore ``slot`` from the
    pre-verify pool snapshot ``snap`` and advance it by the accepted
    prefix ``batch["tokens"]`` (1, a+1), all in one traceable call —
    gather-from-snapshot, :func:`verify_chunk` re-absorb, scatter into
    ``cache``. ``slot`` may be traced (no retrace per slot); only the
    accepted-prefix length changes the shape (≤ speculate_k variants).

    ``snap`` is simply a reference to the pool pytree from before the
    verify call — jax arrays are immutable, so holding the old cache IS
    a bit-exact snapshot of every slot at zero copy cost.
    """
    sub = cache_gather_slot(snap, slot)
    _, sub = verify_chunk(params, cfg, batch, sub)
    return cache_scatter_slot(cache, sub, slot)


# ---------------------------------------------------------------------------
# Slot-indexed cache pools (continuous batching, repro.serve)
# ---------------------------------------------------------------------------
#
# A pool is an ``init_decode_state(..., per_slot=True)`` cache over
# ``slots`` batch rows. Group-stacked leaves carry layers on axis 0 and
# the slot on axis 1; remainder leaves and the position counters carry
# the slot on axis 0. Counter leaves (``pos``, TaylorState ``n``) have
# one fewer dim in a per-sequence cache than in the pool — the update
# helpers expand them on the slot axis.

def _slot_tree_update(pool_leaf, src_leaf, slot, axis: int):
    if src_leaf.ndim < pool_leaf.ndim:          # scalar counters
        src_leaf = jnp.expand_dims(src_leaf, axis)
    return jax.lax.dynamic_update_slice_in_dim(
        pool_leaf, src_leaf.astype(pool_leaf.dtype), slot, axis)


def cache_gather_slot(cache, slot):
    """Slice one slot out of a pool (slot dims kept, size 1)."""
    g = lambda axis: lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis)
    return {
        "groups": [jax.tree.map(g(1), gr) for gr in cache["groups"]],
        "rem": [jax.tree.map(g(0), r) for r in cache["rem"]],
        "pos": jax.lax.dynamic_slice_in_dim(cache["pos"], slot, 1, 0),
    }


def cache_scatter_slot(cache, src, slot):
    """Write a single-sequence cache (batch=1, scalar or size-1 counters
    — e.g. a finished :func:`prefill_chunk` state) into pool slot
    ``slot``. Overwrites every leaf of the slot, so a recycled slot
    carries no trace of its previous occupant."""
    u = lambda axis: (lambda p, s: _slot_tree_update(p, s, slot, axis))
    return {
        "groups": [jax.tree.map(u(1), gr, sr)
                   for gr, sr in zip(cache["groups"], src["groups"])],
        "rem": [jax.tree.map(u(0), r, s)
                for r, s in zip(cache["rem"], src["rem"])],
        "pos": _slot_tree_update(cache["pos"], src["pos"], slot, 0),
    }


def cache_reset_slot(cache, slot):
    """Zero every leaf of one slot (sequence released)."""
    sub = cache_gather_slot(cache, slot)
    return cache_scatter_slot(cache, jax.tree.map(jnp.zeros_like, sub), slot)


def cache_merge_slots(mask, new, old):
    """Per-slot select between two pool caches: slot i takes ``new``
    where ``mask[i]`` and keeps ``old`` otherwise — the write-back of a
    batched pool-level prefill, protecting decoding slots whose rows
    computed on throwaway tokens. ``mask``: (slots,) bool."""
    def sel(axis):
        def f(n, o):
            m = mask.reshape((1,) * axis + (-1,)
                             + (1,) * (n.ndim - axis - 1))
            return jnp.where(m, n, o)
        return f
    return {
        "groups": [jax.tree.map(sel(1), gn, go)
                   for gn, go in zip(new["groups"], old["groups"])],
        "rem": [jax.tree.map(sel(0), rn, ro)
                for rn, ro in zip(new["rem"], old["rem"])],
        "pos": jnp.where(mask, new["pos"], old["pos"]),
    }


def _map_counters(tree, fn):
    """Apply ``fn`` to every position-counter leaf of a decode cache:
    ``pos`` dict entries (the top-level counter and each kv layer's) and
    TaylorState ``n``. Non-counter leaves pass through untouched."""
    if isinstance(tree, T.TaylorState):
        return tree._replace(n=fn(tree.n))
    if isinstance(tree, dict):
        return {k: (fn(v) if k == "pos" else _map_counters(v, fn))
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_map_counters(x, fn) for x in tree)
    return tree


def cache_slot_to_sequence(sub):
    """Normalize a :func:`cache_gather_slot` result (size-1 slot dims,
    per-slot counters) to the canonical single-sequence layout a private
    :func:`prefill_chunk` produces — scalar/(layers,) counters. Needed
    when a pool-resident prefill boundary becomes a prefix-cache entry:
    entries must be layout-identical whichever path built them, so a
    later hit resumes through the scalar-counter (bit-exact prefill)
    body."""
    return _map_counters(sub, lambda a: jnp.squeeze(a, -1))


def cache_truncate(cache, n_tokens: int):
    """Clamp every position counter of a kv decode cache to
    ``n_tokens`` — the partial-prefix reuse primitive. kv rows are
    positionally addressed and the cache attends with an exact-zero
    mask at ``index >= pos``, so rows beyond the clamped counter are
    unobservable: resuming prefill from the truncated cache is
    bit-identical to a cold prefill of the matching ``n_tokens``-token
    prefix. Taylor states are running sums, not positional rows — they
    cannot be truncated (callers gate on ``cache_kind == "kv"``;
    TaylorState leaves here raise)."""
    for leaf in jax.tree.leaves(cache, is_leaf=lambda x: isinstance(
            x, T.TaylorState)):
        if isinstance(leaf, T.TaylorState):
            raise ValueError("cache_truncate: Taylor states are prefix "
                             "sums, not positional rows — kv caches only")
    return _map_counters(cache, lambda a: jnp.minimum(a, n_tokens))


def prefill_slots(params, cfg: ModelConfig, batch, cache, slot_mask):
    """Batched pool-level prefill: absorb a (slots, C) token block
    directly into the slot pool, advancing only the slots ``slot_mask``
    selects. One dispatch covers every same-chunk-length prefilling
    sequence; unselected slots (decoding, free) compute on throwaway
    tokens and are restored bit-exactly by :func:`cache_merge_slots` —
    the same fixed-shape discipline as the batched decode step.

    The per-slot-counter body this runs (:func:`verify_chunk`'s) is
    bit-identical to the scalar prefill body for Taylor caches — rows
    are computationally independent, so batching cannot change a row's
    float ops — which is what keeps pooled prefill streams equal to
    per-sequence ones token for token. (kv caches attend over a
    different extent per body and are NOT bit-identical across the two;
    the engine keeps them on the per-sequence path.)

    Returns (logits (slots, C, vocab), merged pool cache).
    """
    logits, new = prefill_from_state(params, cfg, batch, cache)
    return logits, cache_merge_slots(slot_mask, new, cache)


# ---------------------------------------------------------------------------
# Analytic parameter counts (for MODEL_FLOPS = 6·N·D)
# ---------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params)
               if hasattr(x, "size"))


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact count via eval_shape (no allocation); MoE optionally counted
    at top_k/n_experts activation."""
    shapes = jax.eval_shape(
        functools.partial(init_params, cfg),
        jax.random.PRNGKey(0))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        keys = "/".join(str(p) for p in path)
        if active_only and ("w_up" in keys or "w_gate" in keys
                            or "w_down" in keys):
            n = n * cfg.moe.top_k // max(cfg.moe.n_experts, 1)
        total += n
    return total


def count_embedding_params(cfg: ModelConfig) -> int:
    n = cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n *= 2
    return n
