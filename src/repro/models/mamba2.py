"""Mamba2 (SSD) block — the SSM half of zamba2-7b.

Chunked "state-space dual" algorithm (Dao & Gu, 2024) in pure JAX:
intra-chunk quadratic term + inter-chunk recurrent state carried with a
``lax.scan`` over chunks. TaylorShift is *inapplicable* here (no
attention); the block is implemented faithfully as the substrate the
hybrid architecture needs (docs/design.md §Arch-applicability).

Decode: constant-size per-layer state — causal-conv tail (width-1 window)
plus the SSM state h ∈ (B, H, P, S).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = dict[str, Any]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expansion * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.state, s.n_groups


def mamba2_init(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d_inner, H, P, S, G = _dims(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 5)
    # in_proj packs [z (gate), x, B, C, dt] like the reference impl.
    d_in_proj = 2 * d_inner + 2 * G * S + H
    p: Params = {
        "in_proj": L.dense_init(ks[0], cfg.d_model, d_in_proj, dt),
        "out_proj": L.dense_init(ks[1], d_inner, cfg.d_model, dt),
        "conv_w": (jax.random.normal(ks[2], (s.conv_width, d_inner + 2 * G * S),
                                     jnp.float32) * 0.1).astype(dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": L.rmsnorm_init(d_inner),
    }
    return p


def _split_in_proj(cfg, zxbcdt):
    d_inner, H, P, S, G = _dims(cfg)
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * G * S], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(w, x):
    """Depthwise causal conv, width W. x: (B, N, C); w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i][None, None] for i in range(W))
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk):
    """SSD core. xh: (B,N,H,P), dt: (B,N,H), A: (H,), Bm/Cm: (B,N,G,S).

    Returns y: (B,N,H,P). G divides H (heads share B/C within a group).
    """
    b, n, h, p = xh.shape
    g = Bm.shape[2]
    assert n % chunk == 0
    nc = n // chunk
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2)                    # (B,N,H,S)
    Ch = jnp.repeat(Cm, rep, axis=2)

    # discretized log-decay per step: a_t = -A * dt_t  (A > 0)
    loga = (-A[None, None] * dt).astype(jnp.float32)    # (B,N,H) (<= 0)
    xdt = (xh * dt[..., None]).astype(jnp.float32)      # input scaled by dt

    def r(t, shape):  # reshape into chunks
        return t.reshape(b, nc, chunk, *shape)

    loga_c = r(loga, (h,))
    x_c = r(xdt, (h, p))
    B_c = r(Bh, (h, Bh.shape[-1]))
    C_c = r(Ch, (h, Ch.shape[-1]))

    cs = jnp.cumsum(loga_c, axis=2)                      # (B,nc,C,H)
    # --- intra-chunk (quadratic within chunk) ---
    # L[i,j] = exp(cs_i - cs_j + loga_j)   for i >= j  … standard SSD form:
    # decay from j..i inclusive of step j's own a? Convention: h_t = a_t h_{t-1} + B_t x_t
    # => y_i gets B_j x_j decayed by prod_{t=j+1..i} a_t = exp(cs_i - cs_j).
    scores = jnp.einsum("bzihs,bzjhs->bzhij", C_c, B_c)
    ci = cs.transpose(0, 1, 3, 2)                        # (B,nc,H,C)
    expo = ci[..., :, None] - ci[..., None, :]           # [i,j] = cs_i - cs_j
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.exp(jnp.where(mask[None, None, None], expo, -jnp.inf))
    y_intra = jnp.einsum("bzhij,bzhij,bzjhp->bzihp", scores, decay, x_c)

    # --- chunk states & inter-chunk scan ---
    # state contribution of chunk z: sum_j exp(cs_end - cs_j) B_j ⊗ x_j
    end = cs[:, :, -1:, :]                               # (B,nc,1,H)
    w = jnp.exp(end - cs)                                # (B,nc,C,H)
    states = jnp.einsum("bzjh,bzjhs,bzjhp->bzhsp", w, B_c, x_c)
    chunk_decay = jnp.exp(end[:, :, 0])                  # (B,nc,H)

    def scan_fn(hprev, inp):
        st, dec = inp                                    # (B,H,S,P), (B,H)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, Bh.shape[-1], p), jnp.float32)
    _, h_prefix = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prefix = h_prefix.transpose(1, 0, 2, 3, 4)         # (B,nc,H,S,P) excl.

    # y_inter[i] = C_i · (exp(cs_i) * h_prefix)
    y_inter = jnp.einsum("bzihs,bzih,bzhsp->bzihp",
                         C_c, jnp.exp(cs), h_prefix)
    y = (y_intra + y_inter).reshape(b, n, h, p)
    return y


def mamba2_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray
                 ) -> jnp.ndarray:
    """x: (B, N, d_model) -> (B, N, d_model)."""
    s = cfg.ssm
    d_inner, H, P, S, G = _dims(cfg)
    zxbcdt = L.dense(params["in_proj"], x)
    z, xbc, dt_raw = _split_in_proj(cfg, zxbcdt)
    xbc = _causal_conv(params["conv_w"], xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * S], axis=-1)
    b, n, _ = x.shape
    xh = xs.reshape(b, n, H, P)
    Bm = Bm.reshape(b, n, G, S)
    Cm = Cm.reshape(b, n, G, S)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])
    A = jnp.exp(params["A_log"])
    chunk = min(s.chunk, n)
    while n % chunk:
        chunk //= 2
    y = _ssd_chunked(xh, dt, A, Bm, Cm, max(chunk, 1))
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, n, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return L.dense(params["out_proj"], y)


# ---------------------------------------------------------------------------
# Decode (constant state)
# ---------------------------------------------------------------------------

def mamba2_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, H, P, S, G = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_inner + 2 * G * S), dtype),
        "h": jnp.zeros((batch, H, S, P), jnp.float32),
    }


def mamba2_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray, cache):
    """x: (B, 1, d_model). Returns (y, cache)."""
    s = cfg.ssm
    d_inner, H, P, S, G = _dims(cfg)
    zxbcdt = L.dense(params["in_proj"], x)
    z, xbc, dt_raw = _split_in_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"]
    W = w.shape[0]
    out = jnp.sum(conv_in[:, -W:] * w[None], axis=1, keepdims=True)
    xbc = jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)
    new_conv = conv_in[:, 1:]

    xs, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + G * S], axis=-1)
    b = x.shape[0]
    xh = xs.reshape(b, H, P)
    Bm = jnp.repeat(Bm.reshape(b, G, S), H // G, axis=1)
    Cm = jnp.repeat(Cm.reshape(b, G, S), H // G, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = jnp.exp(params["A_log"])
    dec = jnp.exp(-A[None] * dt)                          # (B,H)
    hnew = (cache["h"] * dec[..., None, None]
            + jnp.einsum("bhs,bhp,bh->bhsp", Bm.astype(jnp.float32),
                         xh.astype(jnp.float32), dt))
    y = jnp.einsum("bhs,bhsp->bhp", Cm.astype(jnp.float32), hnew)
    y = y + params["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return L.dense(params["out_proj"], y), {"conv": new_conv, "h": hnew}
