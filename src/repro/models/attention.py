"""Multi-head attention with TaylorShift / softmax backends.

The paper's technique is integrated as a first-class backend: every
attention site (global causal, global non-causal, sliding-window local,
cross-attention, and single-token decode) has a TaylorShift form. *Which*
form runs — direct/efficient crossover, fused Pallas kernels,
chunked-causal scan (sequential or sequence-parallel), fused decode — is
resolved by ``models/backend.py:select_backend``; this module only
implements the sites and dispatches on the returned Selection.

Caches for decode:
  * ``kv``     — classic KV cache (softmax or direct-Taylor readout)
  * ``taylor`` — constant-size TaylorState (efficient-Taylor readout);
                 this is what makes ``long_500k`` feasible for
                 full-attention architectures.
  * local layers always use a bounded ring-buffer window cache.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import taylor as T
from repro.distributed import ctx
from repro.models import backend as B
from repro.models import layers as L

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig) -> Params:
    dh, H, KV = cfg.dim_head, cfg.n_heads, cfg.kv_heads
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p: Params = {
        "wq": L.dense_init(ks[0], cfg.d_model, H * dh, dt),
        "wk": L.dense_init(ks[1], cfg.d_model, KV * dh, dt),
        "wv": L.dense_init(ks[2], cfg.d_model, KV * dh, dt),
        "wo": L.dense_init(ks[3], H * dh, cfg.d_model, dt),
    }
    if cfg.attn_backend == "taylor":
        p["tau"] = jnp.full((H,), cfg.taylor.tau_init, jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(dh)
        p["k_norm"] = L.rmsnorm_init(dh)
    return p


def _split_heads(x, n_heads, dh):
    b, n, _ = x.shape
    return x.reshape(b, n, n_heads, dh).transpose(0, 2, 1, 3)  # (B,H,N,dh)


def _merge_heads(x):
    b, h, n, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dh)


def _project_qkv(params, cfg: ModelConfig, x, positions, *, rope=True):
    dh, H, KV = cfg.dim_head, cfg.n_heads, cfg.kv_heads
    q = _split_heads(L.dense(params["wq"], x), H, dh)
    k = _split_heads(L.dense(params["wk"], x), KV, dh)
    v = _split_heads(L.dense(params["wv"], x), KV, dh)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q)
        k = L.rmsnorm(params["k_norm"], k)
    if rope and cfg.pos_embed == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _group_q(q, KV):
    """(B,H,N,d) -> (B,KV,G,N,d) so Taylor states are per-kv-head."""
    b, h, n, d = q.shape
    return q.reshape(b, KV, h // KV, n, d)


def _tau(params, cfg: ModelConfig, grouped: bool):
    tau = params["tau"].astype(jnp.float32)
    if grouped:
        return tau.reshape(1, cfg.kv_heads, cfg.n_heads // cfg.kv_heads, 1, 1)
    return tau.reshape(1, cfg.n_heads, 1, 1)


# ---------------------------------------------------------------------------
# Full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def _softmax_attention(cfg, q, k, v, *, causal, window=0):
    """Vanilla baseline (the paper's comparison target). GQA by repeat."""
    b, h, n, d = q.shape
    kv = k.shape[1]
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    x = jnp.einsum("bhnd,bhmd->bhnm", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    if cfg.softcap_attn:
        x = L.softcap(x, cfg.softcap_attn)
    m = k.shape[2]
    if causal:
        mask = jnp.tril(jnp.ones((n, m), bool), m - n)
        if window:
            mask &= jnp.triu(jnp.ones((n, m), bool), m - n - window + 1)
        x = jnp.where(mask, x, -1e30)
    a = jax.nn.softmax(x, axis=-1)
    y = jnp.einsum("bhnm,bhmd->bhnd", a.astype(v.dtype), v)
    return y


def _repeat_kv(cfg: ModelConfig, k, v):
    rep = cfg.n_heads // cfg.kv_heads
    return jnp.repeat(k, rep, axis=1), jnp.repeat(v, rep, axis=1)


def _causal_scan_opts(sel: B.Selection) -> dict:
    """causal_taylorshift kwargs implementing a causal-scan Selection:
    which chunk-scan core runs and (for the sequential core) the
    mesh-aware state sharder."""
    if sel.scan == "seq-parallel":
        from repro.distributed import seqscan
        c = ctx.get()
        return {"chunk": sel.chunk,
                "scan_fn": seqscan.make_seq_scan(c.mesh, axis=c.seq_axis)}
    if sel.scan == "parallel":
        return {"chunk": sel.chunk, "scan_impl": "parallel"}
    c = ctx.get()
    sharder = None
    if c.enabled:
        dpspec = c.dp_spec
        sharder = lambda s2: ctx.constrain(
            s2, dpspec, None, *([None] * (s2.ndim - 4)), "model", None)
    return {"chunk": sel.chunk, "state_sharder": sharder}


def _taylor_global(cfg: ModelConfig, params, q, k, v, *, causal):
    """Full-sequence TaylorShift: resolve the path through
    models/backend.py:select_backend and dispatch on the Selection —
    all routing heuristics (crossovers, mesh gates, kernel gates, GQA
    constraints) live in the backend module."""
    tc = cfg.taylor
    N, d = q.shape[-2], q.shape[-1]
    sel = B.select_backend(cfg, N=N, d=d, site="full", causal=causal)
    if sel.repeat_kv:
        k, v = _repeat_kv(cfg, k, v)
    if sel.backend.caps.kernel:
        from repro.kernels import ops as K
        return K.taylor_attention_kernel(
            q, k, v, tau=_tau(params, cfg, False), causal=causal,
            mode=sel.mode, out_scale=tc.output_scale)
    if sel.name == "direct":
        return T.direct_taylorshift(
            q, k, v, tau=_tau(params, cfg, False), causal=causal,
            normalize_inputs=tc.normalize_inputs,
            output_scale=tc.output_scale)
    qg = _group_q(q, cfg.kv_heads)
    kg, vg = k[:, :, None], v[:, :, None]
    tau = _tau(params, cfg, True)
    if sel.name == "causal-scan":
        y = T.causal_taylorshift(
            qg, kg, vg, tau=tau,
            normalize_inputs=tc.normalize_inputs,
            output_scale=tc.output_scale, **_causal_scan_opts(sel))
    else:
        y = T.efficient_taylorshift(
            qg, kg, vg, tau=tau,
            normalize_inputs=tc.normalize_inputs,
            output_scale=tc.output_scale)
    return y.reshape(q.shape)


def _local_taylor(cfg: ModelConfig, params, q, k, v):
    """Causal sliding-window attention, blocked so cost is O(N·w).

    Window w sits far below the paper's N0 crossover, so the *direct*
    Taylor form is the paper-optimal choice here ("and Back").
    Query block i attends key blocks i-1 and i with a banded mask.
    """
    w = cfg.window
    b, h, n, d = q.shape
    kv = k.shape[1]
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if n <= w or n % w:
        # Small or ragged sequences: banded direct form (O(N²), only hit
        # far below the crossover / in tests).
        qpos = jnp.arange(n)[:, None]
        kpos = jnp.arange(n)[None, :]
        band = (kpos <= qpos) & (kpos > qpos - w)
        y = T.direct_taylorshift(
            q, k, v, tau=_tau(params, cfg, False), causal=False, mask=band,
            normalize_inputs=cfg.taylor.normalize_inputs, output_scale=False)
        if cfg.taylor.output_scale:
            counts = jnp.minimum(jnp.arange(1, n + 1), w).astype(jnp.float32)
            y = y * jnp.sqrt(counts / d)[None, None, :, None]
        return y
    nb = n // w
    tau = _tau(params, cfg, False)
    tc = cfg.taylor
    if tc.normalize_inputs:
        q, k = T.normalize_qk(q, k, tau)
    qb = q.reshape(b, h, nb, w, d)
    kb = k.reshape(b, h, nb, w, d)
    vb = v.reshape(b, h, nb, w, d)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :, :1]), kb[:, :, :-1]], 2)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :, :1]), vb[:, :, :-1]], 2)
    kk = jnp.concatenate([k_prev, kb], axis=3)           # (B,H,nb,2w,d)
    vv = jnp.concatenate([v_prev, vb], axis=3)
    x = jnp.einsum("bhgqd,bhgkd->bhgqk", qb, kk,
                   preferred_element_type=jnp.float32)
    a = T.taylor_exp(x)
    qpos = jnp.arange(w)[:, None]
    kpos = jnp.arange(2 * w)[None, :] - w
    band = (kpos <= qpos) & (kpos > qpos - w)            # exactly w keys
    first_blk = jnp.arange(nb) == 0
    valid = jnp.where(first_blk[:, None, None],
                      (kpos >= 0) & (kpos <= qpos), band[None, :, :])
    a = jnp.where(valid[None, None], a, 0.0)
    denom = jnp.sum(a, axis=-1, keepdims=True)
    y = jnp.einsum("bhgqk,bhgkd->bhgqd", a / denom, vv.astype(a.dtype))
    if tc.output_scale:
        counts = jnp.where(first_blk[:, None], qpos.T + 1, w).astype(jnp.float32)
        y = y * jnp.sqrt(counts / d)[None, None, :, :, None]
    return y.reshape(b, h, n, d).astype(v.dtype)


def attn_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
               positions: jnp.ndarray, kind: str = "global",
               causal: bool = True,
               cross_kv: tuple[jnp.ndarray, jnp.ndarray] | None = None
               ) -> jnp.ndarray:
    """Full-sequence attention. x: (B, N, d_model)."""
    q, k, v = _project_qkv(params, cfg, x, positions,
                           rope=(cross_kv is None))
    if cross_kv is not None:
        k, v = cross_kv  # (B, KV, M, dh) — already projected by the encoder side
        causal = False
    if cfg.attn_backend == "softmax":
        y = _softmax_attention(cfg, q, k, v, causal=causal,
                               window=cfg.window if kind == "local" else 0)
    elif kind == "local" and causal:
        y = _local_taylor(cfg, params, q, k, v)
    else:
        y = _taylor_global(cfg, params, q, k, v, causal=causal)
    return L.dense(params["wo"], _merge_heads(y).astype(x.dtype))


def project_cross_kv(params: Params, cfg: ModelConfig,
                     enc_out: jnp.ndarray):
    """Project encoder outputs to (K, V) once for all decoder steps."""
    dh, KV = cfg.dim_head, cfg.kv_heads
    k = _split_heads(L.dense(params["wk"], enc_out), KV, dh)
    v = _split_heads(L.dense(params["wv"], enc_out), KV, dh)
    if cfg.qk_norm:
        k = L.rmsnorm(params["k_norm"], k)
    return k, v


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, *, kind: str, cache_len: int,
               cache_kind: str = "taylor", dtype=jnp.bfloat16,
               per_slot: bool = False):
    """Cache pytree for one attention layer.

    ``per_slot=True`` gives every batch row its own position counter
    (shape (batch,) instead of scalar) so rows can sit at different
    context lengths — the layout the continuous-batching slot pool in
    ``repro.serve`` decodes over.
    """
    dh, KV = cfg.dim_head, cfg.kv_heads
    n_dims = (batch,) if per_slot else ()
    if kind == "local":
        w = cfg.window
        return {
            "k": jnp.zeros((batch, KV, w, dh), dtype),
            "v": jnp.zeros((batch, KV, w, dh), dtype),
            "pos": jnp.zeros(n_dims, jnp.int32),
        }
    if cache_kind == "taylor":
        return T.TaylorState.zeros((batch, KV, 1), dh, n_dims=n_dims)
    return {
        "k": jnp.zeros((batch, KV, cache_len, dh), dtype),
        "v": jnp.zeros((batch, KV, cache_len, dh), dtype),
        "pos": jnp.zeros(n_dims, jnp.int32),
    }


def _decode_positions(pos: jnp.ndarray) -> jnp.ndarray:
    """Rope-broadcastable positions for a one-token step: scalar shared
    position -> (1,); per-slot (B,) -> (B, 1, 1) so the angle table
    broadcasts over heads."""
    return pos[None] if pos.ndim == 0 else pos[:, None, None]


def attn_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray, cache,
                *, kind: str = "global",
                cross_state: T.TaylorState | None = None):
    """One-token decode. x: (B, 1, d_model). Returns (y, new_cache)."""
    if cross_state is not None:
        # cross-attention readout from the frozen encoder Taylor state
        dh, H, KV = cfg.dim_head, cfg.n_heads, cfg.kv_heads
        q = _split_heads(L.dense(params["wq"], x), H, dh)
        if cfg.qk_norm:
            q = L.rmsnorm(params["q_norm"], q)
        qg = _group_q(q, KV)
        y = T.taylor_readout(cross_state, qg, tau=_tau(params, cfg, True),
                             normalize_inputs=cfg.taylor.normalize_inputs,
                             output_scale=cfg.taylor.output_scale)
        y = y.reshape(q.shape).astype(x.dtype)
        return L.dense(params["wo"], _merge_heads(y)), cache

    is_taylor_state = isinstance(cache, T.TaylorState)
    pos = cache.n if is_taylor_state else cache["pos"]
    q, k, v = _project_qkv(params, cfg, x, _decode_positions(pos))

    sel = B.select_backend(cfg, N=1, d=cfg.dim_head, site="decode",
                           cache_kind="taylor" if is_taylor_state else "kv")
    if is_taylor_state:
        if sel.name == "fused-decode":
            y, cache = _fused_taylor_decode(params, cfg, cache, q, k, v)
        else:
            # causal-scan's one-token limit: the recurrent decode step
            # (grouped per-kv-head states — the GQA layout fused-decode's
            # flat (B·H) kernel can't serve; see its capability flags)
            qg = _group_q(q, cfg.kv_heads)
            kg, vg = k[:, :, None], v[:, :, None]
            y, cache = T.taylor_decode_step(
                cache, qg, kg, vg, tau=_tau(params, cfg, True),
                normalize_inputs=cfg.taylor.normalize_inputs,
                output_scale=cfg.taylor.output_scale)
            y = y.reshape(q.shape)
    else:
        w = cache["k"].shape[2]
        slot = jnp.mod(pos, w) if kind == "local" else pos
        kc = k.astype(cache["k"].dtype)
        vc = v.astype(cache["v"].dtype)
        if pos.ndim:   # per-slot cache: every sequence writes its own index
            upd = jax.vmap(
                lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s, 1))
            ck, cv = upd(cache["k"], kc, slot), upd(cache["v"], vc, slot)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, slot, 2)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, slot, 2)
        cache = {"k": ck, "v": cv, "pos": pos + 1}
        n_valid = jnp.minimum(pos + 1, w) if kind == "local" else pos + 1
        y = _decode_attend(cfg, params, q, ck, cv, n_valid, w)
    return L.dense(params["wo"], _merge_heads(y).astype(x.dtype)), cache


def _fused_taylor_decode(params: Params, cfg: ModelConfig,
                         cache: T.TaylorState, q, k, v):
    """Route the one-token update+readout through the fused Pallas
    decode kernel (kernels/taylor_decode.py). MHA only (H == KV): the
    kernel works on flattened (B·H, ...) states with no GQA grouping."""
    from repro.kernels.taylor_decode import taylor_decode_kernel

    B, H, _, dh = q.shape
    interp = jax.default_backend() != "tpu"
    flat3 = lambda t: t.reshape(B * H, *t.shape[3:])   # (B,H,1,X,Y)->(BH,X,Y)
    n_flat = cache.n if cache.n.ndim == 0 else jnp.repeat(cache.n, H)
    st = T.TaylorState(s2=flat3(cache.s2), s1=flat3(cache.s1),
                       s0=flat3(cache.s0), n=n_flat)
    tau = jnp.tile(params["tau"].astype(jnp.float32).reshape(H, 1, 1),
                   (B, 1, 1))
    yf, stn = taylor_decode_kernel(
        st, q.reshape(B * H, 1, dh), k.reshape(B * H, 1, dh),
        v.reshape(B * H, 1, dh), tau=tau,
        normalize_inputs=cfg.taylor.normalize_inputs,
        output_scale=cfg.taylor.output_scale, interpret=interp)
    unflat = lambda t: t.reshape(B, H, 1, *t.shape[1:])
    new = T.TaylorState(s2=unflat(stn.s2), s1=unflat(stn.s1),
                        s0=unflat(stn.s0), n=cache.n + 1)
    return yf.reshape(B, H, 1, dh), new


def attn_prefill(params: Params, cfg: ModelConfig, x: jnp.ndarray, cache,
                 *, kind: str = "global"):
    """Chunked-prefill attention with state handoff.

    Attends causally over (cached context + this chunk) and absorbs the
    chunk into the cache in one shot — the multi-token replacement for
    looping :func:`attn_decode` over prompt tokens. For a TaylorState
    cache this drives ``core.taylor.causal_taylorshift(initial_state=...,
    return_state=True)``; the resulting state is then consumed by the
    decode path (``taylor_decode_step`` / the fused decode kernel).

    x: (B, C, d_model); cache: TaylorState or kv dict with a *scalar*
    position counter (prefill is per-sequence — the serve engine scatters
    the finished state into its slot pool). Returns (y, new_cache).
    """
    if kind != "global":
        raise NotImplementedError(
            "chunked prefill supports global attention only "
            f"(got kind={kind!r}); local ring-buffer windows would need "
            "windowed chunk logic")
    is_taylor_state = isinstance(cache, T.TaylorState)
    pos = cache.n if is_taylor_state else cache["pos"]
    if pos.ndim:
        raise ValueError("attn_prefill is per-sequence (scalar position); "
                         "got a per-slot cache")
    C = x.shape[1]
    positions = pos + jnp.arange(C)
    q, k, v = _project_qkv(params, cfg, x, positions)

    sel = B.select_backend(cfg, N=C, d=cfg.dim_head, site="prefill",
                           cache_kind="taylor" if is_taylor_state else "kv")
    if is_taylor_state:
        qg = _group_q(q, cfg.kv_heads)
        kg, vg = k[:, :, None], v[:, :, None]
        y, cache = T.causal_taylorshift(
            qg, kg, vg, tau=_tau(params, cfg, True),
            normalize_inputs=cfg.taylor.normalize_inputs,
            output_scale=cfg.taylor.output_scale,
            initial_state=cache, return_state=True,
            **_causal_scan_opts(sel))
        y = y.reshape(q.shape)
    else:
        cache_len = cache["k"].shape[2]
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, 2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, 2)
        cache = {"k": ck, "v": cv, "pos": pos + C}
        qpos = pos + jnp.arange(C)
        # row i sees keys at absolute index <= pos+i; unwritten cache
        # slots sit beyond pos+C-1 and are excluded by the same mask
        mask = jnp.arange(cache_len)[None, :] <= qpos[:, None]      # (C, L)
        y = _prefill_attend(cfg, params, q, ck, cv, mask, counts=qpos + 1)
    return L.dense(params["wo"], _merge_heads(y).astype(x.dtype)), cache


def attn_verify(params: Params, cfg: ModelConfig, x: jnp.ndarray, cache,
                *, kind: str = "global"):
    """Multi-token scoring + absorb from a *per-slot* decode cache — the
    speculative-verify site (src/repro/spec/, docs/design.md §4.4).

    Where :func:`attn_prefill` continues one sequence (scalar counters),
    verify continues every slot of the pool at once: x is (B, C,
    d_model) with B = slots and C = speculate_k + 1, and the cache
    carries per-slot (B,) position counters, so each row attends —
    causally within its block — from its own context length. The same
    path also serves the rollback re-absorb (a gathered batch-1 slot,
    counters (1,)). Routed through ``select_backend(site="verify")``:
    one sequential ``causal_taylorshift`` chunk for Taylor state, a
    per-slot masked direct attend for kv caches.

    Returns (y, new_cache) with every slot advanced by C tokens; the
    caller snapshots/restores slots whose drafts are rejected.
    """
    if kind != "global":
        raise NotImplementedError(
            f"speculative verify supports global attention only "
            f"(got kind={kind!r})")
    is_taylor_state = isinstance(cache, T.TaylorState)
    pos = cache.n if is_taylor_state else cache["pos"]
    C = x.shape[1]
    step = jnp.arange(C)
    # rope positions broadcast over heads: (B, 1, C) per-slot, (C,) scalar
    rpos = pos + step if pos.ndim == 0 else pos[:, None, None] + step
    q, k, v = _project_qkv(params, cfg, x, rpos)

    sel = B.select_backend(cfg, N=C, d=cfg.dim_head, site="verify",
                           cache_kind="taylor" if is_taylor_state else "kv")
    if is_taylor_state:
        qg = _group_q(q, cfg.kv_heads)
        kg, vg = k[:, :, None], v[:, :, None]
        y, cache = T.causal_taylorshift(
            qg, kg, vg, tau=_tau(params, cfg, True),
            normalize_inputs=cfg.taylor.normalize_inputs,
            output_scale=cfg.taylor.output_scale,
            initial_state=cache, return_state=True, chunk=sel.chunk)
        y = y.reshape(q.shape)
    else:
        cache_len = cache["k"].shape[2]
        kc = k.astype(cache["k"].dtype)
        vc = v.astype(cache["v"].dtype)
        if pos.ndim:   # per-slot cache: every sequence writes its own index
            upd = jax.vmap(
                lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s, 1))
            ck, cv = upd(cache["k"], kc, pos), upd(cache["v"], vc, pos)
            qpos = pos[:, None] + step                            # (B, C)
            mask = jnp.arange(cache_len)[None, None] <= qpos[:, :, None]
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kc, pos, 2)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vc, pos, 2)
            qpos = pos + step
            mask = jnp.arange(cache_len)[None] <= qpos[:, None]   # (C, L)
        cache = {"k": ck, "v": cv, "pos": pos + C}
        y = _prefill_attend(cfg, params, q, ck, cv, mask, counts=qpos + 1)
    return L.dense(params["wo"], _merge_heads(y).astype(x.dtype)), cache


def _prefill_attend(cfg, params, q, ck, cv, mask, counts):
    """Masked multi-query attention over a kv cache during chunked
    prefill / speculative verify. q: (B,H,C,d); ck/cv: (B,KV,L,d);
    mask: (C, L) shared, or (B, C, L) per-slot; counts: (C,) or (B, C)
    true per-row context lengths."""
    b, h, _, d = q.shape
    kv = ck.shape[1]
    if kv != h:
        rep = h // kv
        ck = jnp.repeat(ck, rep, axis=1)
        cv = jnp.repeat(cv, rep, axis=1)
    mask4 = mask[None, None] if mask.ndim == 2 else mask[:, None]
    if cfg.attn_backend == "softmax":
        x = jnp.einsum("bhcd,bhmd->bhcm", q, ck,
                       preferred_element_type=jnp.float32) / math.sqrt(d)
        if cfg.softcap_attn:
            x = L.softcap(x, cfg.softcap_attn)
        x = jnp.where(mask4, x, -1e30)
        a = jax.nn.softmax(x, -1)
        return jnp.einsum("bhcm,bhmd->bhcd", a.astype(cv.dtype), cv)
    tc = cfg.taylor
    tau = _tau(params, cfg, False)
    if tc.normalize_inputs:
        q, ck = T.normalize_qk(q, ck, tau)
    x = jnp.einsum("bhcd,bhmd->bhcm", q, ck,
                   preferred_element_type=jnp.float32)
    a = jnp.where(mask4, T.taylor_exp(x), 0.0)
    y = jnp.einsum("bhcm,bhmd->bhcd", a / jnp.sum(a, -1, keepdims=True),
                   cv.astype(a.dtype))
    if tc.output_scale:
        cf = counts.astype(jnp.float32)
        cf = cf[None, None, :, None] if cf.ndim == 1 else cf[:, None, :, None]
        y = y * jnp.sqrt(cf / d)
    return y.astype(cv.dtype)


def _decode_attend(cfg, params, q, ck, cv, n_valid, cache_len):
    """Masked single-query attention over a (possibly ring) cache.

    ``n_valid`` is scalar (shared context length) or (B,) per-slot.
    """
    b, h, _, d = q.shape
    kv = ck.shape[1]
    if kv != h:
        rep = h // kv
        ck = jnp.repeat(ck, rep, axis=1)
        cv = jnp.repeat(cv, rep, axis=1)
    # (1 or B, cache_len) validity, broadcast over heads and the 1 query
    valid = (jnp.arange(cache_len)[None]
             < jnp.reshape(n_valid, (-1, 1)))[:, None, None, :]
    if cfg.attn_backend == "softmax":
        x = jnp.einsum("bhqd,bhmd->bhqm", q, ck,
                       preferred_element_type=jnp.float32) / math.sqrt(d)
        if cfg.softcap_attn:
            x = L.softcap(x, cfg.softcap_attn)
        x = jnp.where(valid, x, -1e30)
        a = jax.nn.softmax(x, -1)
        return jnp.einsum("bhqm,bhmd->bhqd", a.astype(cv.dtype), cv)
    tc = cfg.taylor
    tau = _tau(params, cfg, False)
    if tc.normalize_inputs:
        q, ck = T.normalize_qk(q, ck, tau)
    x = jnp.einsum("bhqd,bhmd->bhqm", q, ck,
                   preferred_element_type=jnp.float32)
    a = jnp.where(valid, T.taylor_exp(x), 0.0)
    y = jnp.einsum("bhqm,bhmd->bhqd", a / jnp.sum(a, -1, keepdims=True),
                   cv.astype(a.dtype))
    if tc.output_scale:
        y = y * jnp.sqrt(T._nb(n_valid, y.ndim) / d)
    return y.astype(cv.dtype)
