"""Sequence classifier — the paper's evaluation setting (§5.3).

Transformer *encoder* (non-causal TaylorShift or softmax backend) with
mean pooling and a linear head; used for the ListOps-style accuracy
parity benchmark (paper Table 3) and the normalization ablation
(paper Table 4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import model as M


def classifier_init(cfg: ModelConfig, n_classes: int, rng):
    k1, k2 = jax.random.split(rng)
    params = M.init_params(cfg, k1)
    params["head"] = L.dense_init(k2, cfg.d_model, n_classes,
                                  dtype=jnp.float32)
    return params


def classifier_logits(params, cfg: ModelConfig, tokens):
    hidden, _ = M.forward(params, cfg, {"tokens": tokens})
    pooled = jnp.mean(hidden.astype(jnp.float32), axis=1)
    return L.dense(params["head"], pooled)


def classifier_loss(params, cfg: ModelConfig, batch):
    logits = classifier_logits(params, cfg, batch["tokens"])
    labels = batch["label"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def classifier_accuracy(params, cfg: ModelConfig, batch):
    logits = classifier_logits(params, cfg, batch["tokens"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["label"]).astype(
        jnp.float32))
