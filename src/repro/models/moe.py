"""Mixture-of-Experts layer (llama4-maverick top-1, grok-1 top-2).

Sort-free capacity-based dispatch: each token scatters into a per-expert
buffer of fixed capacity; overflow tokens are dropped (contribute zero,
standard GShard/Switch behaviour). The expert dimension is sharded over
the ``model`` mesh axis and the within-expert hidden dimension over
``data`` (see distributed/sharding.py), so a 128-expert, 16G-param layer
spreads across all 256 chips of a pod.

Differentiable end-to-end: router probabilities multiply the combined
output; an auxiliary load-balancing loss (Switch-style) is returned for
the trainer to add.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import ctx
from repro.models import layers as L

Params = dict[str, Any]


def moe_init(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    dt = cfg.param_dtype
    k_router, k_up, k_gate, k_down, k_shared = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, m.n_experts
    scale = 1.0 / math.sqrt(d)

    def stack(k, shape, scl):
        return (jax.random.normal(k, shape, jnp.float32) * scl).astype(dt)

    p: Params = {
        "router": L.dense_init(k_router, d, e, jnp.float32),
        "w_up": stack(k_up, (e, d, f), scale),
        "w_gate": stack(k_gate, (e, d, f), scale),
        "w_down": stack(k_down, (e, f, d), 1.0 / math.sqrt(f)),
    }
    if m.n_shared_experts:
        p["shared"] = L.mlp_init(k_shared, d, f * m.n_shared_experts,
                                 gated=True, dtype=dt)
    return p


def _n_groups(T: int) -> int:
    """Dispatch groups = data shards (GShard-style), so routing math is
    shard-local. §Perf iteration 2: a single global dispatch group made
    the position-in-expert cumsum a cross-device prefix sum, forcing
    GSPMD to replicate (T, d_model) tensors — ~9 TB/step of wire on
    llama4-maverick train_4k."""
    c = ctx.get()
    if c.mesh is None:
        return 1
    g = 1
    for a in c.dp:
        g *= c.mesh.shape[a]
    return g if T % g == 0 else 1


def moe_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, N, d). Returns (y, aux_loss)."""
    m = cfg.moe
    B, N, d = x.shape
    T = B * N
    E, K = m.n_experts, m.top_k
    G = _n_groups(T)
    Tg = T // G
    cap = max(int(Tg * K / E * m.capacity_factor), 4)
    xt = x.reshape(G, Tg, d)
    xt = ctx.constrain(xt, ctx.get().dp_spec, None, None)

    logits = L.dense(params["router"], xt.astype(jnp.float32))   # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)              # (G, Tg, K)
    # Renormalize the chosen gates (standard for top-k routing).
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Position of each (token, choice) within its expert's buffer —
    # cumsum over the GROUP-LOCAL token axis only (no cross-shard deps).
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)      # (G,Tg,K,E)
    flat_oh = onehot.reshape(G, Tg * K, E)
    pos_in_expert = (jnp.cumsum(flat_oh, axis=1) - flat_oh)      # exclusive
    pos = jnp.sum(pos_in_expert * flat_oh, axis=-1)              # (G, Tg*K)
    keep = pos < cap                                             # drop overflow
    eid = expert_ids.reshape(G, Tg * K)
    slot = jnp.where(keep, pos, cap)                             # cap = trash

    # Dispatch into (G, E, cap+1, d); per-group scatter via vmap.
    x_rep = jnp.repeat(xt, K, axis=1)                            # (G, Tg*K, d)

    def disp(xg, eg, sg):
        buf = jnp.zeros((E, cap + 1, d), xg.dtype)
        return buf.at[eg, sg].add(xg)

    buf = jax.vmap(disp)(x_rep, eid, slot)[:, :, :cap]           # (G,E,cap,d)
    # EP when E divides the model axis: all-to-all reshards tokens from
    # group-local to expert-sharded. Otherwise (grok: 8e on 16-way model)
    # FSDP-style experts: tokens stay data-sharded, weights gather JIT.
    c = ctx.get()
    ep = (c.mesh is not None and E % c.mesh.shape["model"] == 0 and
          E >= c.mesh.shape["model"])
    dpspec = c.dp_spec
    if ep:
        buf = ctx.constrain(buf, dpspec, "model", None, None)
    else:
        buf = ctx.constrain(buf, dpspec, None, None, None)

    # Expert MLPs (einsum over the expert axis — shardable over 'model').
    h = jnp.einsum("gecd,edf->gecf", buf, params["w_up"],
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"],
                   preferred_element_type=jnp.float32)
    h = (h * jax.nn.silu(g)).astype(buf.dtype)
    h = ctx.constrain(h, dpspec, "model", None, None) if ep else \
        ctx.constrain(h, dpspec, None, None, "model")
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = ctx.constrain(out, dpspec, "model", None, None) if ep else \
        ctx.constrain(out, dpspec, None, None, None)

    # Combine: gather each (token, choice)'s expert output, weight by gate.
    out = jnp.concatenate([out, jnp.zeros((G, E, 1, d), out.dtype)], axis=2)

    def comb(og, eg, sg):
        return og[eg, sg]

    gathered = jax.vmap(comb)(out, eid, slot).reshape(G, Tg, K, d)
    y = jnp.sum(gathered * gate_vals[..., None].astype(gathered.dtype),
                axis=2)
    y = y.astype(x.dtype)

    if m.n_shared_experts:
        y = y + L.mlp(params["shared"], xt, act="silu")

    # Switch-style load-balance loss: E * Σ_e f_e · p_e
    frac_tokens = jnp.mean(
        jnp.sum(onehot.astype(jnp.float32), axis=2), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * mean_probs) * m.aux_loss_weight
    return y.reshape(B, N, d), aux
