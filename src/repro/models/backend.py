"""Unified attention-backend registry and selection.

Every attention entry point — ``attn_apply`` / ``attn_prefill`` /
``attn_decode`` (models/attention.py), the serving engine
(serve/engine.py) and the train / dry-run launchers — resolves *which*
TaylorShift implementation runs through :func:`select_backend`, instead
of re-deriving kernel/mode/mesh heuristics inline. The registry declares
each backend's capabilities; selection is capability-driven plus the
paper's analytic cost model (`core/taylor.py`: Eq. 5/6 FLOPs, Eq. 7/9
crossovers N0/N1).

Decisions folded in from their previous scattered homes:

* direct↔efficient "and Back" crossover (``T.pick_mode``) plus the
  TPU-mesh twist (§Perf iteration 4, ex-``_sharding_aware_mode``): when
  the head count doesn't divide the model axis, the direct form's
  (B,H,N,N) scores are partially replicated and PSUMed across the mesh,
  while the efficient form contracts over d² (always mesh-divisible) —
  wire bytes beat FLOPs, so non-causal sites prefer efficient. The
  override stays **off for causal** sites (measured regression: the
  (d², d+1)-state HBM/wire traffic outweighs the uneven-head psum).
* the fused-kernel gate (ex-``_taylor_global_kernel``): pallas_call has
  no partitioning rule, so kernels are capability-gated to single-device
  meshes; causal+efficient stays on the chunked scan core (its custom
  VJP already trains in linear memory); GQA+efficient stays on the
  grouped core path (flat kernels would recompute per-kv-head sums
  rep×).
* the GQA fused-decode gap (ex-inline ``n_heads == kv_heads`` if): the
  decode kernel works on flattened (B·H) states with no grouping, so it
  declares ``gqa=False`` and selection falls back to the grouped
  recurrent step — the constraint is now a capability flag, not a
  buried conditional.
* sequence parallelism: under a mesh with a ``seq`` axis the causal
  chunk scan runs the associative formulation with shard_map
  boundary-state exchange (distributed/seqscan.py, docs/sharding.md);
  selection checks divisibility and falls back to the sequential scan
  otherwise.

Auditability: with ``repro.obs.decisions.log`` enabled, every
``select_backend`` call appends a structured record (site, shape,
chosen backend, N0/N1, reason) — ``launch/dryrun.py`` stores the
records per cell, ``launch/serve.py --decision-log`` writes them as
JSONL, and ``benchmarks/crossover.py --decision-log`` diffs them
against the analytic crossovers (docs/observability.md).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

from repro.core import taylor as T
from repro.distributed import ctx
from repro.obs import decisions as D
from repro.tune import table as TU


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Capabilities:
    """What a backend can serve. Selection never routes around a False
    flag implicitly — it either repeats K/V heads (``Selection.repeat_kv``)
    or picks a different backend, with the reason recorded."""
    causal: bool = False        # causal masking
    non_causal: bool = False    # bidirectional / cross attention
    gqa: bool = False           # native grouped-KV (no head repeat)
    multi_device: bool = False  # safe under a >1-device GSPMD mesh
    seq_parallel: bool = False  # can shard the sequence axis (`seq`)
    differentiable: bool = False  # exact grads (custom VJP or pure jnp)
    decode: bool = False        # one-token absorb+readout
    kernel: bool = False        # Pallas-backed


@dataclass(frozen=True)
class AttentionBackend:
    name: str
    caps: Capabilities
    ops: Callable | None       # analytic FLOPs fn(N, d) — paper Eq. (5)/(6)
    entries: Callable | None   # peak tensor entries fn(N, d) — §4.2/Eq. (8)
    doc: str = ""


REGISTRY: dict[str, AttentionBackend] = {b.name: b for b in [
    AttentionBackend(
        "direct",
        Capabilities(causal=True, non_causal=True, multi_device=True,
                     differentiable=True, decode=True),
        T.ops_direct, T.entries_direct,
        "O(N²d) jnp reference; materializes the score matrix. GQA by "
        "K/V head repeat. Also serves masked kv-cache prefill/decode "
        "readouts (the paper's 'and Back' regime below N0/N1)."),
    AttentionBackend(
        "efficient",
        Capabilities(non_causal=True, gqa=True, multi_device=True,
                     differentiable=True),
        T.ops_efficient, T.entries_efficient,
        "O(N d³) ⊠-trick (Algorithm 1), grouped per-kv-head states."),
    AttentionBackend(
        "causal-scan",
        Capabilities(causal=True, gqa=True, multi_device=True,
                     seq_parallel=True, differentiable=True, decode=True),
        T.ops_efficient, T.entries_efficient,
        "Chunkwise prefix-state scan over TaylorState; recompute-based "
        "custom VJP (linear-memory training). Sequential (lax.scan) or "
        "associative/sequence-parallel core; its one-token limit is "
        "taylor_decode_step (the recurrent decode fallback)."),
    AttentionBackend(
        "kernel-direct",
        Capabilities(causal=True, non_causal=True, differentiable=True,
                     kernel=True),
        T.ops_direct, T.entries_direct,
        "Fused Pallas direct kernel + flash-style recompute backward "
        "(kernels/taylor_direct.py, taylor_grad.py)."),
    AttentionBackend(
        "kernel-efficient",
        Capabilities(non_causal=True, differentiable=True, kernel=True),
        T.ops_efficient, T.entries_efficient,
        "Fused Pallas ⊠-trick kernel + O(N·d + d³) backward "
        "(kernels/taylor_efficient.py, taylor_grad.py)."),
    AttentionBackend(
        "fused-decode",
        Capabilities(causal=True, decode=True, kernel=True),
        None, None,
        "One-token update+readout fused in VMEM "
        "(kernels/taylor_decode.py). Flat (B·H) state layout — no GQA "
        "grouping (caps.gqa=False), single-device."),
]}


@dataclass(frozen=True)
class Selection:
    """A resolved routing decision, with the evidence that produced it."""
    backend: AttentionBackend
    mode: str            # resolved direct|efficient ('' where n/a)
    repeat_kv: bool      # caller must repeat K/V heads before the call
    seq_shards: int      # >1: run the causal scan sequence-parallel
    scan: str            # causal-scan core: sequential|parallel|seq-parallel
    chunk: int           # causal-scan chunk size (0 = n/a)
    n0: float            # crossovers that governed this decision — analytic
    n1: float            #   Eq. (7)/(9), or measured when a tuning table hit
    reason: str
    provenance: str = "analytic"   # analytic | calibrated (repro.tune table)

    @property
    def name(self) -> str:
        return self.backend.name


# ---------------------------------------------------------------------------
# Cost model / mode resolution
# ---------------------------------------------------------------------------

def resolved_mode(cfg, N: int, d: int, *, causal: bool, c=None,
                  n0: float | None = None, n1: float | None = None) -> str:
    """Pinned config mode, else the paper crossover with the mesh twist
    (§Perf iteration 4) for non-causal sites. ``n0``/``n1`` pin
    calibrated thresholds from a measured-override table."""
    tc = cfg.taylor
    if tc.mode != "auto":
        return tc.mode
    base = T.pick_mode(N, d, optimize_for=tc.optimize_for, n0=n0, n1=n1)
    c = c or ctx.get()
    if (base == "direct" and not causal and c.enabled
            and c.mesh is not None):
        msize = c.mesh.shape[c.model_axis]
        if cfg.n_heads % msize and (d * d) % msize == 0:
            return "efficient"
    return base


def plan_chunk(N: int, want: int, *, seq_shards: int = 1,
               cap_passes: int = 8) -> int:
    """Causal chunk size for a (possibly seq-sharded) scan: at most
    ``cap_passes`` chunk passes per shard (§Perf iteration 5b — each
    pass re-reads the (d², d+1) state), halved until it divides."""
    local = max(N // max(seq_shards, 1), 1)
    chunk = min(max(want, local // cap_passes), local)
    while local % chunk:
        chunk //= 2
    return max(chunk, 1)


def _seq_plan(cfg, N: int, c, *, chunk_want: int) -> tuple[int, str, int]:
    """(seq_shards, scan, chunk) for a causal-scan selection."""
    tc = cfg.taylor
    shards = c.seq_size
    if shards > 1 and N % shards == 0 and N // shards >= 1 \
            and tc.scan != "sequential":
        chunk = plan_chunk(N, chunk_want, seq_shards=shards)
        return shards, "seq-parallel", chunk
    scan = "parallel" if tc.scan == "parallel" else "sequential"
    return 1, scan, plan_chunk(N, chunk_want)


# ---------------------------------------------------------------------------
# Selection
# ---------------------------------------------------------------------------

def select_backend(cfg, *, N: int, d: int, site: str = "full",
                   causal: bool = True, cache_kind: str = "taylor",
                   mesh=None) -> Selection:
    """Resolve the implementation for one attention site.

    site: ``full`` (train / whole-sequence forward), ``prefill``
    (chunked prompt absorption into a decode cache), ``decode``
    (one-token step). ``mesh`` defaults to the ambient sharding context
    (distributed/ctx.py); pass a mesh explicitly for offline reports.
    """
    c = ctx.get()
    if mesh is not None:
        c = dataclasses.replace(c, enabled=True, mesh=mesh)
    tc = cfg.taylor
    gqa = cfg.kv_heads != cfg.n_heads
    n0, n1 = T.crossover_n0(d), T.crossover_n1(d)
    # measured-override table (repro.tune): the most specific entry for
    # (d, H, site) replaces the analytic thresholds BEFORE any routing
    # below reads them, and the provenance travels with the Selection —
    # the decision log shows exactly which choices ran on measurements
    provenance, cal_n0, cal_n1 = "analytic", None, None
    table = TU.active()
    if table is not None:
        entry = table.lookup(d=d, H=cfg.n_heads, site=site)
        if entry is not None and (entry.n0 is not None
                                  or entry.n1 is not None):
            provenance = "calibrated"
            cal_n0, cal_n1 = entry.n0, entry.n1
            if entry.n0 is not None:
                n0 = float(entry.n0)
            if entry.n1 is not None:
                n1 = float(entry.n1)

    def sel(name, mode="", repeat_kv=False, seq_shards=1, scan="",
            chunk=0, reason=""):
        s = Selection(REGISTRY[name], mode, repeat_kv, seq_shards,
                      scan, chunk, n0, n1, reason, provenance)
        if D.log.enabled:   # audit every resolved selection (obs/decisions)
            D.log.record(site=site, N=N, d=d, H=cfg.n_heads,
                         kv_heads=cfg.kv_heads, causal=causal,
                         cache_kind=cache_kind, backend=s.name, mode=s.mode,
                         repeat_kv=s.repeat_kv, seq_shards=s.seq_shards,
                         scan=s.scan, chunk=s.chunk, n0=s.n0, n1=s.n1,
                         reason=s.reason, provenance=s.provenance)
        return s

    if site == "decode":
        if cache_kind == "kv":
            return sel("direct", mode="direct", repeat_kv=gqa,
                       reason="kv cache: masked direct readout "
                              "('and Back' below the memory crossover)")
        fused = REGISTRY["fused-decode"].caps
        if tc.use_kernel and not (gqa and not fused.gqa) \
                and not (c.multi_device and not fused.multi_device):
            return sel("fused-decode",
                       reason="use_kernel, MHA state layout, single device")
        why = ("fused-decode lacks GQA grouping (caps.gqa=False)" if gqa
               and tc.use_kernel else
               "fused-decode is single-device (caps.multi_device=False)"
               if tc.use_kernel else "kernels off")
        return sel("causal-scan", scan="sequential",
                   reason=f"recurrent taylor_decode_step — {why}")

    if site == "verify":
        # Speculative verification (src/repro/spec/): score a short block
        # of drafted tokens for every slot in one call, continuing each
        # slot's state. The block is tiny (speculate_k+1 ≤ ~9 tokens), so
        # it always runs as ONE chunk of the sequential scan — no seq
        # sharding, no kernels (per-slot (B,) counters are a layout the
        # flat kernels don't serve).
        if cache_kind == "kv":
            return sel("direct", mode="direct", repeat_kv=gqa,
                       reason="kv cache: masked direct verify attend "
                              "(per-slot positions)")
        return sel("causal-scan", scan="sequential", chunk=max(N, 1),
                   reason="multi-token verify from per-slot TaylorState "
                          "(causal_taylorshift initial_state=…, one chunk)")

    if site == "prefill":
        if cache_kind == "kv":
            return sel("direct", mode="direct", repeat_kv=gqa,
                       reason="kv cache: masked direct prefill attend")
        shards, scan, chunk = _seq_plan(cfg, N, c, chunk_want=N)
        return sel("causal-scan", seq_shards=shards, scan=scan, chunk=chunk,
                   reason="TaylorState handoff "
                          "(causal_taylorshift initial_state=…)")

    # --- full-sequence -----------------------------------------------------
    mode = resolved_mode(cfg, N, d, causal=causal, c=c,
                         n0=cal_n0, n1=cal_n1)
    kernel_ok = (tc.use_kernel and tc.normalize_inputs
                 and not c.multi_device)
    if kernel_ok and causal and mode != "direct":
        kernel_ok = False          # chunked-scan core trains in linear memory
    elif kernel_ok and gqa and mode == "efficient":
        kernel_ok = False          # flat kernels recompute kv-head sums rep×
    if kernel_ok:
        name = "kernel-direct" if mode == "direct" else "kernel-efficient"
        return sel(name, mode=mode, repeat_kv=gqa and mode == "direct",
                   reason="use_kernel on a single-device mesh")

    if causal and mode != "direct":
        shards, scan, chunk = _seq_plan(cfg, N, c, chunk_want=tc.chunk)
        return sel("causal-scan", mode=mode, seq_shards=shards, scan=scan,
                   chunk=chunk,
                   reason=f"causal beyond crossover (N0={n0:.0f})"
                          + (f"; seq-parallel ×{shards}" if shards > 1
                             else ""))
    if mode == "direct":
        why = (f"N below crossover (N0={n0:.0f})" if tc.mode == "auto"
               else "mode pinned by config")
        if tc.use_kernel and tc.normalize_inputs and c.multi_device:
            why += "; kernels skipped: pallas_call has no partitioning rule"
        return sel("direct", mode="direct", repeat_kv=gqa, reason=why)
    return sel("efficient", mode="efficient",
               reason=f"beyond crossover (N0={n0:.0f})"
                      if tc.mode == "auto" else "mode pinned by config")


def select_composed_scan(cfg, *, N: int, d: int, causal: bool,
                         mesh) -> Selection:
    """Resolve the attention path *inside* the composed (data, pipe, seq)
    manual region (distributed/composed.py).

    Only the linear-memory forms are eligible — the composed path exists
    to hold the activation-memory slope at long N, so the direct O(N²)
    form is never selected here regardless of the N0 crossover; kernels
    are gated off (pallas_call has no partitioning rule under a
    multi-device mesh). Causal picks the boundary-exchange chunk scan
    (seq-parallel when the seq axis is non-trivial, the per-shard
    sequential scan otherwise); non-causal picks Algorithm 1 with its
    key-side sums psum'd across the seq axis. The decision is audited to
    the same log as every other dispatch (site="composed").
    """
    c = dataclasses.replace(ctx.get(), enabled=True, mesh=mesh)
    tc = cfg.taylor
    shards = c.seq_size
    n0, n1 = T.crossover_n0(d), T.crossover_n1(d)

    def sel(name, scan="", chunk=0, reason=""):
        s = Selection(REGISTRY[name], "efficient", False, shards, scan,
                      chunk, n0, n1, reason, "analytic")
        if D.log.enabled:
            D.log.record(site="composed", N=N, d=d, H=cfg.n_heads,
                         kv_heads=cfg.kv_heads, causal=causal,
                         cache_kind="taylor", backend=s.name, mode=s.mode,
                         repeat_kv=False, seq_shards=shards, scan=s.scan,
                         chunk=s.chunk, n0=n0, n1=n1, reason=s.reason,
                         provenance=s.provenance)
        return s

    if causal:
        if shards > 1 and N % shards == 0:
            return sel("causal-scan", scan="seq-parallel",
                       chunk=plan_chunk(N, tc.chunk, seq_shards=shards),
                       reason=f"composed mesh: boundary-exchange chunk "
                              f"scan ×{shards} inside the manual region")
        return sel("causal-scan", scan="sequential",
                   chunk=plan_chunk(N, tc.chunk),
                   reason="composed mesh: trivial seq axis — per-shard "
                          "sequential chunk scan")
    return sel("efficient",
               reason=(f"non-causal: Algorithm 1, key-side sums psum'd "
                       f"×{shards}" if shards > 1
                       else "non-causal: Algorithm 1 per shard"))


# ---------------------------------------------------------------------------
# Serving plan ("and Back" for the cache, satellite of the engine)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServePlan:
    cache_kind: str      # taylor | kv (resolved from 'auto')
    prefill: Selection
    decode: Selection
    reason: str
    verify: Selection | None = None   # speculative verify (speculate_k > 0)


def select_serve_plan(cfg, *, max_seq_len: int, prefill_chunk: int,
                      cache_kind: str = "auto", speculate_k: int = 0,
                      mesh=None) -> ServePlan:
    """Resolve the engine's cache layout and both serving paths.

    ``cache_kind='auto'`` applies the paper's memory crossover N1
    (Eq. 9) via ``pick_mode(optimize_for='memory')``: below N1 the O(N)
    KV cache is *smaller* than the constant (d², d+1) Taylor state, so
    short-context engines take the direct/kv route ("and Back"); beyond
    it the constant-size state wins and slots become fixed-size.
    """
    d = cfg.dim_head
    reason = "cache_kind pinned by config"
    if cache_kind == "auto":
        # effective_n1 consults the installed tuning-table hook, so a
        # calibrated memory crossover moves the "and Back" cache choice
        n1 = T.effective_n1(d)
        mode = T.pick_mode(max_seq_len, d, optimize_for="memory")
        how = "measured" if n1 != T.crossover_n1(d) else "analytic"
        cache_kind = "taylor" if mode == "efficient" else "kv"
        reason = (f"{how} memory crossover N1(d={d})={n1:.0f} vs "
                  f"max_seq_len={max_seq_len} -> {cache_kind}")
    return ServePlan(
        cache_kind=cache_kind,
        prefill=select_backend(cfg, N=prefill_chunk, d=d, site="prefill",
                               cache_kind=cache_kind, mesh=mesh),
        decode=select_backend(cfg, N=1, d=d, site="decode",
                              cache_kind=cache_kind, mesh=mesh),
        verify=(select_backend(cfg, N=speculate_k + 1, d=d, site="verify",
                               cache_kind=cache_kind, mesh=mesh)
                if speculate_k else None),
        reason=reason)


# ---------------------------------------------------------------------------
# Launcher helpers
# ---------------------------------------------------------------------------

def configure_for_training(cfg, *, use_kernels: bool = True):
    """Route full-sequence training attention through the fused kernels
    (differentiable via the custom-VJP backward kernels,
    docs/training.md). Causal beyond-crossover sites keep the chunked
    scan core — select_backend enforces that per site."""
    if use_kernels and cfg.attn_backend == "taylor" \
            and not cfg.taylor.use_kernel:
        return cfg.with_(taylor=dataclasses.replace(cfg.taylor,
                                                    use_kernel=True))
    return cfg


def report(cfg, *, N: int, d: int, mesh=None) -> dict:
    """Routing report for one (config, shape, mesh) cell — surfaced by
    launch/dryrun.py next to the roofline so sweep results record which
    implementation they measured."""
    out = {"crossover_n0": T.crossover_n0(d), "crossover_n1": T.crossover_n1(d)}
    for site, causal, n in [("full", cfg.causal, N), ("prefill", True, N),
                            ("decode", True, 1)]:
        s = select_backend(cfg, N=n, d=d, site=site, causal=causal,
                           mesh=mesh)
        out[site] = {"backend": s.name, "mode": s.mode,
                     "seq_shards": s.seq_shards, "reason": s.reason,
                     "provenance": s.provenance}
    return out
