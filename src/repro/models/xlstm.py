"""xLSTM blocks (sLSTM + mLSTM) — xlstm-125m.

TaylorShift is inapplicable (attention-free; docs/design.md
§Arch-applicability). Notably the mLSTM matrix memory C_t ∈ R^{d×d} is
the closest structural cousin of efficient-TaylorShift's S1 state — both
are outer-product accumulators read out by the query — so the chunked
implementation below mirrors core/taylor.py's chunk scheme.

mLSTM: exponential input gate, sigmoid-style forget gate in log space,
max-stabilizer m_t; chunked parallel form for training, O(1)-state decode.
sLSTM: strict scalar recurrence with exponential gating and normalizer —
``lax.scan`` over time.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    dt = cfg.param_dtype
    ks = jax.random.split(key, 7)
    up = 2 * d
    return {
        "up_proj": L.dense_init(ks[0], d, 2 * up, dt),    # path + gate
        "wq": L.dense_init(ks[1], up, H * dh, dt),
        "wk": L.dense_init(ks[2], up, H * dh, dt),
        "wv": L.dense_init(ks[3], up, up, dt),
        "w_if": L.dense_init(ks[4], up, 2 * H, jnp.float32),
        "norm": L.rmsnorm_init(up),
        "down_proj": L.dense_init(ks[5], up, d, dt),
    }


def _mlstm_cell_chunked(q, k, v, i_gate, f_gate, chunk):
    """Stabilized chunked mLSTM cell.

    q,k: (B,H,N,dk); v: (B,H,N,dv); i_gate,f_gate: (B,H,N) raw (pre-act).
    Returns (B,H,N,dv).

    h_t = (qᵀ C_t) / max(|qᵀ n_t|, 1);  C_t = f C_{t-1} + i k vᵀ
    with log-space stabilization m_t = max(log f + m_{t-1}, log i).
    Chunked: exact same algebra, stabilizer carried per chunk.
    """
    b, h, n, dk = q.shape
    dv = v.shape[-1]
    assert n % chunk == 0
    nc = n // chunk
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))   # (B,H,N)
    logi = i_gate.astype(jnp.float32)
    q = q.astype(jnp.float32) / jnp.sqrt(dk)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    def r(t, *shape):
        return t.reshape(b, h, nc, chunk, *shape)

    qc, kc, vc = r(q, dk), r(k, dk), r(v, dv)
    lf, li = r(logf), r(logi)
    csf = jnp.cumsum(lf, axis=-1)                            # Σ log f within chunk
    # intra-chunk log weights: D[i,j] = csf_i - csf_j + li_j  (j <= i)
    Dm = csf[..., :, None] - csf[..., None, :] + li[..., None, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    Dm = jnp.where(mask, Dm, -jnp.inf)
    m_intra = jnp.max(Dm, axis=-1)                           # (B,H,nc,C)

    # inter-chunk state: S_z = Σ_j exp(csf_end - csf_j + li_j) k_j v_jᵀ, with
    # per-chunk stabilizer m_state = max_j (csf_end - csf_j + li_j)
    end = csf[..., -1:]
    wlog = end - csf + li                                    # (B,H,nc,C)
    m_state = jnp.max(wlog, axis=-1)                         # (B,H,nc)
    w = jnp.exp(wlog - m_state[..., None])
    S = jnp.einsum("bhzc,bhzck,bhzcv->bhzkv", w, kc, vc)
    nrm = jnp.einsum("bhzc,bhzck->bhzk", w, kc)
    fsum = end[..., 0]                                       # Σ log f per chunk

    def scan_fn(carry, inp):
        Cprev, nprev, mprev = carry
        Sz, nz, mz, fz = inp
        mnew = jnp.maximum(fz + mprev, mz)
        Cnew = (Cprev * jnp.exp(fz + mprev - mnew)[..., None, None]
                + Sz * jnp.exp(mz - mnew)[..., None, None])
        nnew = (nprev * jnp.exp(fz + mprev - mnew)[..., None]
                + nz * jnp.exp(mz - mnew)[..., None])
        return (Cnew, nnew, mnew), (Cprev, nprev, mprev)

    C0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    m0 = jnp.full((b, h), -jnp.inf)
    swap = lambda t: jnp.moveaxis(t, 2, 0)
    (_, _, _), (Cp, np_, mp) = jax.lax.scan(
        scan_fn, (C0, n0, m0),
        (swap(S), swap(nrm), swap(m_state), swap(fsum)))
    Cp, np_, mp = jnp.moveaxis(Cp, 0, 2), jnp.moveaxis(np_, 0, 2), jnp.moveaxis(mp, 0, 2)

    # combine intra + inter with a joint stabilizer per position
    m_inter = csf + mp[..., None]                            # (B,H,nc,C)
    m_tot = jnp.maximum(m_intra, m_inter)
    m_tot = jnp.where(jnp.isfinite(m_tot), m_tot, 0.0)
    w_intra = jnp.exp(jnp.where(mask, Dm - m_tot[..., None], -jnp.inf))
    w_intra = jnp.where(mask, w_intra, 0.0)
    scores = jnp.einsum("bhzik,bhzjk->bhzij", qc, kc) * w_intra
    num = jnp.einsum("bhzij,bhzjv->bhziv", scores, vc)
    den = jnp.sum(scores, axis=-1)
    wi = jnp.exp(m_inter - m_tot)
    num = num + jnp.einsum("bhzc,bhzck,bhzkv->bhzcv", wi, qc, Cp)
    den = den + jnp.einsum("bhzc,bhzck,bhzk->bhzc", wi, qc, np_)
    out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return out.reshape(b, h, n, dv)


def mlstm_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, n, d = x.shape
    H = cfg.n_heads
    up = 2 * d
    dh = d // H
    path, gate = jnp.split(L.dense(params["up_proj"], x), 2, axis=-1)
    q = L.dense(params["wq"], path).reshape(b, n, H, dh).transpose(0, 2, 1, 3)
    k = L.dense(params["wk"], path).reshape(b, n, H, dh).transpose(0, 2, 1, 3)
    v = L.dense(params["wv"], path).reshape(b, n, H, up // H).transpose(0, 2, 1, 3)
    gif = L.dense(params["w_if"], path.astype(jnp.float32)).reshape(b, n, 2, H)
    i_g = gif[:, :, 0].transpose(0, 2, 1)                    # (B,H,N)
    f_g = gif[:, :, 1].transpose(0, 2, 1)
    chunk = min(cfg.ssm.chunk, n)
    while n % chunk:
        chunk //= 2
    y = _mlstm_cell_chunked(q, k, v, i_g, f_g, max(chunk, 1))
    y = y.transpose(0, 2, 1, 3).reshape(b, n, up).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y)
    y = y * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return L.dense(params["down_proj"], y)


def mlstm_init_cache(cfg: ModelConfig, batch: int):
    d, H = cfg.d_model, cfg.n_heads
    dh, dv = d // H, 2 * d // H
    return {
        "C": jnp.zeros((batch, H, dh, dv), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray, cache):
    b, _, d = x.shape
    H = cfg.n_heads
    up = 2 * d
    dh = d // H
    path, gate = jnp.split(L.dense(params["up_proj"], x), 2, axis=-1)
    q = L.dense(params["wq"], path).reshape(b, H, dh).astype(jnp.float32) / jnp.sqrt(dh)
    k = L.dense(params["wk"], path).reshape(b, H, dh).astype(jnp.float32)
    v = L.dense(params["wv"], path).reshape(b, H, up // H).astype(jnp.float32)
    gif = L.dense(params["w_if"], path.astype(jnp.float32)).reshape(b, 2, H)
    logi = gif[:, 0]
    logf = jax.nn.log_sigmoid(gif[:, 1])
    mnew = jnp.maximum(logf + cache["m"], logi)
    Cnew = (cache["C"] * jnp.exp(logf + cache["m"] - mnew)[..., None, None]
            + jnp.einsum("bhk,bhv->bhkv", k, v) * jnp.exp(logi - mnew)[..., None, None])
    nnew = (cache["n"] * jnp.exp(logf + cache["m"] - mnew)[..., None]
            + k * jnp.exp(logi - mnew)[..., None])
    num = jnp.einsum("bhk,bhkv->bhv", q, Cnew)
    den = jnp.einsum("bhk,bhk->bh", q, nnew)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    y = y.reshape(b, 1, up).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y) * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return L.dense(params["down_proj"], y), {"C": Cnew, "n": nnew, "m": mnew}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    return {
        "w_gates": L.dense_init(ks[0], d, 4 * d, dt),      # z, i, f, o
        "r_gates": L.dense_init(ks[1], d, 4 * d, dt),      # recurrent
        "norm": L.rmsnorm_init(d),
        "ffn": L.mlp_init(ks[2], d, int(d * 4 / 3) // 8 * 8, gated=True, dtype=dt),
    }


def _slstm_step_from_wx(params, carry, wx_t):
    """One sLSTM step given the precomputed input projection wx_t.

    §Perf iteration (xlstm): W·x_t for ALL timesteps is hoisted out of
    the scan into one batched MXU matmul — inside the scan only the
    recurrent R·h remains, halving per-step weight re-reads (the scan
    re-read both (d,4d) matrices from HBM every timestep: 2×9.4 MB ×
    4096 steps × layers of pure HBM traffic)."""
    c, nrm, m, h = carry
    gates = (wx_t
             + L.dense(params["r_gates"], h.astype(wx_t.dtype))
             ).astype(jnp.float32)
    z, i, f, o = jnp.split(gates, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f)
    mnew = jnp.maximum(logf + m, i)
    ig = jnp.exp(i - mnew)
    fg = jnp.exp(logf + m - mnew)
    cnew = fg * c + ig * jnp.tanh(z)
    nnew = fg * nrm + ig
    hnew = jax.nn.sigmoid(o) * cnew / jnp.maximum(nnew, 1.0)
    return (cnew, nnew, mnew, hnew), hnew


def slstm_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, n, d = x.shape
    carry = slstm_init_cache(cfg, b)
    wx = L.dense(params["w_gates"], x)        # (B, N, 4d) — one MXU matmul

    def step(carry, wx_t):
        return _slstm_step_from_wx(params, carry, wx_t)

    _, hs = jax.lax.scan(step, carry, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    h = L.rmsnorm(params["norm"], h)
    return L.mlp(params["ffn"], h, act="gelu")


def slstm_init_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, jnp.full((batch, d), -1e30, jnp.float32), z)


def slstm_decode(params: Params, cfg: ModelConfig, x: jnp.ndarray, cache):
    wx = L.dense(params["w_gates"], x[:, 0])
    carry, h = _slstm_step_from_wx(params, cache, wx)
    h = h[:, None].astype(x.dtype)
    h = L.rmsnorm(params["norm"], h)
    return L.mlp(params["ffn"], h, act="gelu"), carry
