"""``repro.state/v1`` — the serving-state wire format.

The paper's constant-size recurrent attention state makes an entire
in-flight request cheap to ship between machines: a decoding stream is
O(layers · d²) bytes of Taylor state plus a few counters, independent
of how much context it has absorbed ("Transformers are RNNs" is the
lineage — PAPERS.md). This module turns that observation into bytes:
a versioned, self-describing binary encoding of the serving state
pytrees — ``StatePool`` slot snapshots, ``prefix_cache.PrefixCache``
trie entries (Taylor state and "and Back" kv blocks, plus boundary
logits rows), and request lifecycle metadata — that round-trips
**bit-exactly** and *refuses* anything it cannot prove intact.

Blob layout::

    magic   b"REPROST1"                      (8 bytes)
    hlen    u32 little-endian                (4 bytes)
    header  JSON, utf-8                      (hlen bytes)
    payload concatenated raw array bytes
    crc     u32 little-endian crc32 over hlen|header|payload

Header schema::

    {"schema": "repro.state/v1", "kind": "<caller tag>",
     "meta": {...json metadata...},
     "tree": <structure skeleton>,
     "arrays": [{"dtype": "float32", "shape": [..], "nbytes": n}, ...]}

The ``tree`` skeleton mirrors the pytree with array leaves replaced by
payload indices — dicts, lists, tuples, ``core.taylor.TaylorState``
and plain scalars are all representable, which covers every decode
cache / trie entry shape the serving stack produces. Versioning
follows the ``repro.tune/v1`` / ``repro.obs/v1`` convention: foreign
schema strings are refused with a clear error, never coerced.

Integrity contract (tests/test_wire.py pins it with hypothesis):
``decode(encode(tree))`` is the identity for every leaf, bit for bit
and dtype for dtype; any truncation or byte mutation of a blob raises
:class:`WireError` — a blob either restores completely or not at all
(the crc covers the length field, the header and the payload, so there
is no mutable region the check misses; the crc itself is covered
because a mutated crc no longer matches the recomputed one). This is a
checksum against corruption and truncation, not a MAC against an
adversary — transport security is the deployment's problem.
"""

from __future__ import annotations

import json
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.taylor import TaylorState

SCHEMA = "repro.state/v1"

_MAGIC = b"REPROST1"

# NamedTuple leaves allowed in serving-state pytrees. Anything else is
# refused at encode time — silently pickling unknown node types is how
# wire formats grow un-versionable.
_NAMEDTUPLES = {"TaylorState": TaylorState}


class WireError(ValueError):
    """Blob refused: foreign version, corrupt, truncated, or a
    structure the format does not speak. Nothing was restored."""


# ---------------------------------------------------------------------------
# Encode
# ---------------------------------------------------------------------------

def _encode_node(node, arrays: list) -> object:
    """Recursively fold a pytree node into the JSON skeleton, appending
    array leaves to ``arrays``."""
    if isinstance(node, (np.ndarray, jnp.ndarray)):
        a = np.asarray(node)
        arrays.append(a)
        return {"__arr__": len(arrays) - 1}
    for name, cls in _NAMEDTUPLES.items():
        if isinstance(node, cls):
            return {"__nt__": name,
                    "fields": {k: _encode_node(v, arrays)
                               for k, v in node._asdict().items()}}
    if isinstance(node, dict):
        if not all(isinstance(k, str) for k in node):
            raise WireError("wire trees need str dict keys")
        return {"__dict__": {k: _encode_node(v, arrays)
                             for k, v in node.items()}}
    if isinstance(node, tuple):
        return {"__tuple__": [_encode_node(v, arrays) for v in node]}
    if isinstance(node, list):
        return {"__list__": [_encode_node(v, arrays) for v in node]}
    if node is None or isinstance(node, (bool, int, float, str)):
        return {"__val__": node}
    raise WireError(f"cannot serialize node of type {type(node).__name__}")


def encode(kind: str, tree, meta: dict | None = None) -> bytes:
    """Serialize ``tree`` (+ JSON-able ``meta``) into one self-describing
    blob. ``kind`` tags what the blob is (``"stream"``, ``"trie"``, …)
    so a decoder can refuse a blob handed to the wrong restore path."""
    arrays: list[np.ndarray] = []
    skeleton = _encode_node(tree, arrays)
    payload = b"".join(a.tobytes() for a in arrays)
    header = json.dumps({
        "schema": SCHEMA, "kind": kind, "meta": meta or {},
        "tree": skeleton,
        "arrays": [{"dtype": a.dtype.name, "shape": list(a.shape),
                    "nbytes": a.nbytes} for a in arrays],
    }, sort_keys=True).encode()
    body = len(header).to_bytes(4, "little") + header + payload
    crc = zlib.crc32(body).to_bytes(4, "little")
    return _MAGIC + body + crc


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        pass
    try:                         # ml_dtypes extras (bfloat16, fp8, ...)
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))
    except (ImportError, AttributeError, TypeError):
        raise WireError(f"unknown array dtype {name!r}") from None


def _decode_node(node, leaves: list):
    if not isinstance(node, dict) or len(node) == 0:
        raise WireError(f"malformed tree node {node!r}")
    if "__arr__" in node:
        idx = node["__arr__"]
        if not isinstance(idx, int) or not 0 <= idx < len(leaves):
            raise WireError(f"array index {idx!r} out of range")
        return leaves[idx]
    if "__nt__" in node:
        cls = _NAMEDTUPLES.get(node["__nt__"])
        if cls is None:
            raise WireError(f"unknown namedtuple {node.get('__nt__')!r}")
        fields = {k: _decode_node(v, leaves)
                  for k, v in node["fields"].items()}
        if set(fields) != set(cls._fields):
            raise WireError(f"{node['__nt__']} fields {sorted(fields)} != "
                            f"{sorted(cls._fields)}")
        return cls(**fields)
    if "__dict__" in node:
        return {k: _decode_node(v, leaves)
                for k, v in node["__dict__"].items()}
    if "__tuple__" in node:
        return tuple(_decode_node(v, leaves) for v in node["__tuple__"])
    if "__list__" in node:
        return [_decode_node(v, leaves) for v in node["__list__"]]
    if "__val__" in node:
        return node["__val__"]
    raise WireError(f"malformed tree node {node!r}")


def decode(blob: bytes, expect_kind: str | None = None,
           as_jax: bool = True) -> tuple[str, dict, object]:
    """Restore ``(kind, meta, tree)`` from a blob.

    All-or-nothing: every integrity check — magic, schema version, crc
    over length/header/payload, per-array byte accounting — runs before
    any tree is built, so a caller can scatter the result into live
    state knowing the blob was intact. ``expect_kind`` additionally
    pins which restore path the blob is allowed to feed. ``as_jax``
    returns ``jnp`` leaves (device-ready); pass False for raw numpy.
    """
    if not isinstance(blob, (bytes, bytearray, memoryview)):
        raise WireError("blob is not bytes")
    blob = bytes(blob)
    if len(blob) < len(_MAGIC) + 8:
        raise WireError(f"truncated blob ({len(blob)} bytes)")
    if blob[:len(_MAGIC)] != _MAGIC:
        raise WireError("bad magic — not a repro.state blob")
    body, crc_stored = blob[len(_MAGIC):-4], blob[-4:]
    if zlib.crc32(body).to_bytes(4, "little") != crc_stored:
        raise WireError("crc mismatch — blob corrupt or truncated")
    hlen = int.from_bytes(body[:4], "little")
    if hlen > len(body) - 4:
        raise WireError("header length exceeds blob")
    try:
        header = json.loads(body[4:4 + hlen].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"header is not valid JSON: {e}") from None
    if not isinstance(header, dict):
        raise WireError("header is not an object")
    if header.get("schema") != SCHEMA:
        raise WireError(f"schema {header.get('schema')!r} is not "
                        f"{SCHEMA!r} — refusing (foreign version)")
    kind = header.get("kind")
    if expect_kind is not None and kind != expect_kind:
        raise WireError(f"blob kind {kind!r}, expected {expect_kind!r}")
    meta = header.get("meta")
    specs = header.get("arrays")
    if not isinstance(meta, dict) or not isinstance(specs, list):
        raise WireError("header missing meta/arrays")
    payload = body[4 + hlen:]
    leaves, off = [], 0
    for i, spec in enumerate(specs):
        try:
            dt = _dtype(spec["dtype"])
            shape = tuple(int(s) for s in spec["shape"])
            nbytes = int(spec["nbytes"])
        except (KeyError, TypeError, ValueError):
            raise WireError(f"arrays[{i}]: malformed spec") from None
        want = dt.itemsize * int(np.prod(shape, dtype=np.int64))
        if nbytes != want:
            raise WireError(f"arrays[{i}]: nbytes {nbytes} != "
                            f"dtype×shape {want}")
        if off + nbytes > len(payload):
            raise WireError(f"arrays[{i}]: payload truncated")
        a = np.frombuffer(payload, dtype=dt, count=want // dt.itemsize,
                          offset=off).reshape(shape)
        if as_jax and jax.dtypes.canonicalize_dtype(dt) == dt:
            # Only promote to jax when the dtype survives canonicalization
            # bit-for-bit — jnp.asarray silently narrows int64/float64 when
            # x64 is off, which would break the round-trip contract.
            a = jnp.asarray(a)
        leaves.append(a)
        off += nbytes
    if off != len(payload):
        raise WireError(f"payload has {len(payload) - off} trailing bytes")
    tree = _decode_node(header.get("tree"), leaves)
    return kind, meta, tree


# ---------------------------------------------------------------------------
# Serving-state conveniences (the three kinds the fleet ships around)
# ---------------------------------------------------------------------------

KIND_STREAM = "stream"       # a live request: slot state + lifecycle meta
KIND_TRIE = "trie"           # one prefix-cache entry: state + logits row
KIND_SNAPSHOT = "snapshot"   # a bare slot/pool snapshot (tests, tooling)


def encode_stream(state, *, request: dict, out_tokens: list[int],
                  cache_kind: str, cache_len: int,
                  model: dict | None = None,
                  replica: str | None = None) -> bytes:
    """One in-flight decoding request: the slot's state snapshot plus
    everything a peer needs to continue the stream bit-identically."""
    return encode(KIND_STREAM, state, meta={
        "request": request, "out_tokens": [int(t) for t in out_tokens],
        "cache_kind": cache_kind, "cache_len": int(cache_len),
        "model": model or {}, "replica": replica})


def decode_stream(blob: bytes) -> tuple[dict, object]:
    """(meta, state) of a :func:`encode_stream` blob."""
    _, meta, state = decode(blob, expect_kind=KIND_STREAM)
    for key in ("request", "out_tokens", "cache_kind", "cache_len"):
        if key not in meta:
            raise WireError(f"stream blob meta missing {key!r}")
    return meta, state


def encode_trie_entry(tokens, n_tokens: int, state, logits) -> bytes:
    """One prefix-cache boundary: the trie path's tokens, the state
    snapshot, and the boundary logits row (None for partial entries)."""
    return encode(KIND_TRIE, {"state": state, "logits": logits},
                  meta={"tokens": [int(t) for t in tokens],
                        "n_tokens": int(n_tokens)})


def decode_trie_entry(blob: bytes) -> tuple[list[int], int, object, object]:
    """(tokens, n_tokens, state, logits) of an :func:`encode_trie_entry`
    blob."""
    _, meta, tree = decode(blob, expect_kind=KIND_TRIE)
    if "tokens" not in meta or "n_tokens" not in meta:
        raise WireError("trie blob meta missing tokens/n_tokens")
    if not isinstance(tree, dict) or set(tree) != {"state", "logits"}:
        raise WireError("trie blob tree must be {state, logits}")
    return (list(meta["tokens"]), int(meta["n_tokens"]),
            tree["state"], tree["logits"])
