"""Continuous-batching serving engine on constant-size Taylor state.

The paper's serving win — decode state that never grows with context —
makes an inference engine unusually simple: no paged KV-block allocator
(vLLM) is needed because every sequence's per-layer attention state is a
fixed-size ``TaylorState``. The engine therefore reduces to

  * a slot pool of preallocated per-layer states (``pool.StatePool``),
  * chunked prefill through ``causal_taylorshift(initial_state=...)``
    with power-of-two chunk planning (``prefill``),
  * a token-budget scheduler interleaving prefill chunks with batched
    decode steps (``scheduler``),
  * request lifecycle + admission queue with backpressure (``request``),
  * snapshot/rollback of whole slots in O(d²) (``pool.StatePool.
    snapshot/restore``) — the primitive the speculative-generation
    subsystem (``repro.spec``, ``EngineConfig.speculate_k``) builds on,
  * a shared-prefix state cache (``prefix_cache.PrefixCache``,
    ``EngineConfig.prefix_cache_mb``): a radix trie over prompt chunks
    whose entries are those same constant-size snapshots, so repeated
    system prompts resume from cached state instead of re-prefilling,

tied together by ``engine.Engine``. See docs/serving.md.
"""

from repro.serve.engine import Engine, EngineConfig
from repro.serve.prefix_cache import CacheEntry, PrefixCache
from repro.serve.request import (AdmissionQueue, QueueFullError, Request,
                                 Sequence, SequenceStatus, TokenEvent)
from repro.serve.scheduler import EngineStats, Scheduler, StepMetrics

__all__ = [
    "Engine", "EngineConfig",
    "AdmissionQueue", "QueueFullError", "Request", "Sequence",
    "SequenceStatus", "TokenEvent",
    "EngineStats", "Scheduler", "StepMetrics",
    "PrefixCache", "CacheEntry",
]
