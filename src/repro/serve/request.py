"""Request / sequence lifecycle types and the admission queue.

A ``Request`` is what a client submits; a ``Sequence`` is the engine's
mutable bookkeeping around it (status, slot, private prefill cache,
generated tokens, timing). The ``AdmissionQueue`` is the front door:
bounded, FIFO, and it *rejects* on overflow (backpressure surfaces to
the caller instead of growing memory unboundedly).
"""

from __future__ import annotations

import enum
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Sequence as Seq


class QueueFullError(RuntimeError):
    """Admission queue at capacity — caller must retry or shed load."""


class SequenceStatus(enum.Enum):
    WAITING = "waiting"          # in the admission queue
    PREFILLING = "prefilling"    # absorbing prompt chunks
    DECODING = "decoding"        # in the batched decode loop
    FINISHED = "finished"


@dataclass
class Request:
    """One generation request. ``prompt``: token ids.

    Sampling is per-request, not engine-global: ``temperature=None``
    inherits the engine default (``EngineConfig.temperature``), any
    other value pins this request. ``top_k``/``top_p`` restrict the
    sampled support (0 / 1.0 = off); both compose (top-k filter first,
    then nucleus). Greedy requests (effective temperature <= 0) are the
    ones speculative decoding accepts drafts for — sampled requests
    still flow through a speculative step but draw from the verify
    logits' first position (see docs/serving.md).
    """
    request_id: str
    prompt: Seq[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    temperature: float | None = None   # None = engine default
    top_k: int = 0                     # 0 = no top-k cut
    top_p: float = 1.0                 # 1.0 = no nucleus cut

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")


@dataclass
class TokenEvent:
    """One streamed token. ``first`` marks the TTFT token."""
    request_id: str
    token: int
    index: int                   # 0-based position in the generation
    first: bool = False
    finished: bool = False


@dataclass
class Sequence:
    """Engine-side state of one request."""
    request: Request
    status: SequenceStatus = SequenceStatus.WAITING
    slot: int | None = None
    out_tokens: list[int] = field(default_factory=list)
    # chunked-prefill bookkeeping (set on admission)
    cache: object = None         # private batch=1 cache during prefill
    #   (None when pool_resident: state lives in the slot pool instead)
    pool_resident: bool = False  # prefilling directly in the pool slot
    #   (batched multi-slot prefill — engine seeds the slot at admission)
    chunks: list[int] = field(default_factory=list)
    chunk_idx: int = 0
    consumed: int = 0            # prompt tokens absorbed so far
    cached_tokens: int = 0       # of which served by the prefix cache
    last_logits: object = None   # (1, C, V) logits of the latest chunk
    # timing
    t_submit: float = field(default_factory=time.perf_counter)
    t_first_token: float | None = None
    t_last_token: float | None = None   # latest emitted token (ITL base)
    t_finish: float | None = None
    itls: list[float] = field(default_factory=list)  # per-request
    #   inter-token latencies (gap between consecutive emitted tokens)

    @property
    def request_id(self) -> str:
        return self.request.request_id

    @property
    def prefill_done(self) -> bool:
        return self.consumed >= len(self.request.prompt)

    @property
    def next_chunk(self) -> int:
        return self.chunks[self.chunk_idx]

    @property
    def next_token(self) -> int:
        """Token to feed the next decode step (last generated)."""
        return self.out_tokens[-1]

    @property
    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit


class AdmissionQueue:
    """Bounded FIFO of submitted-but-unscheduled sequences."""

    def __init__(self, max_size: int):
        if max_size < 1:
            raise ValueError("max_size must be >= 1")
        self.max_size = max_size
        self._q: deque[Sequence] = deque()

    @property
    def depth(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.max_size

    def push(self, seq: Sequence) -> None:
        if self.full:
            raise QueueFullError(
                f"admission queue full ({self.max_size}); retry later")
        self._q.append(seq)

    def pop(self) -> Sequence:
        return self._q.popleft()
