"""Shared-prefix state cache: a radix trie over prompt-token chunks.

TaylorShift's constant-size attention state (PAPER.md §3.2) turns
prefix reuse — the workhorse of production serving under heavy
shared-system-prompt traffic — into a cheap pytree copy. A cached
prompt prefix is a fixed ``O(layers · d²)`` snapshot of the chunked
prefill state (plus ``pos``/TaylorState ``n`` counters), not an
``O(N)`` paged-KV region, so "resume from the longest cached prefix"
degenerates to *start prefill from a different initial cache*. With
``cache_kind="kv"`` (the "and Back" regime below the N1 crossover)
entries hold the prefix's KV blocks instead — still one snapshot, but
sized by ``cache_len``; the byte budget treats both honestly.

Why keys are whole ``chunk_tokens``-sized chunks, not arbitrary token
prefixes: bit-identity. ``prefill.plan_chunks(P, C)`` always emits the
full ``C``-sized chunks first, so every cached boundary sits on the
``k·C`` grid, and the suffix plan after a hit — ``plan_chunks(P - k·C,
C)`` — has exactly the chunk shapes the cold plan has after the same
boundary. Same chunks + same immutable snapshot = the same float ops in
the same order, so a cache-hit stream equals the cold-prefill stream
token for token (``tests/test_prefix_cache.py`` pins this for greedy
and seeded sampling, speculation on and off, both cache kinds).

Aliasing discipline: entries are references to jax arrays, which are
immutable — an entry can never observe a later pool mutation, a
speculative rollback, or another sequence resuming from the same node.
Two sequences resuming from one entry each functionally update their
own copies from the first suffix chunk on. ``insert`` therefore never
copies, and a hit costs zero device work.

Eviction is LRU under a byte budget: every lookup/insert touches the
node; when ``bytes > budget`` the stalest *entries* are dropped (and
childless interior nodes pruned) until the budget holds. Metrics (hits,
misses, reused tokens, evictions, bytes) live in the cache's own
``obs.metrics.MetricsRegistry`` — lifetime-scoped, surviving
``Engine.reset_metrics`` exactly like the cached state does — and
surface through ``Engine`` into
``EngineStats.summary()["prefix_cache"]`` (with a ``since_reset``
sub-dict re-based on the last reset) and the Prometheus exposition.
"""

from __future__ import annotations

import zlib
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Iterator, Sequence as Seq

import jax
import numpy as np

from repro.obs.metrics import MetricsRegistry


def tree_nbytes(tree) -> int:
    """Total bytes of every array leaf in a pytree."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


def chunk_hash_chain(chunks: Seq[tuple[int, ...]]) -> list[int]:
    """Rolling crc32 over a chunk sequence: ``out[k]`` identifies the
    path ``chunks[:k+1]`` as one integer. This is what a replica
    *advertises* instead of its raw trie (``PrefixCache.summary``) and
    what the router scores prompts with (``serve/router.py``) — a
    collision can only misroute a request (a perf wobble), never change
    its tokens, since the landing replica's own trie does the real
    token-exact lookup."""
    out, h = [], 0
    for c in chunks:
        h = zlib.crc32(np.asarray(c, np.int64).tobytes(), h)
        out.append(h)
    return out


@dataclass
class CacheEntry:
    """One cached prefix boundary.

    ``state`` is the single-sequence (batch=1) decode cache exactly as
    ``prefill_chunk`` returned it at the boundary — Taylor prefix sums
    or KV blocks plus the position counter, immutable and shared by
    reference. ``logits`` is the boundary chunk's last-position row
    ``(1, 1, vocab)``: when an entry covers a whole prompt, the engine
    samples the first token from it without running any model call.
    ``n_tokens`` is the boundary position (a multiple of the cache's
    chunk size); ``nbytes`` is what the entry charges the budget.
    """
    state: object
    logits: object
    n_tokens: int
    nbytes: int


class _Node:
    """Radix-trie node. Children are keyed by the next chunk's token
    tuple; ``entry`` (if set) caches the state at this node's depth."""

    __slots__ = ("children", "entry", "parent", "edge")

    def __init__(self, parent: "_Node | None" = None,
                 edge: tuple[int, ...] | None = None):
        self.children: dict[tuple[int, ...], _Node] = {}
        self.entry: CacheEntry | None = None
        self.parent = parent
        self.edge = edge


class _CacheMetrics:
    """The cache's lifetime counters, registered in a
    ``MetricsRegistry`` (the migration target of the old ``CacheStats``
    dataclass): hits/misses/reuse as ``prefix_cache_*_total`` counters,
    resident bytes/entries as gauges. ``as_dict()`` keeps the exact key
    set ``PrefixCache.stats()`` has always returned."""

    _COUNTERS = {
        "lookups": "prefix-cache lookups",
        "hits": "lookups that found a usable entry",
        "misses": "lookups that found nothing",
        "hit_tokens": "prompt tokens served from cache",
        "lookup_tokens": "prompt tokens offered to lookups",
        "inserts": "new entries stored",
        "duplicate_inserts": "boundary already cached (touch only)",
        "evictions": "entries dropped by LRU/budget",
        "partial_hits": "hits served by truncating a kv entry",
        "truncated_tokens": "kv rows discarded by partial-hit truncation",
    }
    _GAUGES = {
        "bytes": "current resident entry bytes",
        "entries": "current resident entries",
    }

    def __init__(self, registry: MetricsRegistry):
        self.registry = registry
        self._c = {k: registry.counter(f"prefix_cache_{k}_total", h)
                   for k, h in self._COUNTERS.items()}
        self._g = {k: registry.gauge(f"prefix_cache_{k}", h)
                   for k, h in self._GAUGES.items()}

    def inc(self, key: str, amount: int = 1) -> None:
        self._c[key].inc(amount)

    def add(self, key: str, amount: float) -> None:
        self._g[key].inc(amount)

    def __getitem__(self, key: str) -> int:
        m = self._c.get(key) or self._g[key]
        return int(m.value)

    def as_dict(self) -> dict:
        d = {k: int(m.value) for k, m in self._c.items()}
        d.update({k: int(m.value) for k, m in self._g.items()})
        d["hit_rate"] = (d["hits"] / d["lookups"] if d["lookups"]
                         else 0.0)
        d["token_reuse"] = (d["hit_tokens"] / d["lookup_tokens"]
                            if d["lookup_tokens"] else 0.0)
        return d


class PrefixCache:
    """Radix-trie prefix cache over chunked-prefill state snapshots.

    Contract: ``lookup(prompt)`` returns the deepest cached boundary on
    the ``chunk_tokens`` grid that is a prefix of ``prompt`` (the whole
    prompt included — full hits sample from the stored boundary
    logits), or ``None``. ``insert(prompt, n_tokens, state, logits)``
    records the snapshot at boundary ``n_tokens`` — a no-op unless the
    boundary is a positive multiple of ``chunk_tokens`` (off-grid
    boundaries come from power-of-two tail chunks, whose shapes a later
    cold plan would not reproduce; caching them would break
    bit-identity). Entries are immutable once stored: a duplicate
    insert only refreshes LRU recency, so concurrent sequences always
    observe one canonical state per boundary.

    ``budget_bytes <= 0`` disables the budget (unbounded);
    ``max_entries`` (0 = unbounded) bounds the entry count
    independently — useful when Taylor entries are so small the byte
    budget alone would let the trie grow wide.

    ``kv_partial`` (kv caches only): kv rows are positionally
    addressed, so an entry whose prompt shares only the first ``m``
    tokens with a new prompt is still usable after clamping its
    position counters to ``m`` (``models.model.cache_truncate``) — the
    attend masks rows at ``index >= pos`` with exact zeros, so the
    stale tail is unobservable and the resumed stream stays
    bit-identical to a cold prefill. Partial hits return an
    *ephemeral* ``CacheEntry`` (``logits=None``, ``n_tokens=m`` capped
    at ``len(prompt) - 1`` so at least the final prompt token — whose
    boundary logits no entry holds — is re-run); nothing new is
    stored. Taylor states are running sums, not positional rows — the
    flag must stay off for them (the engine gates it on the pool's
    cache kind).
    """

    def __init__(self, chunk_tokens: int, budget_bytes: int = 0,
                 max_entries: int = 0,
                 registry: MetricsRegistry | None = None,
                 kv_partial: bool = False):
        if chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1")
        self.chunk_tokens = chunk_tokens
        self.budget_bytes = budget_bytes
        self.max_entries = max_entries
        self.kv_partial = kv_partial
        self.root = _Node()
        self._lru: OrderedDict[_Node, None] = OrderedDict()
        # lifetime-scoped registry (NOT the engine's resettable stats
        # registry): cache counters live exactly as long as the cached
        # state they describe
        self.registry = registry or MetricsRegistry()
        self.stats_ = _CacheMetrics(self.registry)

    # -- trie walk ----------------------------------------------------------

    def _chunks(self, prompt: Seq[int]) -> list[tuple[int, ...]]:
        C = self.chunk_tokens
        return [tuple(int(t) for t in prompt[i:i + C])
                for i in range(0, (len(prompt) // C) * C, C)]

    def lookup(self, prompt: Seq[int]) -> CacheEntry | None:
        """Longest cached prefix of ``prompt`` on the chunk grid —
        extended past the grid by truncating a kv entry when
        ``kv_partial`` (deepest match wins either way)."""
        self.stats_.inc("lookups")
        self.stats_.inc("lookup_tokens", len(prompt))
        node, best, depth = self.root, None, 0
        for key in self._chunks(prompt):
            nxt = node.children.get(key)
            if nxt is None:
                break
            node = nxt
            depth += 1
            if node.entry is not None:
                best = node
        if self.kv_partial:
            part = self._partial_entry(
                prompt, node, depth,
                best.entry.n_tokens if best is not None else 0)
            if part is not None:
                return part
        if best is None:
            self.stats_.inc("misses")
            return None
        self._touch(best)
        self.stats_.inc("hits")
        self.stats_.inc("hit_tokens", best.entry.n_tokens)
        return best.entry

    def _partial_entry(self, prompt: Seq[int], node: _Node, depth: int,
                       best_n: int) -> CacheEntry | None:
        """Partial-prefix hit off the chunk grid: the exact walk stopped
        at ``node`` (``depth`` chunks matched); find the child edge
        sharing the longest token prefix with the remaining prompt and
        truncate any entry below it to the match depth ``m``. Every
        entry under that edge absorbed the same first ``m`` tokens, so
        its kv rows ``[0, m)`` are exactly the rows a cold prefill of
        ``prompt[:m]`` would write — the clamped-counter resume is
        bit-identical. Only taken when it beats the best exact hit."""
        base = depth * self.chunk_tokens
        rest = [int(t) for t in prompt[base:]]
        child, best_extra = None, 0
        for edge, ch in node.children.items():
            m = 0
            for a, b in zip(edge, rest):
                if a != b:
                    break
                m += 1
            if m > best_extra:
                child, best_extra = ch, m
        if child is None:
            return None
        m = min(base + best_extra, len(prompt) - 1)
        if m <= base or m <= best_n:
            return None
        holder = self._subtree_entry(child)
        if holder is None:
            return None
        from repro.models.model import cache_truncate
        self._touch(holder)
        self.stats_.inc("hits")
        self.stats_.inc("hit_tokens", m)
        self.stats_.inc("partial_hits")
        self.stats_.inc("truncated_tokens", holder.entry.n_tokens - m)
        return CacheEntry(state=cache_truncate(holder.entry.state, m),
                          logits=None, n_tokens=m,
                          nbytes=holder.entry.nbytes)

    @staticmethod
    def _subtree_entry(node: _Node) -> _Node | None:
        """Shallowest entry-holding node under ``node`` (BFS — less
        truncation waste than a deep one; any entry would be correct)."""
        q = deque([node])
        while q:
            n = q.popleft()
            if n.entry is not None:
                return n
            q.extend(n.children.values())
        return None

    def insert(self, prompt: Seq[int], n_tokens: int, state, logits) -> bool:
        """Cache the prefill state at boundary ``n_tokens``. Returns
        True when a new entry was stored."""
        C = self.chunk_tokens
        if n_tokens < C or n_tokens % C or n_tokens > len(prompt):
            return False
        nbytes = tree_nbytes(state) + tree_nbytes(logits)
        if self.budget_bytes > 0 and nbytes > self.budget_bytes:
            return False          # one entry alone would bust the budget —
            #   refused BEFORE building path nodes, so hopeless inserts
            #   (every prompt, forever) never leak trie skeleton
        node = self.root
        for key in self._chunks(prompt[:n_tokens]):
            nxt = node.children.get(key)
            if nxt is None:
                nxt = node.children[key] = _Node(node, key)
            node = nxt
        if node.entry is not None:
            self.stats_.inc("duplicate_inserts")
            self._touch(node)
            return False
        node.entry = CacheEntry(state=state, logits=logits,
                                n_tokens=n_tokens, nbytes=nbytes)
        self._lru[node] = None
        self.stats_.inc("inserts")
        self.stats_.add("entries", 1)
        self.stats_.add("bytes", nbytes)
        self._evict(keep=node)
        return True

    # -- LRU / eviction -----------------------------------------------------

    def _touch(self, node: _Node) -> None:
        self._lru.move_to_end(node)

    def _over_budget(self) -> bool:
        if self.budget_bytes > 0 and self.stats_["bytes"] > self.budget_bytes:
            return True
        return bool(self.max_entries
                    and self.stats_["entries"] > self.max_entries)

    def _evict(self, keep: _Node | None = None) -> None:
        while self._over_budget():
            victim = next((n for n in self._lru if n is not keep), None)
            if victim is None:    # only the just-inserted entry remains
                break
            del self._lru[victim]
            self._drop(victim)

    def _drop(self, node: _Node) -> None:
        self.stats_.add("bytes", -node.entry.nbytes)
        self.stats_.add("entries", -1)
        self.stats_.inc("evictions")
        node.entry = None
        # prune entry-less leaf chains so the trie doesn't accumulate
        # skeleton paths for evicted prefixes
        while (node.parent is not None and not node.children
               and node.entry is None):
            del node.parent.children[node.edge]
            node = node.parent

    # -- fleet surface (serve/router.py + serve/wire.py) --------------------

    def entries(self) -> Iterator[tuple[list[int], CacheEntry]]:
        """Every resident entry as ``(path_tokens, entry)`` — the full
        token path from the root, which is exactly the prompt prefix the
        entry caches (``len(path) == entry.n_tokens``)."""
        stack = [(self.root, ())]
        while stack:
            node, path = stack.pop()
            if node.entry is not None:
                yield list(path), node.entry
            for edge, ch in node.children.items():
                stack.append((ch, path + edge))

    def summary(self) -> dict:
        """The advertised trie: ``{"chunk_tokens": C, "boundaries":
        {chain_hash: n_tokens}}`` — a few ints per entry instead of
        O(layers·d²) state, cheap enough to gossip to a router every
        step. Hashes come from :func:`chunk_hash_chain` over each
        entry's path."""
        boundaries: dict[int, int] = {}
        stack = [(self.root, 0)]
        while stack:
            node, h = stack.pop()
            if node.entry is not None:
                boundaries[h] = node.entry.n_tokens
            for edge, ch in node.children.items():
                stack.append(
                    (ch, zlib.crc32(np.asarray(edge, np.int64).tobytes(), h)))
        return {"chunk_tokens": self.chunk_tokens, "boundaries": boundaries}

    def export_entries(self, max_entries: int = 0) -> list[bytes]:
        """Serialize resident entries (most-recently-used first, capped
        at ``max_entries`` when > 0) into ``repro.state/v1`` blobs a
        peer's :meth:`import_entries` can warm from."""
        from repro.serve import wire
        order = {id(n.entry): i for i, n in enumerate(reversed(self._lru))}
        pairs = sorted(self.entries(),
                       key=lambda te: order.get(id(te[1]), len(order)))
        if max_entries > 0:
            pairs = pairs[:max_entries]
        return [wire.encode_trie_entry(toks, e.n_tokens, e.state, e.logits)
                for toks, e in pairs]

    def import_entries(self, blobs: Seq[bytes]) -> int:
        """Warm this trie from a peer's exported entries; returns how
        many were stored. Every blob passes the full wire integrity
        check, and ``insert`` applies the same grid/budget discipline as
        local inserts — an off-grid boundary (peer with a different
        chunk size) is refused, never bent onto this grid."""
        from repro.serve import wire
        n = 0
        for blob in blobs:
            toks, n_tokens, state, logits = wire.decode_trie_entry(blob)
            if n_tokens != len(toks):
                raise wire.WireError(
                    f"trie blob path {len(toks)} tokens != boundary "
                    f"{n_tokens}")
            n += bool(self.insert(toks, n_tokens, state, logits))
        return n

    # -- introspection ------------------------------------------------------

    def stats(self) -> dict:
        return self.stats_.as_dict()

    def clear(self) -> None:
        """Drop every entry (metrics keep accumulating)."""
        self.root = _Node()
        self._lru.clear()
        self.stats_.add("bytes", -self.stats_["bytes"])
        self.stats_.add("entries", -self.stats_["entries"])
