"""Chunked prefill planning and execution.

Prompts are absorbed through ``models.model.prefill_chunk`` — one
full-sequence forward per chunk with TaylorState handoff
(``causal_taylorshift(initial_state=..., return_state=True)``) — instead
of the old token-by-token teacher-forced loop. A prompt of length P
costs ceil(P / chunk) jitted calls at full arithmetic intensity rather
than P single-token calls.

Chunk planning: fixed-size chunks while the remainder allows, then a
*power-of-two decomposition* of the tail. jax retraces per distinct
chunk length, so this bounds the number of compiled prefill shapes to
log2(chunk) + 1 across every prompt length ever seen.

Shared-prefix resume (``prefix_cache.PrefixCache``): ``start_prefill``
seeds the private cache from the longest cached prefix on the
full-chunk grid and plans chunks only for the un-cached suffix — which
is exactly the tail of the cold plan, so the resumed stream is
bit-identical to a cold prefill. ``advance_prefill`` inserts each
completed full-chunk boundary (state snapshot + the chunk's last-row
logits) back into the trie; power-of-two tail chunks land off-grid and
are never cached.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.serve.prefix_cache import PrefixCache
from repro.serve.request import Sequence


def plan_chunks(prompt_len: int, chunk: int) -> list[int]:
    """Split ``prompt_len`` into jit-friendly chunk sizes."""
    if prompt_len < 1:
        raise ValueError("prompt_len must be >= 1")
    chunk = max(1, chunk)
    out = [chunk] * (prompt_len // chunk)
    rest = prompt_len % chunk
    bit = 1 << max(rest.bit_length() - 1, 0)
    while rest:
        if rest >= bit:
            out.append(bit)
            rest -= bit
        bit >>= 1
    return out


def start_prefill(seq: Sequence, pool, prefill_chunk: int,
                  prefix_cache: PrefixCache | None = None, *,
                  pool_resident: bool = False) -> None:
    """Attach a cache and a chunk plan to a just-admitted sequence.

    With a prefix cache, the longest cached prefix of the prompt seeds
    ``seq.cache`` (zero-copy — the snapshot is immutable) and only the
    suffix is planned; a full-prompt hit leaves an empty plan and
    restores the boundary logits so the engine can emit the first token
    without any prefill dispatch.

    ``pool_resident`` (batched multi-slot prefill): the sequence
    prefills directly in its pool slot instead of a private cache —
    a cold start needs no seeding at all (released slots are
    zero-reset, exactly the fresh-cache state). ``seq.cache`` stays
    ``None``. Prefix *hits* opt out and resume on the private path
    even when the engine batches: the cached snapshot seeds
    ``seq.cache`` zero-copy (a slot scatter is a real dispatch that
    would land squarely on TTFT), the short resumed suffix runs the
    cheapest per-chunk dispatch, and the state reaches the pool once,
    at decode start — exactly the cold-path cost profile the cache is
    supposed to beat.
    """
    hit = prefix_cache.lookup(seq.request.prompt) if prefix_cache else None
    seq.pool_resident = pool_resident and hit is None
    if hit is not None:
        seq.consumed = seq.cached_tokens = hit.n_tokens
        rest = len(seq.request.prompt) - hit.n_tokens
        seq.chunks = plan_chunks(rest, prefill_chunk) if rest else []
        if not rest:              # full-prompt hit: boundary logits are
            seq.last_logits = hit.logits   # the prompt's next-token row
        seq.cache = hit.state
    else:
        seq.cache = None if pool_resident else pool.new_sequence_cache()
        seq.chunks = plan_chunks(len(seq.request.prompt), prefill_chunk)
        seq.consumed = 0
        seq.cached_tokens = 0
    seq.chunk_idx = 0


def advance_prefill(seq: Sequence, prefill_fn,
                    prefix_cache: PrefixCache | None = None) -> int:
    """Run the sequence's next prompt chunk. Returns tokens consumed.

    ``prefill_fn(tokens (1, C) int32, cache) -> (logits, cache)`` — the
    engine's jitted closure over ``model.prefill_from_state``. Completed
    boundaries that land on the full-chunk grid are inserted into
    ``prefix_cache`` (the returned cache pytree *is* the snapshot; jax
    immutability makes the share safe).
    """
    c = seq.next_chunk
    lo = seq.consumed
    toks = jnp.asarray([seq.request.prompt[lo:lo + c]], jnp.int32)
    seq.last_logits, seq.cache = prefill_fn(toks, seq.cache)
    seq.chunk_idx += 1
    seq.consumed += c
    if prefix_cache is not None and c == prefix_cache.chunk_tokens:
        prefix_cache.insert(seq.request.prompt, seq.consumed, seq.cache,
                            seq.last_logits[:, -1:])
    return c


def advance_prefill_batch(group: list[Sequence], pool, pool_prefill_fn,
                          prefix_cache: PrefixCache | None = None,
                          slot_prefill_fn=None) -> int:
    """Run one same-length prompt chunk for every sequence in ``group``
    as a single pool-level dispatch. Returns tokens consumed.

    ``pool_prefill_fn(tokens (slots, C) int32, mask (slots,) bool,
    pool_cache) -> (logits, pool_cache)`` — the engine's jitted closure
    over ``model.prefill_slots``. The dispatch always covers the full
    slot batch (fixed shapes, no recompiles as group size varies);
    non-member slots compute on zero tokens and keep their state
    bit-exactly via the mask merge.

    A *singleton* group takes ``slot_prefill_fn(tokens (1, C),
    pool_cache, slot) -> (logits, pool_cache, seq_state)`` instead:
    the full-batch dispatch would burn ``n_slots×`` the FLOPs of the
    one chunk that matters — on a compute-bound host that waste dwarfs
    the dispatch saving the pooled path exists for. The engine fuses
    the gather -> batch-1 prefill -> scatter round trip into one jit,
    so a singleton chunk costs exactly one dispatch, like the
    private-cache path. The gathered sub-cache keeps its per-slot
    ``(1,)`` counters, so the same verify body runs at batch 1 — rows
    are computationally independent, so both paths stay bit-identical
    to the scalar prefill. ``seq_state`` is the slot's post-chunk state
    already normalized to the canonical single-sequence layout, ready
    for a prefix-cache insert.

    Full-chunk-grid boundaries are inserted into ``prefix_cache`` in
    the canonical single-sequence layout (``cache_slot_to_sequence``),
    so pooled and per-sequence prefill build interchangeable entries.
    """
    c = group[0].next_chunk
    if len(group) == 1 and slot_prefill_fn is not None:
        s = group[0]
        lo = s.consumed
        toks = jnp.asarray([s.request.prompt[lo:lo + c]], jnp.int32)
        logits, pool.cache, state = slot_prefill_fn(toks, pool.cache,
                                                    s.slot)
        s.last_logits = logits
        s.chunk_idx += 1
        s.consumed += c
        if prefix_cache is not None and c == prefix_cache.chunk_tokens:
            prefix_cache.insert(s.request.prompt, s.consumed, state,
                                s.last_logits[:, -1:])
        return c
    toks = np.zeros((pool.n_slots, c), np.int32)
    mask = np.zeros((pool.n_slots,), bool)
    for s in group:
        lo = s.consumed
        toks[s.slot] = s.request.prompt[lo:lo + c]
        mask[s.slot] = True
    logits, pool.cache = pool_prefill_fn(
        jnp.asarray(toks), jnp.asarray(mask), pool.cache)
    for s in group:
        s.last_logits = logits[s.slot:s.slot + 1]
        s.chunk_idx += 1
        s.consumed += c
        if prefix_cache is not None and c == prefix_cache.chunk_tokens:
            state = M.cache_slot_to_sequence(pool.gather(s.slot))
            prefix_cache.insert(s.request.prompt, s.consumed, state,
                                s.last_logits[:, -1:])
    return c * len(group)
