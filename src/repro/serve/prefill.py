"""Chunked prefill planning and execution.

Prompts are absorbed through ``models.model.prefill_chunk`` — one
full-sequence forward per chunk with TaylorState handoff
(``causal_taylorshift(initial_state=..., return_state=True)``) — instead
of the old token-by-token teacher-forced loop. A prompt of length P
costs ceil(P / chunk) jitted calls at full arithmetic intensity rather
than P single-token calls.

Chunk planning: fixed-size chunks while the remainder allows, then a
*power-of-two decomposition* of the tail. jax retraces per distinct
chunk length, so this bounds the number of compiled prefill shapes to
log2(chunk) + 1 across every prompt length ever seen.

Shared-prefix resume (``prefix_cache.PrefixCache``): ``start_prefill``
seeds the private cache from the longest cached prefix on the
full-chunk grid and plans chunks only for the un-cached suffix — which
is exactly the tail of the cold plan, so the resumed stream is
bit-identical to a cold prefill. ``advance_prefill`` inserts each
completed full-chunk boundary (state snapshot + the chunk's last-row
logits) back into the trie; power-of-two tail chunks land off-grid and
are never cached.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.serve.prefix_cache import PrefixCache
from repro.serve.request import Sequence


def plan_chunks(prompt_len: int, chunk: int) -> list[int]:
    """Split ``prompt_len`` into jit-friendly chunk sizes."""
    if prompt_len < 1:
        raise ValueError("prompt_len must be >= 1")
    chunk = max(1, chunk)
    out = [chunk] * (prompt_len // chunk)
    rest = prompt_len % chunk
    bit = 1 << max(rest.bit_length() - 1, 0)
    while rest:
        if rest >= bit:
            out.append(bit)
            rest -= bit
        bit >>= 1
    return out


def start_prefill(seq: Sequence, pool, prefill_chunk: int,
                  prefix_cache: PrefixCache | None = None) -> None:
    """Attach a cache and a chunk plan to a just-admitted sequence.

    With a prefix cache, the longest cached prefix of the prompt seeds
    ``seq.cache`` (zero-copy — the snapshot is immutable) and only the
    suffix is planned; a full-prompt hit leaves an empty plan and
    restores the boundary logits so the engine can emit the first token
    without any prefill dispatch.
    """
    hit = prefix_cache.lookup(seq.request.prompt) if prefix_cache else None
    if hit is not None:
        seq.cache = hit.state
        seq.consumed = seq.cached_tokens = hit.n_tokens
        rest = len(seq.request.prompt) - hit.n_tokens
        seq.chunks = plan_chunks(rest, prefill_chunk) if rest else []
        if not rest:              # full-prompt hit: boundary logits are
            seq.last_logits = hit.logits   # the prompt's next-token row
    else:
        seq.cache = pool.new_sequence_cache()
        seq.chunks = plan_chunks(len(seq.request.prompt), prefill_chunk)
        seq.consumed = 0
        seq.cached_tokens = 0
    seq.chunk_idx = 0


def advance_prefill(seq: Sequence, prefill_fn,
                    prefix_cache: PrefixCache | None = None) -> int:
    """Run the sequence's next prompt chunk. Returns tokens consumed.

    ``prefill_fn(tokens (1, C) int32, cache) -> (logits, cache)`` — the
    engine's jitted closure over ``model.prefill_from_state``. Completed
    boundaries that land on the full-chunk grid are inserted into
    ``prefix_cache`` (the returned cache pytree *is* the snapshot; jax
    immutability makes the share safe).
    """
    c = seq.next_chunk
    lo = seq.consumed
    toks = jnp.asarray([seq.request.prompt[lo:lo + c]], jnp.int32)
    seq.last_logits, seq.cache = prefill_fn(toks, seq.cache)
    seq.chunk_idx += 1
    seq.consumed += c
    if prefix_cache is not None and c == prefix_cache.chunk_tokens:
        prefix_cache.insert(seq.request.prompt, seq.consumed, seq.cache,
                            seq.last_logits[:, -1:])
    return c
