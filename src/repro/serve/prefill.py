"""Chunked prefill planning and execution.

Prompts are absorbed through ``models.model.prefill_chunk`` — one
full-sequence forward per chunk with TaylorState handoff
(``causal_taylorshift(initial_state=..., return_state=True)``) — instead
of the old token-by-token teacher-forced loop. A prompt of length P
costs ceil(P / chunk) jitted calls at full arithmetic intensity rather
than P single-token calls.

Chunk planning: fixed-size chunks while the remainder allows, then a
*power-of-two decomposition* of the tail. jax retraces per distinct
chunk length, so this bounds the number of compiled prefill shapes to
log2(chunk) + 1 across every prompt length ever seen.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.serve.request import Sequence


def plan_chunks(prompt_len: int, chunk: int) -> list[int]:
    """Split ``prompt_len`` into jit-friendly chunk sizes."""
    if prompt_len < 1:
        raise ValueError("prompt_len must be >= 1")
    chunk = max(1, chunk)
    out = [chunk] * (prompt_len // chunk)
    rest = prompt_len % chunk
    bit = 1 << max(rest.bit_length() - 1, 0)
    while rest:
        if rest >= bit:
            out.append(bit)
            rest -= bit
        bit >>= 1
    return out


def start_prefill(seq: Sequence, pool, prefill_chunk: int) -> None:
    """Attach a private cache and a chunk plan to a just-admitted
    sequence."""
    seq.cache = pool.new_sequence_cache()
    seq.chunks = plan_chunks(len(seq.request.prompt), prefill_chunk)
    seq.chunk_idx = 0
    seq.consumed = 0


def advance_prefill(seq: Sequence, prefill_fn) -> int:
    """Run the sequence's next prompt chunk. Returns tokens consumed.

    ``prefill_fn(tokens (1, C) int32, cache) -> (logits, cache)`` — the
    engine's jitted closure over ``model.prefill_chunk``.
    """
    c = seq.next_chunk
    lo = seq.consumed
    toks = jnp.asarray([seq.request.prompt[lo:lo + c]], jnp.int32)
    seq.last_logits, seq.cache = prefill_fn(toks, seq.cache)
    seq.chunk_idx += 1
    seq.consumed += c
    return c
