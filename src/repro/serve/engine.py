"""The continuous-batching inference engine.

Fixed shapes everywhere: decode always runs the full slot batch
(inactive slots compute on throwaway state and are ignored), prefill
runs per-sequence at a bounded set of chunk lengths — so after warmup
no step ever recompiles. Sequences at different context lengths share
decode batches thanks to the per-slot position counters
(``init_decode_state(per_slot=True)``).

Speculative decoding (``speculate_k > 0``, src/repro/spec/): instead of
one token per step, a drafter proposes k tokens per decoding slot, one
batched ``verify_chunk`` call scores all k+1 from each slot's current
Taylor state, and the longest argmax-matching prefix (plus one bonus
token) is emitted. Slots whose drafts are rejected roll back through
``StatePool.snapshot/restore`` — O(d²) regardless of context length —
and re-absorb just the accepted prefix. Greedy output is bit-identical
to the one-token-per-step engine; only throughput changes.

Typical use::

    eng = Engine(cfg, params, EngineConfig(n_slots=4))
    eng.submit(Request("a", prompt, max_new_tokens=16))
    for ev in eng.run():            # streams TokenEvents
        ...
    eng.results["a"].out_tokens
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, PrefixCacheConfig, SpecConfig
from repro.models import backend as B
from repro.models import model as M
from repro.models.model import PREFILL_KINDS
from repro.obs import metrics as OM
from repro.obs.trace import tracer
from repro.serve import prefill as PF
from repro.serve.pool import StatePool
from repro.serve.prefix_cache import PrefixCache
from repro.serve.request import (AdmissionQueue, Request, Sequence,
                                 SequenceStatus, TokenEvent)
from repro.serve.scheduler import EngineStats, Scheduler, StepMetrics


@dataclass
class EngineConfig:
    """Engine-level knobs; one instance per :class:`Engine`.

    Contract highlights: ``max_seq_len`` bounds prompt + generation per
    request (kv pools preallocate it; Taylor slots are size-invariant);
    ``token_budget`` is the per-step scheduled-token ceiling that
    decode, speculative drafts and prefill chunks all draw from;
    ``cache_kind="auto"`` resolves through the paper's N1 memory
    crossover (models/backend.py:select_serve_plan);
    ``prefix_cache_mb > 0`` enables the shared-prefix state cache
    (serve/prefix_cache.py) with that byte budget — hits charge only
    the un-cached suffix against the token budget and never change
    emitted tokens (bit-identical streams, cache on or off).
    """
    n_slots: int = 4             # max sequences decoding concurrently
    max_queue: int = 64          # admission backpressure threshold
    prefill_chunk: int = 128     # target prompt tokens per prefill call
    token_budget: int = 256      # scheduled tokens per engine step
    max_seq_len: int = 2048      # pool cache_len (kv caches only grow to this)
    cache_kind: str = "taylor"   # taylor | kv | auto ("and Back" via the
    #   N1 memory crossover — models/backend.py:select_serve_plan)
    temperature: float = 0.0     # default; Request.temperature overrides
    seed: int = 0
    speculate_k: int = 0         # max draft length; 0 = no speculation
    spec: SpecConfig = field(default_factory=SpecConfig)
    batch_prefill: bool = True   # pool-resident batched prefill: group
    #   same-chunk-length prefilling sequences into ONE pool-level
    #   dispatch per step (Taylor pools only — the per-slot body is
    #   bit-identical to the scalar one there; kv pools keep the
    #   per-sequence path)
    prefix_cache_mb: float = 0.0  # shared-prefix cache byte budget in MB
    #   (0 = cache off; <0 = on, unbounded)
    prefix: PrefixCacheConfig = field(default_factory=PrefixCacheConfig)
    replica_id: str | None = None  # fleet identity: ONE name threaded
    #   through obs snapshots, ft.Membership and the router (serve/
    #   router.py) — None = single-replica deployment


def _filter_logits(lg: jnp.ndarray, top_k: int, top_p: float) -> jnp.ndarray:
    """Apply top-k then nucleus (top-p) filtering to one logits row.

    top-k keeps the k largest logits; top-p keeps the smallest
    probability-sorted prefix whose cumulative mass reaches ``top_p``
    (the first token always survives, so sampling is never empty).
    Filtered entries go to -inf — ``jax.random.categorical`` assigns
    them zero probability.
    """
    if top_k > 0:
        kth = jnp.sort(lg)[-min(top_k, lg.shape[-1])]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    if top_p < 1.0:
        order = jnp.argsort(-lg)
        probs = jax.nn.softmax(lg[order])
        keep_sorted = jnp.cumsum(probs) - probs < top_p
        keep = jnp.zeros_like(keep_sorted).at[order].set(keep_sorted)
        lg = jnp.where(keep, lg, -jnp.inf)
    return lg


class Engine:
    """The continuous-batching engine: submit ``Request``s, drive
    ``step()``/``run()``, drain ``results``.

    Contract: emitted token streams are a pure function of (params,
    ModelConfig, Request, EngineConfig.temperature/seed) — independent
    of batching, arrival order, speculation (``speculate_k``) and the
    shared-prefix cache (``prefix_cache_mb``), all of which only move
    throughput and latency. Greedy streams are bit-identical across
    those knobs; sampled streams are reproducible per (seed,
    request_id, token index). All pool mutation happens inside
    ``step()``; snapshots handed out (speculative rollback, prefix-cache
    entries) are immutable jax pytrees and can never observe later
    engine state.
    """

    def __init__(self, cfg: ModelConfig, params, econf: EngineConfig | None = None):
        econf = econf or EngineConfig()
        bad = [k for k in cfg.layer_pattern if k not in PREFILL_KINDS]
        if bad or cfg.family == "encdec":
            raise NotImplementedError(
                "serve engine: chunked prefill supports global-attention "
                f"decoder architectures (pattern {tuple(cfg.layer_pattern)})")
        self.cfg = cfg
        self.econf = econf
        self.replica_id = econf.replica_id
        # One routing decision for the whole engine: cache layout
        # (resolving cache_kind="auto" through the paper's N1 memory
        # crossover) plus the prefill/decode path selections the
        # attention layers will re-derive identically at trace time.
        self.plan = B.select_serve_plan(
            cfg, max_seq_len=econf.max_seq_len,
            prefill_chunk=econf.prefill_chunk,
            cache_kind=econf.cache_kind,
            speculate_k=econf.speculate_k)
        # kv caches need k rows of headroom: a verify block written at the
        # final context position overshoots max_seq_len by up to k before
        # the rollback trims it (Taylor slots are size-invariant anyway)
        cache_len = econf.max_seq_len + max(econf.speculate_k, 0)
        self.pool = StatePool(cfg, econf.n_slots,
                              cache_len=cache_len,
                              cache_kind=self.plan.cache_kind)
        self.queue = AdmissionQueue(econf.max_queue)
        self.stats = EngineStats()
        self.scheduler = Scheduler(econf.token_budget)
        self.scheduler.bind_registry(self.stats.registry)
        # shared-prefix state cache: entries are immutable snapshots of
        # the chunked-prefill cache at full-chunk boundaries, so a hit
        # is a zero-copy resume (serve/prefix_cache.py). Keyed on the
        # engine's own prefill chunk — the granularity that keeps
        # cache-hit streams bit-identical to cold prefill.
        self.prefix_cache: PrefixCache | None = None
        if econf.prefix_cache_mb:
            if econf.prefix.chunk_tokens not in (0, econf.prefill_chunk):
                # any other granularity lets power-of-two *tail* chunks
                # land on the trie grid, and a hit would then resume
                # with a chunk decomposition no cold prefill runs —
                # breaking the bit-identity contract
                raise ValueError(
                    f"prefix.chunk_tokens={econf.prefix.chunk_tokens} "
                    f"must equal prefill_chunk={econf.prefill_chunk} "
                    "(or 0 to follow it)")
            budget = int(econf.prefix_cache_mb * 1024 * 1024) \
                if econf.prefix_cache_mb > 0 else 0
            self.prefix_cache = PrefixCache(
                econf.prefill_chunk,
                budget_bytes=budget, max_entries=econf.prefix.max_entries,
                # kv rows are positionally addressed, so entries can be
                # truncated to any matching token depth (partial-prefix
                # hits); Taylor prefix sums cannot
                kv_partial=(self.plan.cache_kind == "kv"))
        self.sequences: dict[str, Sequence] = {}
        self.results: dict[str, Sequence] = {}
        self._slots: list[Sequence | None] = [None] * econf.n_slots
        self._step_idx = 0
        self._rng = jax.random.PRNGKey(econf.seed)
        # params travel as a jit *argument* (not a closure capture) so
        # the weights aren't baked into the jaxpr as constants
        self._params = params
        # Pool-resident batched prefill is gated on the Taylor cache
        # kind: for Taylor states the per-slot-counter prefill body is
        # bit-identical to the scalar one (rows are computationally
        # independent), so pooling cannot change any stream; kv caches
        # attend over a different extent per body and stay per-sequence.
        self._batch_prefill = (econf.batch_prefill
                               and self.plan.cache_kind == "taylor")
        prefill_jit = jax.jit(
            lambda p, toks, cache: M.prefill_from_state(p, cfg,
                                                        {"tokens": toks},
                                                        cache))
        if self._batch_prefill:
            # Partially-prefilled state now lives in pool slots between
            # steps, so whole-pool writers (decode/verify) must merge
            # through a slot mask — unselected live slots keep their
            # state bit-exactly instead of absorbing throwaway tokens.
            def _masked(fn):
                def run(p, toks, mask, cache):
                    lg, nc = fn(p, cfg, {"tokens": toks}, cache)
                    return lg, M.cache_merge_slots(mask, nc, cache)
                return run
            decode_jit = jax.jit(_masked(M.decode_step))
            verify_jit = jax.jit(_masked(M.verify_chunk))
            pool_prefill_jit = jax.jit(
                lambda p, toks, mask, cache: M.prefill_slots(
                    p, cfg, {"tokens": toks}, cache, mask))
            self._pool_prefill_fn = lambda toks, mask, cache: \
                pool_prefill_jit(self._params, toks, mask, cache)
            # singleton groups bypass the full-batch dispatch: one
            # gathered slot, batch-1 per-slot body, same bits. The
            # gather -> prefill -> scatter round trip is fused into a
            # single jit (slot index is a traced argument) so a
            # singleton chunk costs exactly one dispatch, like the
            # private-cache path; the canonical sequence-layout state
            # comes back too, ready for a prefix-cache insert.
            def _slot_prefill(p, toks, cache, slot):
                sub = M.cache_gather_slot(cache, slot)
                logits, sub = M.prefill_from_state(
                    p, cfg, {"tokens": toks}, sub)
                return (logits, M.cache_scatter_slot(cache, sub, slot),
                        M.cache_slot_to_sequence(sub))
            slot_prefill_jit = jax.jit(_slot_prefill)
            self._slot_prefill_fn = lambda toks, cache, slot: \
                slot_prefill_jit(self._params, toks, cache, slot)
        else:
            decode_jit = jax.jit(
                lambda p, toks, mask, cache: M.decode_step(
                    p, cfg, {"tokens": toks}, cache))
            verify_jit = jax.jit(
                lambda p, toks, mask, cache: M.verify_chunk(
                    p, cfg, {"tokens": toks}, cache))
            self._pool_prefill_fn = None
            self._slot_prefill_fn = None
        rollback_jit = jax.jit(
            lambda p, cache, snap, slot, toks: M.verify_rollback(
                p, cfg, cache, snap, slot, {"tokens": toks}))
        self._prefill_fn = lambda toks, cache: prefill_jit(
            self._params, toks, cache)
        self._decode_fn = lambda toks, mask, cache: decode_jit(
            self._params, toks, mask, cache)
        self._verify_fn = lambda toks, mask, cache: verify_jit(
            self._params, toks, mask, cache)
        self._rollback_fn = lambda cache, snap, slot, toks: rollback_jit(
            self._params, cache, snap, slot, toks)
        # speculative machinery (lazy import: repro.spec builds on the
        # pool/prefill layers of this package)
        self.drafter = None
        self._controller = None
        if econf.speculate_k > 0:
            from repro.spec.controller import DraftController
            from repro.spec.drafter import make_drafter
            self.drafter = make_drafter(
                cfg, params, n_slots=econf.n_slots, cache_len=cache_len,
                cache_kind=self.plan.cache_kind, spec=econf.spec,
                prefill_chunk=econf.prefill_chunk)
            self._controller = DraftController(econf.speculate_k, econf.spec,
                                              registry=self.stats.registry)

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> Sequence:
        """Enqueue a request. Raises QueueFullError under backpressure."""
        if (request.request_id in self.sequences
                or request.request_id in self.results):
            raise ValueError(f"duplicate request_id {request.request_id!r}")
        if len(request.prompt) + request.max_new_tokens > self.econf.max_seq_len:
            raise ValueError("prompt + max_new_tokens exceeds max_seq_len")
        seq = Sequence(request=request)
        self.queue.push(seq)
        self.sequences[request.request_id] = seq
        return seq

    @property
    def idle(self) -> bool:
        return self.queue.depth == 0 and all(s is None for s in self._slots)

    @property
    def step_idx(self) -> int:
        """Number of scheduler steps taken (public: arrival schedules and
        tests key on this)."""
        return self._step_idx

    def reset_metrics(self) -> None:
        """Fresh ``EngineStats`` (with a fresh metrics registry) and
        draft controller. For warm/timed benchmark pairs: the adaptive
        controller's draft length follows its acceptance history, so
        without a reset the timed run would take a different k
        trajectory than the warmup (and recompile verify shapes
        mid-measurement).

        The prefix cache's lifetime registry is NOT reset (the counters
        describe state that survives); instead its current counter
        values become the baseline for the summary's
        ``prefix_cache.since_reset`` sub-dict, so post-reset summaries
        are self-consistent. Purely observational either way — resets
        never change emitted tokens."""
        self.stats = EngineStats()
        self.scheduler.bind_registry(self.stats.registry)
        if self.prefix_cache is not None:
            self.stats.prefix_cache_baseline = self.prefix_cache.stats()
        if self._controller is not None:
            from repro.spec.controller import DraftController
            self._controller = DraftController(self.econf.speculate_k,
                                               self.econf.spec,
                                               registry=self.stats.registry)

    def render_metrics(self) -> str:
        """Prometheus text exposition over every registry the engine
        owns: the resettable stats registry plus the prefix cache's
        lifetime registry (``launch/serve.py --metrics-file/-port``)."""
        regs = [self.stats.registry]
        if self.prefix_cache is not None:
            regs.append(self.prefix_cache.registry)
        return OM.render_all(*regs)

    def snapshot_metrics(self, *, replica: str | None = None) -> dict:
        """Versioned ``repro.obs/v1`` snapshot of every engine registry
        (``launch/serve.py --metrics-snapshot``). Unlike the rendered
        exposition this is mergeable: the fleet aggregator
        (``python -m repro.obs --merge-snapshots``) folds N replicas'
        snapshots into one exposition whose counters are the fleet sums
        and whose gauges keep a per-``replica`` label.

        ``replica`` defaults to ``EngineConfig.replica_id`` — the ONE
        identity the router, membership and obs agree on; the override
        exists for tooling that relabels snapshots after the fact."""
        from repro.obs import aggregate as OA
        regs = [self.stats.registry]
        if self.prefix_cache is not None:
            regs.append(self.prefix_cache.registry)
        if replica is None:
            replica = self.replica_id
        return OA.snapshot(*regs, replica=replica)

    def pop_result(self, request_id: str) -> Sequence:
        """Drain one finished sequence. ``results`` retains finished
        sequences until popped — long-running callers must drain (and may
        then reuse the request_id), or memory grows with requests served."""
        return self.results.pop(request_id)

    # ------------------------------------------------------------------
    # Live migration (serve/wire.py + serve/router.py)
    # ------------------------------------------------------------------
    #
    # A decoding stream is its slot snapshot plus the request and the
    # tokens emitted so far — O(layers·d²) bytes for Taylor slots,
    # independent of context (the paper's asset; ROADMAP "fleet-scale
    # serving"). Migration happens only at step boundaries: between
    # steps the slot state has absorbed exactly prompt + out_tokens[:-1]
    # (the last emitted token is the *next* decode feed), so a peer
    # restoring the snapshot continues the stream with the same float
    # ops a non-migrated engine would run — bit-identical tokens.
    # Sampling survives too: keys are derived from (engine seed,
    # request_id, token index), none of which move with the machine.

    def _fingerprint(self) -> dict:
        """What the importing engine must agree on for the continued
        stream to be bit-identical to an unmigrated run."""
        return {"model": {"name": self.cfg.name,
                          "n_layers": self.cfg.n_layers,
                          "d_model": self.cfg.d_model,
                          "n_heads": self.cfg.n_heads,
                          "vocab": self.cfg.vocab},
                "seed": self.econf.seed,
                "temperature": self.econf.temperature}

    def export_request(self, request_id: str) -> bytes:
        """Drain one decoding stream into a ``repro.state/v1`` wire blob
        and drop it from this engine (slot freed, bookkeeping cleared).

        Only DECODING streams export — WAITING/PREFILLING requests hold
        no state worth shipping (cancel + resubmit replays them
        deterministically), and mid-step there is no boundary to cut at.
        """
        from repro.serve import wire
        seq = self.sequences.get(request_id)
        if seq is None:
            raise KeyError(f"unknown request {request_id!r}")
        if seq.status is not SequenceStatus.DECODING:
            raise ValueError(
                f"request {request_id!r} is {seq.status.value}; only "
                "decoding streams migrate (step-boundary invariant)")
        with tracer.span("migrate_export", request=request_id):
            r = seq.request
            blob = wire.encode_stream(
                self.pool.snapshot(seq.slot),
                request={"request_id": r.request_id,
                         "prompt": [int(t) for t in r.prompt],
                         "max_new_tokens": r.max_new_tokens,
                         "eos_id": r.eos_id, "temperature": r.temperature,
                         "top_k": r.top_k, "top_p": r.top_p},
                out_tokens=seq.out_tokens,
                cache_kind=self.plan.cache_kind,
                cache_len=self.pool.cache_len,
                model=self._fingerprint(), replica=self.replica_id)
        # drain only after the snapshot is safely in the blob
        self._slots[seq.slot] = None
        if self.drafter is not None:
            self.drafter.release(seq.slot)
        self.pool.release(seq.slot)
        seq.slot = None
        del self.sequences[request_id]
        return blob

    def import_request(self, blob: bytes) -> Sequence:
        """Restore a migrated stream from a wire blob and resume
        decoding it here. All-or-nothing: every validation — blob
        integrity (wire.decode), engine compatibility, duplicate id,
        capacity, structural shape/dtype match against this pool's slot
        template — runs *before* a slot is touched, so a refused blob
        leaves the engine bit-exactly as it was (never half-restored).
        """
        from repro.serve import wire
        meta, state = wire.decode_stream(blob)
        req = Request(**meta["request"])
        rid = req.request_id
        out = [int(t) for t in meta["out_tokens"]]
        if rid in self.sequences or rid in self.results:
            raise ValueError(f"duplicate request_id {rid!r}")
        if not out:
            raise wire.WireError(
                "stream blob has no emitted tokens — a decoding stream "
                "always has at least the first token")
        if len(out) >= req.max_new_tokens or out[-1] == req.eos_id:
            raise wire.WireError("stream blob is already finished")
        if len(req.prompt) + req.max_new_tokens > self.econf.max_seq_len:
            raise ValueError("prompt + max_new_tokens exceeds max_seq_len")
        if meta["cache_kind"] != self.plan.cache_kind:
            raise wire.WireError(
                f"blob cache_kind {meta['cache_kind']!r} != engine "
                f"{self.plan.cache_kind!r}")
        if meta["cache_len"] != self.pool.cache_len:
            raise wire.WireError(
                f"blob cache_len {meta['cache_len']} != pool "
                f"{self.pool.cache_len}")
        fp = self._fingerprint()
        if meta.get("model", fp) != fp:
            raise wire.WireError(
                f"engine fingerprint mismatch: blob {meta['model']} vs "
                f"here {fp} — continued stream would not be bit-identical")
        # structural check: the blob's tree must match this pool's slot
        # layout leaf for leaf (shape AND dtype) before any scatter
        template = jax.eval_shape(
            lambda c: M.cache_gather_slot(c, 0), self.pool.cache)
        t_def = jax.tree.structure(template)
        s_def = jax.tree.structure(state)
        if t_def != s_def:
            raise wire.WireError(
                f"blob tree structure {s_def} != slot template {t_def}")
        for i, (want, got) in enumerate(zip(jax.tree.leaves(template),
                                            jax.tree.leaves(state))):
            if want.shape != got.shape or want.dtype != got.dtype:
                raise wire.WireError(
                    f"leaf {i}: blob {got.shape}/{got.dtype} != slot "
                    f"template {want.shape}/{want.dtype}")
        if not self.pool.free_slots:
            raise RuntimeError("no free slot")
        with tracer.span("migrate_import", request=rid):
            slot = self.pool.alloc()
            try:
                self.pool.restore(slot, state)
                seq = Sequence(request=req,
                               status=SequenceStatus.DECODING,
                               slot=slot, out_tokens=out,
                               consumed=len(req.prompt))
                seq.t_first_token = seq.t_submit  # TTFT was paid at the
                #   source; don't re-record it here
                self._slots[slot] = seq
                self.sequences[rid] = seq
                if self.drafter is not None:
                    self.drafter.on_ready(seq)
            except Exception:
                self._slots[slot] = None
                self.pool.release(slot)
                self.sequences.pop(rid, None)
                raise
        return seq

    def cancel(self, request_id: str) -> Request:
        """Abandon a live request (any pre-FINISHED status), free its
        slot if it holds one, and return the Request — the router's
        replay path (failed hard, nothing exportable) resubmits it
        elsewhere; determinism makes the replayed stream identical."""
        seq = self.sequences.get(request_id)
        if seq is None:
            raise KeyError(f"unknown request {request_id!r}")
        if seq.status is SequenceStatus.WAITING:
            self.queue._q.remove(seq)
        else:
            self._slots[seq.slot] = None
            if self.drafter is not None:
                self.drafter.release(seq.slot)
            self.pool.release(seq.slot)
            seq.slot = None
        del self.sequences[request_id]
        return seq.request

    # ------------------------------------------------------------------
    # One scheduler step
    # ------------------------------------------------------------------

    def step(self) -> tuple[StepMetrics, list[TokenEvent]]:
        t0 = time.perf_counter()
        events: list[TokenEvent] = []
        # every phase below is wrapped in an obs span; with the global
        # tracer disabled (the default) each wrapper is one flag check
        # returning a shared no-op context — docs/observability.md
        step_span = tracer.span("engine_step", step_num=self._step_idx)
        with step_span:

            # 1. admit — waiting sequences take free slots; the prefix
            # cache seeds each new sequence from its longest cached prefix
            cached_tokens = 0
            admitted = 0
            with tracer.span("admit") as adm:
                while self.pool.free_slots and self.queue.depth:
                    seq = self.queue.pop()
                    seq.slot = self.pool.alloc()
                    seq.status = SequenceStatus.PREFILLING
                    self._slots[seq.slot] = seq
                    # per-request child of the batch-level admit span:
                    # the first span of a request's cross-process
                    # timeline (python -m repro.obs --request <id>)
                    with tracer.span("admission",
                                     request=seq.request_id,
                                     slot=seq.slot):
                        with tracer.span("prefix_lookup",
                                         request=seq.request_id) as lk:
                            PF.start_prefill(
                                seq, self.pool,
                                self.econf.prefill_chunk,
                                self.prefix_cache,
                                pool_resident=self._batch_prefill)
                            lk.set("cached_tokens", seq.cached_tokens)
                    cached_tokens += seq.cached_tokens
                    admitted += 1
                adm.set("admitted", admitted)

            plan = self.scheduler.plan(
                [s for s in self._slots if s is not None])
            budget = self.scheduler.token_budget

            # 2. one batched decode (or draft+verify) pass for every
            # running sequence. Speculation only pays when at least one
            # decoding row is greedy — sampled rows always reject their
            # drafts, so an all-sampled batch takes the plain decode path
            # (one token per slot, no draft/verify/rollback work, no
            # budget surcharge).
            decode_tokens = decode_charge = 0
            draft_tokens = accepted_tokens = rollbacks = k_step = 0
            spec_step = (self.drafter is not None
                         and any(self._temp(s) <= 0.0 for s in plan.decode))
            if plan.decode and spec_step:
                k_step = self._controller.k
                (decode_tokens, draft_tokens, accepted_tokens,
                 rollbacks) = self._speculative_decode(plan.decode, k_step,
                                                       events)
                # charge the k the controller actually used, then refund
                # the verified-and-rolled-back drafts: the net equals
                # the tokens that advanced a stream, so speculation plus
                # prefix-cache hits can no longer double-charge the
                # budget relative to the work that really ran
                decode_charge = self.scheduler.decode_cost(
                    len(plan.decode), k_step,
                    rejected=draft_tokens - accepted_tokens)
                budget -= decode_charge
            elif plan.decode:
                dec_span = tracer.span(
                    "decode_batch",
                    compile_key=("decode", self.pool.n_slots),
                    slots=len(plan.decode))
                if tracer.enabled:
                    # batched phases list every member request so the
                    # per-request timeline can claim them; guarded so
                    # the disabled path builds no list
                    dec_span.set("requests",
                                 [s.request_id for s in plan.decode])
                with dec_span:
                    tokens = np.zeros((self.pool.n_slots, 1), np.int32)
                    mask = np.zeros((self.pool.n_slots,), bool)
                    for s in plan.decode:
                        tokens[s.slot, 0] = s.next_token
                        mask[s.slot] = True
                    logits, self.pool.cache = self._decode_fn(
                        jnp.asarray(tokens), jnp.asarray(mask),
                        self.pool.cache)
                    last = logits[:, -1]
                    # one batched argmax + one device sync covers every
                    # greedy row; skipped when the whole batch is sampled
                    greedy = None
                    if any(self._temp(s) <= 0.0 for s in plan.decode):
                        greedy = np.asarray(jnp.argmax(last, axis=-1))
                    for s in plan.decode:
                        if self._temp(s) <= 0.0:
                            events.append(self._emit(s, int(greedy[s.slot])))
                        else:
                            events.append(
                                self._emit(s, self._sample(s, last[s.slot])))
                decode_tokens = len(plan.decode)
                decode_charge = self.scheduler.decode_cost(len(plan.decode))
                budget -= decode_charge

            # 3. chunked prefill under the remaining budget
            prefill_tokens = 0
            first = True
            if self._batch_prefill:
                # prefix-hit sequences resume on the private path
                # (zero-copy seed, see prefill.start_prefill) and run
                # first: a resumed suffix is the cheapest way to turn
                # budget into a first token
                resident = [s for s in plan.prefill if s.pool_resident]
                for s in plan.prefill:
                    if s.pool_resident:
                        continue
                    while not s.prefill_done:
                        c = s.next_chunk
                        if not first and c > budget:
                            break
                        with tracer.span(
                                "prefill_chunk",
                                compile_key=("prefill", c),
                                request=s.request_id, chunk=c):
                            prefill_tokens += PF.advance_prefill(
                                s, self._prefill_fn, self.prefix_cache)
                        budget -= c
                        first = False
                    if not s.prefill_done:
                        break
                # then rounds of same-chunk-length groups over the
                # pool-resident (cold) sequences, each ONE pooled
                # dispatch over the full slot batch (fixed shapes)
                while True:
                    group = self.scheduler.group_prefill(
                        resident, budget, first_exempt=first)
                    if not group:
                        break
                    c = group[0].next_chunk
                    grp_span = tracer.span(
                        "prefill_batch",
                        compile_key=(("prefill_pool", c)
                                     if len(group) > 1
                                     else ("prefill_slot", c)),
                        slots=len(group), chunk=c)
                    if tracer.enabled:
                        grp_span.set("requests",
                                     [s.request_id for s in group])
                    with grp_span:
                        if tracer.enabled:
                            # the group span fans into per-slot markers
                            # so each request's timeline shows *its*
                            # slot inside the pooled dispatch
                            for s in group:
                                tracer.instant("prefill_slot",
                                               request=s.request_id,
                                               slot=s.slot, chunk=c)
                        prefill_tokens += PF.advance_prefill_batch(
                            group, self.pool, self._pool_prefill_fn,
                            self.prefix_cache, self._slot_prefill_fn)
                    budget -= len(group) * c
                    first = False
                for s in plan.prefill:
                    if s.prefill_done:
                        self._begin_decode(s, events)
            else:
                for s in plan.prefill:
                    while not s.prefill_done:
                        c = s.next_chunk
                        if not first and c > budget:
                            break
                        with tracer.span(
                                "prefill_chunk",
                                compile_key=("prefill", c),
                                request=s.request_id, chunk=c):
                            prefill_tokens += PF.advance_prefill(
                                s, self._prefill_fn, self.prefix_cache)
                        budget -= c
                        first = False
                    if not s.prefill_done:
                        break
                    self._begin_decode(s, events)

        m = StepMetrics(
            step=self._step_idx, wall_s=time.perf_counter() - t0,
            decode_tokens=decode_tokens, prefill_tokens=prefill_tokens,
            queue_depth=self.queue.depth, occupancy=self.pool.occupancy,
            active_decoding=len(plan.decode),
            draft_tokens=draft_tokens, accepted_tokens=accepted_tokens,
            rollbacks=rollbacks, speculate_k=k_step,
            cached_prefix_tokens=cached_tokens,
            scheduled_tokens=decode_charge + prefill_tokens)
        self.stats.record_step(m)
        if self.prefix_cache is not None:
            self.stats.prefix_cache = self.prefix_cache.stats()
        self._step_idx += 1
        return m, events

    def run(self) -> Iterator[TokenEvent]:
        """Drive steps until idle, streaming TokenEvents."""
        while not self.idle:
            _, events = self.step()
            yield from events

    def generate(self, requests: list[Request]) -> dict[str, list[int]]:
        """Convenience batch API: submit everything, run to completion,
        return request_id -> generated tokens."""
        for r in requests:
            self.submit(r)
        for _ in self.run():
            pass
        return {r.request_id: self.results[r.request_id].out_tokens
                for r in requests}

    # ------------------------------------------------------------------
    # Speculative decode (draft -> one batched verify -> accept/rollback)
    # ------------------------------------------------------------------

    def _speculative_decode(self, decoding: list[Sequence], k: int,
                            events: list[TokenEvent]
                            ) -> tuple[int, int, int, int]:
        """One draft+verify pass over every decoding slot.

        Returns (emitted, drafted, accepted, rollbacks). Greedy
        sequences accept the longest draft prefix whose argmax chain
        matches (bit-identical to one-token-per-step greedy decoding);
        sampled sequences draw from the verify block's first position —
        exactly the next-token distribution — and always roll back the
        drafted tail.

        Rollback discipline: jax arrays are immutable, so holding the
        pre-verify pool pytree is a zero-copy bit-exact snapshot of
        every slot. A rejected slot is then fixed in ONE fused call
        (``models.model.verify_rollback``): restore from the snapshot +
        re-absorb the accepted prefix, ≤ k distinct shapes total. An
        accepted-everything step costs exactly the dispatches of a
        plain decode step (verify + argmax) while emitting k+1 tokens
        per slot.
        """
        from repro.spec.verify import accepted_prefix

        rids = ([s.request_id for s in decoding] if tracer.enabled
                else None)
        draft_span = tracer.span("draft", compile_key=("draft", k), k=k,
                                 slots=len(decoding))
        if rids is not None:
            draft_span.set("requests", rids)
        with draft_span:
            drafts = self.drafter.draft(decoding, k)
        tokens = np.zeros((self.pool.n_slots, k + 1), np.int32)
        mask = np.zeros((self.pool.n_slots,), bool)
        for s in decoding:
            tokens[s.slot, 0] = s.next_token
            tokens[s.slot, 1:] = drafts[s.slot]
            mask[s.slot] = True
        snap = self.pool.cache          # O(1): arrays are immutable
        verify_span = tracer.span("verify", compile_key=("verify", k + 1),
                                  k=k, slots=len(decoding))
        if rids is not None:
            verify_span.set("requests", rids)
        with verify_span:
            logits, self.pool.cache = self._verify_fn(
                jnp.asarray(tokens), jnp.asarray(mask), self.pool.cache)
            greedy = np.asarray(jnp.argmax(logits, axis=-1))  # (slots, k+1)

        # every decoding slot's k drafts are scored (and budgeted),
        # sampled ones included — only acceptance is greedy-specific
        emitted_n, accepted_n, rollbacks = 0, 0, 0
        drafted_n = k * len(decoding)
        for s in decoding:
            slot = s.slot
            if self._temp(s) <= 0.0:
                a, emitted = accepted_prefix(drafts[slot], greedy[slot])
                accepted_n += a
                self._controller.update(a, k)   # greedy observations only:
            else:                               # sampled seqs always reject
                a, emitted = 0, [self._sample(s, logits[slot, 0])]
            for t in emitted:
                ev = self._emit(s, t)
                events.append(ev)
                emitted_n += 1
                if ev.finished:
                    break
            if s.status is SequenceStatus.FINISHED:
                continue        # slot already released and zero-reset
            if a < k:
                # state absorbed all k+1 fed tokens but only a+1 are
                # real context: restore and re-absorb the accepted
                # prefix (the bonus token is the *next* feed, never
                # absorbed here — same as the non-speculative step)
                with tracer.span("rollback",
                                 compile_key=("rollback", a + 1),
                                 request=s.request_id, accepted=a):
                    self.pool.cache = self._rollback_fn(
                        self.pool.cache, snap, slot,
                        jnp.asarray(tokens[slot, :a + 1], jnp.int32)[None])
                rollbacks += 1
            self.drafter.commit(s, a, tokens[slot].tolist())
        return emitted_n, drafted_n, accepted_n, rollbacks

    # ------------------------------------------------------------------
    # Sampling / lifecycle internals
    # ------------------------------------------------------------------

    def _begin_decode(self, s: Sequence, events: list[TokenEvent]) -> None:
        """Prompt fully absorbed: hand the state to the decode path and
        sample the first token from the last chunk's logits. Pool-
        resident sequences already live in their slot; private ones
        scatter in here."""
        if not s.pool_resident:
            self.pool.scatter(s.cache, s.slot)
            s.cache = None
        s.status = SequenceStatus.DECODING
        if self.drafter is not None:
            self.drafter.on_ready(s)
        s.t_first_token = time.perf_counter()
        tracer.instant("first_token", request=s.request_id)
        self.stats.record_first_token(s.ttft)
        events.append(self._emit(s, self._sample(s, s.last_logits[0, -1]),
                                 first=True))
        s.last_logits = None

    def _temp(self, seq: Sequence) -> float:
        """Effective temperature: per-request override, engine default."""
        t = seq.request.temperature
        return self.econf.temperature if t is None else t

    def _sample(self, seq: Sequence, logits_row) -> int:
        temp = self._temp(seq)
        if temp <= 0.0:
            return int(jnp.argmax(logits_row))
        lg = jnp.asarray(logits_row, jnp.float32) / temp
        lg = _filter_logits(lg, seq.request.top_k, seq.request.top_p)
        # per-(request, index) keys: sampling is independent of how the
        # request was batched, so staggered arrivals stay reproducible;
        # crc32, not hash() — str hashing is salted per interpreter
        rid = zlib.crc32(seq.request_id.encode()) & 0x7FFFFFFF
        key = jax.random.fold_in(jax.random.fold_in(self._rng, rid),
                                 len(seq.out_tokens))
        return int(jax.random.categorical(key, lg))

    def _emit(self, seq: Sequence, token: int, *, first: bool = False
              ) -> TokenEvent:
        # per-request inter-token latency: wall gap between consecutive
        # emitted tokens (tokens a verify step releases together are
        # honest ~0 gaps — that burstiness is what the ITL percentiles
        # exist to show)
        now = time.perf_counter()
        if seq.t_last_token is not None:
            itl = now - seq.t_last_token
            seq.itls.append(itl)
            self.stats.record_itl(itl)
        seq.t_last_token = now
        seq.out_tokens.append(token)
        done = (len(seq.out_tokens) >= seq.request.max_new_tokens
                or token == seq.request.eos_id)
        if done:
            self._finish(seq)
        return TokenEvent(request_id=seq.request_id, token=token,
                          index=len(seq.out_tokens) - 1, first=first,
                          finished=done)

    def _finish(self, seq: Sequence) -> None:
        seq.status = SequenceStatus.FINISHED
        seq.t_finish = time.perf_counter()
        tracer.instant("finish", request=seq.request_id,
                       tokens=len(seq.out_tokens))
        self._slots[seq.slot] = None
        if self.drafter is not None:
            self.drafter.release(seq.slot)
        self.pool.release(seq.slot)
        seq.slot = None
        del self.sequences[seq.request_id]   # live bookkeeping only
        self.results[seq.request_id] = seq
        self.stats.record_finish()
