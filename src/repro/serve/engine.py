"""The continuous-batching inference engine.

Fixed shapes everywhere: decode always runs the full slot batch
(inactive slots compute on throwaway state and are ignored), prefill
runs per-sequence at a bounded set of chunk lengths — so after warmup
no step ever recompiles. Sequences at different context lengths share
decode batches thanks to the per-slot position counters
(``init_decode_state(per_slot=True)``).

Typical use::

    eng = Engine(cfg, params, EngineConfig(n_slots=4))
    eng.submit(Request("a", prompt, max_new_tokens=16))
    for ev in eng.run():            # streams TokenEvents
        ...
    eng.results["a"].out_tokens
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import backend as B
from repro.models import model as M
from repro.models.model import PREFILL_KINDS
from repro.serve import prefill as PF
from repro.serve.pool import StatePool
from repro.serve.request import (AdmissionQueue, Request, Sequence,
                                 SequenceStatus, TokenEvent)
from repro.serve.scheduler import EngineStats, Scheduler, StepMetrics


@dataclass
class EngineConfig:
    n_slots: int = 4             # max sequences decoding concurrently
    max_queue: int = 64          # admission backpressure threshold
    prefill_chunk: int = 128     # target prompt tokens per prefill call
    token_budget: int = 256      # scheduled tokens per engine step
    max_seq_len: int = 2048      # pool cache_len (kv caches only grow to this)
    cache_kind: str = "taylor"   # taylor | kv | auto ("and Back" via the
    #   N1 memory crossover — models/backend.py:select_serve_plan)
    temperature: float = 0.0
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, econf: EngineConfig | None = None):
        econf = econf or EngineConfig()
        bad = [k for k in cfg.layer_pattern if k not in PREFILL_KINDS]
        if bad or cfg.family == "encdec":
            raise NotImplementedError(
                "serve engine: chunked prefill supports global-attention "
                f"decoder architectures (pattern {tuple(cfg.layer_pattern)})")
        self.cfg = cfg
        self.econf = econf
        # One routing decision for the whole engine: cache layout
        # (resolving cache_kind="auto" through the paper's N1 memory
        # crossover) plus the prefill/decode path selections the
        # attention layers will re-derive identically at trace time.
        self.plan = B.select_serve_plan(
            cfg, max_seq_len=econf.max_seq_len,
            prefill_chunk=econf.prefill_chunk,
            cache_kind=econf.cache_kind)
        self.pool = StatePool(cfg, econf.n_slots,
                              cache_len=econf.max_seq_len,
                              cache_kind=self.plan.cache_kind)
        self.queue = AdmissionQueue(econf.max_queue)
        self.scheduler = Scheduler(econf.token_budget)
        self.stats = EngineStats()
        self.sequences: dict[str, Sequence] = {}
        self.results: dict[str, Sequence] = {}
        self._slots: list[Sequence | None] = [None] * econf.n_slots
        self._step_idx = 0
        self._rng = jax.random.PRNGKey(econf.seed)
        # params travel as a jit *argument* (not a closure capture) so
        # the weights aren't baked into the jaxpr as constants
        self._params = params
        prefill_jit = jax.jit(
            lambda p, toks, cache: M.prefill_chunk(p, cfg,
                                                   {"tokens": toks}, cache))
        decode_jit = jax.jit(
            lambda p, toks, cache: M.decode_step(p, cfg,
                                                 {"tokens": toks}, cache))
        self._prefill_fn = lambda toks, cache: prefill_jit(
            self._params, toks, cache)
        self._decode_fn = lambda toks, cache: decode_jit(
            self._params, toks, cache)

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> Sequence:
        """Enqueue a request. Raises QueueFullError under backpressure."""
        if (request.request_id in self.sequences
                or request.request_id in self.results):
            raise ValueError(f"duplicate request_id {request.request_id!r}")
        if len(request.prompt) + request.max_new_tokens > self.econf.max_seq_len:
            raise ValueError("prompt + max_new_tokens exceeds max_seq_len")
        seq = Sequence(request=request)
        self.queue.push(seq)
        self.sequences[request.request_id] = seq
        return seq

    @property
    def idle(self) -> bool:
        return self.queue.depth == 0 and all(s is None for s in self._slots)

    @property
    def step_idx(self) -> int:
        """Number of scheduler steps taken (public: arrival schedules and
        tests key on this)."""
        return self._step_idx

    def pop_result(self, request_id: str) -> Sequence:
        """Drain one finished sequence. ``results`` retains finished
        sequences until popped — long-running callers must drain (and may
        then reuse the request_id), or memory grows with requests served."""
        return self.results.pop(request_id)

    # ------------------------------------------------------------------
    # One scheduler step
    # ------------------------------------------------------------------

    def step(self) -> tuple[StepMetrics, list[TokenEvent]]:
        t0 = time.perf_counter()
        events: list[TokenEvent] = []

        # 1. admit — waiting sequences take free slots
        while self.pool.free_slots and self.queue.depth:
            seq = self.queue.pop()
            seq.slot = self.pool.alloc()
            seq.status = SequenceStatus.PREFILLING
            self._slots[seq.slot] = seq
            PF.start_prefill(seq, self.pool, self.econf.prefill_chunk)

        plan = self.scheduler.plan([s for s in self._slots if s is not None])
        budget = self.scheduler.token_budget

        # 2. one batched decode step for every running sequence
        decode_tokens = 0
        if plan.decode:
            tokens = np.zeros((self.pool.n_slots, 1), np.int32)
            for s in plan.decode:
                tokens[s.slot, 0] = s.next_token
            logits, self.pool.cache = self._decode_fn(
                jnp.asarray(tokens), self.pool.cache)
            last = logits[:, -1]
            if self.econf.temperature <= 0.0:
                # one batched argmax + one device sync for the whole step
                greedy = np.asarray(jnp.argmax(last, axis=-1))
                for s in plan.decode:
                    events.append(self._emit(s, int(greedy[s.slot])))
            else:
                for s in plan.decode:
                    events.append(self._emit(s, self._sample(s, last[s.slot])))
            decode_tokens = len(plan.decode)
            budget -= decode_tokens

        # 3. chunked prefill under the remaining budget
        prefill_tokens = 0
        first = True
        for s in plan.prefill:
            while not s.prefill_done:
                c = s.next_chunk
                if not first and c > budget:
                    break
                prefill_tokens += PF.advance_prefill(s, self._prefill_fn)
                budget -= c
                first = False
            if not s.prefill_done:
                break
            # prompt fully absorbed: hand the state to the decode path
            # and sample the first token from the last chunk's logits
            self.pool.scatter(s.cache, s.slot)
            s.cache = None
            s.status = SequenceStatus.DECODING
            s.t_first_token = time.perf_counter()
            self.stats.record_first_token(s.ttft)
            events.append(self._emit(s, self._sample(s, s.last_logits[0, -1]),
                                     first=True))
            s.last_logits = None

        m = StepMetrics(
            step=self._step_idx, wall_s=time.perf_counter() - t0,
            decode_tokens=decode_tokens, prefill_tokens=prefill_tokens,
            queue_depth=self.queue.depth, occupancy=self.pool.occupancy,
            active_decoding=len(plan.decode))
        self.stats.record_step(m)
        self._step_idx += 1
        return m, events

    def run(self) -> Iterator[TokenEvent]:
        """Drive steps until idle, streaming TokenEvents."""
        while not self.idle:
            _, events = self.step()
            yield from events

    def generate(self, requests: list[Request]) -> dict[str, list[int]]:
        """Convenience batch API: submit everything, run to completion,
        return request_id -> generated tokens."""
        for r in requests:
            self.submit(r)
        for _ in self.run():
            pass
        return {r.request_id: self.results[r.request_id].out_tokens
                for r in requests}

    # ------------------------------------------------------------------
    # Sampling / lifecycle internals
    # ------------------------------------------------------------------

    def _sample(self, seq: Sequence, logits_row) -> int:
        if self.econf.temperature <= 0.0:
            return int(jnp.argmax(logits_row))
        # per-(request, index) keys: sampling is independent of how the
        # request was batched, so staggered arrivals stay reproducible;
        # crc32, not hash() — str hashing is salted per interpreter
        rid = zlib.crc32(seq.request_id.encode()) & 0x7FFFFFFF
        key = jax.random.fold_in(jax.random.fold_in(self._rng, rid),
                                 len(seq.out_tokens))
        return int(jax.random.categorical(
            key, logits_row / self.econf.temperature))

    def _emit(self, seq: Sequence, token: int, *, first: bool = False
              ) -> TokenEvent:
        seq.out_tokens.append(token)
        done = (len(seq.out_tokens) >= seq.request.max_new_tokens
                or token == seq.request.eos_id)
        if done:
            self._finish(seq)
        return TokenEvent(request_id=seq.request_id, token=token,
                          index=len(seq.out_tokens) - 1, first=first,
                          finished=done)

    def _finish(self, seq: Sequence) -> None:
        seq.status = SequenceStatus.FINISHED
        seq.t_finish = time.perf_counter()
        self._slots[seq.slot] = None
        self.pool.release(seq.slot)
        seq.slot = None
        del self.sequences[seq.request_id]   # live bookkeeping only
        self.results[seq.request_id] = seq
        self.stats.record_finish()
