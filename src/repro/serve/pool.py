"""Slot-based TaylorState cache pool.

Preallocates the model's whole decode cache with a leading slot
dimension — for a TaylorShift model that is
``(layers, slots, kv_heads, 1, d², d+1)`` per layer group — plus
per-slot position counters. Because every slot is constant-size,
sequences join and leave the running batch by gather/scatter on the
pytree: no paged blocks, no reallocation, no recompilation, and decode
memory that never grows with context length.

Slot lifecycle: ``alloc`` (admission) → ``scatter`` (prefill finished,
single-sequence state dropped into the slot) → ``release`` (zero-reset,
back on the free list).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


class StatePool:
    def __init__(self, cfg: ModelConfig, n_slots: int, *, cache_len: int,
                 cache_kind: str = "taylor", dtype=jnp.float32):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.cache_kind = cache_kind
        self.dtype = dtype
        self.cache = M.init_decode_state(cfg, n_slots, cache_len=cache_len,
                                         cache_kind=cache_kind, dtype=dtype,
                                         per_slot=True)
        self._free = list(range(n_slots - 1, -1, -1))
        self._scatter = jax.jit(M.cache_scatter_slot)
        self._reset = jax.jit(M.cache_reset_slot)
        self._gather = jax.jit(M.cache_gather_slot)

    # -- slot bookkeeping ---------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free slot")
        return self._free.pop()

    def release(self, slot: int) -> None:
        """Zero the slot's state and return it to the free list. The
        zero-reset is hygiene, not correctness: a later ``scatter``
        overwrites every leaf of the slot anyway."""
        self.cache = self._reset(self.cache, slot)
        self._free.append(slot)

    # -- state movement -----------------------------------------------------

    def new_sequence_cache(self):
        """Private batch=1 cache a sequence prefills into before joining
        the pool (same cache_len so leaves scatter shape-exactly)."""
        return M.init_decode_state(self.cfg, 1, cache_len=self.cache_len,
                                   cache_kind=self.cache_kind,
                                   dtype=self.dtype)

    def scatter(self, src_cache, slot: int) -> None:
        self.cache = self._scatter(self.cache, src_cache, slot)

    def gather(self, slot: int):
        return self._gather(self.cache, slot)

    def nbytes(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.cache)
                   if hasattr(x, "size"))
