"""Slot-based TaylorState cache pool.

Preallocates the model's whole decode cache with a leading slot
dimension — for a TaylorShift model that is
``(layers, slots, kv_heads, 1, d², d+1)`` per layer group — plus
per-slot position counters. Because every slot is constant-size,
sequences join and leave the running batch by gather/scatter on the
pytree: no paged blocks, no reallocation, no recompilation, and decode
memory that never grows with context length.

Slot lifecycle: ``alloc`` (admission) → ``scatter`` (prefill finished,
single-sequence state dropped into the slot) → ``release`` (zero-reset,
back on the free list).

Because a Taylor slot is constant-size — O(layers · d²) sums plus
counters, independent of context length — a full copy of a slot's state
is as cheap as one decode step's state update. ``snapshot``/``restore``
expose that as the rollback primitive speculative decoding builds on
(src/repro/spec/, docs/design.md): snapshot before scoring drafted
tokens, restore when the drafts are rejected. jax arrays are immutable,
so a snapshot is simply the gathered sub-pytree — it can never be
corrupted by later pool updates, and restore is one scatter. (With
``cache_kind="kv"`` — the "and Back" regime below the N1 crossover —
a slot copy is O(layers · cache_len · d) instead: still one gather,
but growing with ``max_seq_len``; the constant-cost claim is the
Taylor state's.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


class StatePool:
    """Slot pool over one ``init_decode_state(per_slot=True)`` pytree.

    Contract: ``self.cache`` is the only mutable reference — every
    method that "mutates" a slot rebinds it to a functionally-updated
    pytree, so any pytree previously handed out (``gather``/
    ``snapshot`` results, prefix-cache entries, the pre-verify
    speculative snapshot) is immutable and stays bit-exact forever.
    ``alloc``/``release`` manage the free list only; state movement is
    ``scatter`` (overwrites *every* leaf of a slot — a recycled slot
    carries no trace of its previous occupant) and ``gather``. Byte
    accounting via ``nbytes()`` matches what the prefix cache charges
    per single-sequence entry times ``n_slots``.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, *, cache_len: int,
                 cache_kind: str = "taylor", dtype=jnp.float32):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.cache_kind = cache_kind
        self.dtype = dtype
        self.cache = M.init_decode_state(cfg, n_slots, cache_len=cache_len,
                                         cache_kind=cache_kind, dtype=dtype,
                                         per_slot=True)
        self._free = list(range(n_slots - 1, -1, -1))
        self._scatter = jax.jit(M.cache_scatter_slot)
        self._reset = jax.jit(M.cache_reset_slot)
        self._gather = jax.jit(M.cache_gather_slot)

    # -- slot bookkeeping ---------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free slot")
        return self._free.pop()

    def release(self, slot: int) -> None:
        """Zero the slot's state and return it to the free list. The
        zero-reset is hygiene, not correctness: a later ``scatter``
        overwrites every leaf of the slot anyway."""
        self.reset(slot)
        self._free.append(slot)

    def reset(self, slot: int) -> None:
        """Zero one slot *without* freeing it — for shadow pools (e.g.
        the self-drafter's) whose slot indices mirror this pool's and
        are not independently allocated."""
        self.cache = self._reset(self.cache, slot)

    # -- state movement -----------------------------------------------------

    def new_sequence_cache(self):
        """Private batch=1 cache a sequence prefills into before joining
        the pool (same cache_len so leaves scatter shape-exactly)."""
        return M.init_decode_state(self.cfg, 1, cache_len=self.cache_len,
                                   cache_kind=self.cache_kind,
                                   dtype=self.dtype)

    def scatter(self, src_cache, slot: int) -> None:
        self.cache = self._scatter(self.cache, src_cache, slot)

    def gather(self, slot: int):
        return self._gather(self.cache, slot)

    # -- snapshot / rollback (speculative decoding, repro.spec) -------------
    #
    # Thin rollback-facing names over gather/scatter — ONE underlying
    # slot-copy path. A snapshot is bit-exact for every leaf (state
    # sums / kv rows / pos counters) and immutable, so it survives any
    # number of pool mutations; restore makes the slot bit-identical to
    # snapshot time (tests/test_spec.py pins the round-trip). Cost is
    # O(layers · d²) for Taylor slots — context-length-independent —
    # and O(layers · cache_len · d) for kv slots.

    def snapshot(self, slot: int):
        return self.gather(slot)

    def restore(self, slot: int, snap) -> None:
        self.scatter(snap, slot)

    def nbytes(self) -> int:
        from repro.serve.prefix_cache import tree_nbytes
        return tree_nbytes(self.cache)
