"""Prefix-aware router over engine replicas, with live migration.

The fleet tier the ROADMAP's "fleet-scale serving" item asks for: N
:class:`~repro.serve.engine.Engine` replicas behind one front door.
Three jobs, all built on the paper's constant-size recurrent state:

  * **Placement** — each request is scored against every replica's
    *advertised* trie summary (``PrefixCache.summary``: chunk-hash
    chains, a few ints per entry) and routed to the replica holding its
    longest cached prefix; ties and cold prompts fall back to
    least-loaded. A hash collision can only misroute (perf), never
    change tokens — the landing replica's trie does the token-exact
    lookup.
  * **Cache federation** — ``warm_from_peer`` ships a peer's trie
    entries as ``repro.state/v1`` blobs (``serve/wire.py``) so a cold
    replica starts with warm prefixes.
  * **Migration** — a decoding stream drains at a step boundary
    (``Engine.export_request``: slot snapshot + lifecycle meta,
    O(layers·d²) bytes for Taylor state regardless of context), ships
    as one wire blob, restores into a peer's pool
    (``Engine.import_request``) and continues **bit-identically** —
    emitted streams are a pure function of (params, config, request,
    seed), and the machine they run on is not in that list.

Health is ``distributed/ft.Membership``: the router heartbeats every
replica it steps; :meth:`kill` (hard crash — engine gone, heartbeats
stop) leaves in-flight requests orphaned until the sweep expires the
peer, at which point they are *replayed* on surviving replicas —
determinism makes the replayed stream identical, and already-delivered
event indices are suppressed so downstream consumers never see a
duplicate token. :meth:`preempt` (cooperative — straggler replacement,
planned eviction) migrates decoding streams instead of replaying them
when ``migrate_on_preempt`` is set, cancels + resubmits the rest, and
``Membership.leave``s immediately.

Everything observable publishes into one router-owned registry —
``router_*`` counters/gauges next to the membership's ``ft_*`` series —
snapshot via :meth:`snapshot_metrics` (tagged ``replica="router"``) and
merged with the replicas' snapshots into a single fleet exposition.

In-process by design: replicas are Engine objects in one process, the
"wire" is bytes in memory. That keeps the chaos suite
(tests/test_router.py) honest — every failure mode is driven through
the same code paths a networked deployment would take, minus the
transport.
"""

from __future__ import annotations

import logging
import time
from typing import Iterable, Iterator

from repro.distributed.ft import Membership, StragglerDetector
from repro.obs import metrics as OM
from repro.obs.trace import tracer
from repro.serve.engine import Engine
from repro.serve.prefix_cache import chunk_hash_chain
from repro.serve.request import Request, SequenceStatus, TokenEvent

log = logging.getLogger("repro.router")


class Router:
    """Front door over a set of live Engine replicas.

    Every replica must carry a unique ``EngineConfig.replica_id`` —
    the ONE identity the router, its ``ft.Membership`` and the obs
    snapshots agree on. ``clock`` is injectable (tests drive time to
    force heartbeat expiry); ``timeout_s`` is the membership's silence
    budget.
    """

    def __init__(self, replicas: Iterable[Engine] = (), *,
                 timeout_s: float = 10.0, migrate_on_preempt: bool = True,
                 registry: OM.MetricsRegistry | None = None,
                 clock=time.monotonic):
        self.registry = registry or OM.MetricsRegistry()
        self.membership = Membership(timeout_s=timeout_s,
                                     registry=self.registry, clock=clock)
        self.migrate_on_preempt = migrate_on_preempt
        self.replicas: dict[str, Engine] = {}
        self.results: dict[str, object] = {}    # request_id -> Sequence
        self._requests: dict[str, Request] = {}  # live requests, by id
        self._owner: dict[str, str] = {}         # request_id -> replica
        self._emitted: dict[str, int] = {}       # next expected ev.index
        self._stragglers: dict[str, StragglerDetector] = {}
        r = self.registry
        self._requests_c = r.counter("router_requests_total",
                                     "requests routed, by landing replica",
                                     labelnames=("replica",))
        self._prefix_c = r.counter("router_prefix_routed_total",
                                   "requests placed by cached-prefix score")
        self._loaded_c = r.counter("router_least_loaded_routed_total",
                                   "requests placed by least-loaded fallback")
        self._migrations_c = r.counter("router_migrations_total",
                                       "live streams migrated between "
                                       "replicas")
        self._resub_c = r.counter("router_resubmissions_total",
                                  "requests replayed after replica loss")
        self._wire_c = r.counter("router_wire_bytes_total",
                                 "repro.state/v1 bytes shipped")
        self._failures_c = r.counter("router_replica_failures_total",
                                     "replicas lost to heartbeat expiry")
        self._cache_import_c = r.counter("router_cache_import_entries_total",
                                         "prefix-cache entries imported "
                                         "from peers")
        self._replicas_g = r.gauge("router_replicas",
                                   "replicas currently serving")
        for eng in replicas:
            self.add_replica(eng)

    # ------------------------------------------------------------------
    # Membership
    # ------------------------------------------------------------------

    def add_replica(self, engine: Engine) -> str:
        rid = engine.replica_id
        if not rid:
            raise ValueError("router replicas need EngineConfig.replica_id")
        if rid in self.replicas:
            raise ValueError(f"duplicate replica_id {rid!r}")
        self.replicas[rid] = engine
        self._stragglers[rid] = StragglerDetector()
        self.membership.heartbeat(rid)      # join (epoch bump)
        self._replicas_g.set(len(self.replicas))
        return rid

    @property
    def live(self) -> list[str]:
        """Replicas that are both attached and membership-live."""
        return [r for r in self.membership.members if r in self.replicas]

    def kill(self, rid: str) -> None:
        """Hard crash: the engine vanishes, its heartbeats stop. Its
        in-flight requests stay orphaned until the membership sweep
        expires the peer (heartbeat-loss detection), then replay on the
        survivors — the chaos suite's main lever."""
        self.replicas.pop(rid, None)
        self._stragglers.pop(rid, None)
        self._replicas_g.set(len(self.replicas))

    def preempt(self, rid: str) -> dict:
        """Cooperative drain (planned eviction / straggler replacement).

        Decoding streams migrate to peers with free slots when
        ``migrate_on_preempt`` (else cancel + resubmit, still
        deterministic — just re-paying prefill); waiting/prefilling
        requests always cancel + resubmit (nothing emitted yet, so
        replay is trivially identical). The replica then ``leave``s the
        membership immediately — no timeout wait.
        """
        eng = self.replicas.get(rid)
        if eng is None:
            raise KeyError(f"unknown replica {rid!r}")
        moved = {"migrated": [], "resubmitted": []}
        with tracer.span("router_preempt", replica=rid):
            for req_id in [r for r, o in self._owner.items() if o == rid]:
                seq = eng.sequences[req_id]
                dst = (self._pick_migration_target(rid)
                       if (self.migrate_on_preempt
                           and seq.status is SequenceStatus.DECODING)
                       else None)
                if dst is not None:
                    self.migrate(req_id, dst)
                    moved["migrated"].append(req_id)
                else:
                    req = eng.cancel(req_id)
                    self._resubmit(req, exclude=rid)
                    moved["resubmitted"].append(req_id)
        self.replicas.pop(rid, None)
        self._stragglers.pop(rid, None)
        self.membership.leave(rid)
        self._replicas_g.set(len(self.replicas))
        log.info("preempted %s: %d migrated, %d resubmitted", rid,
                 len(moved["migrated"]), len(moved["resubmitted"]))
        return moved

    def _pick_migration_target(self, exclude: str) -> str | None:
        """Least-loaded live peer with a free pool slot."""
        cands = [(self.replicas[r].queue.depth
                  + len(self.replicas[r].sequences), r)
                 for r in self.live
                 if r != exclude and self.replicas[r].pool.free_slots]
        return min(cands)[1] if cands else None

    # ------------------------------------------------------------------
    # Placement
    # ------------------------------------------------------------------

    def _score(self, summary: dict | None, prompt) -> int:
        """Longest advertised cached prefix of ``prompt``, in tokens."""
        if not summary or not summary["boundaries"]:
            return 0
        C = summary["chunk_tokens"]
        chunks = [tuple(int(t) for t in prompt[i:i + C])
                  for i in range(0, (len(prompt) // C) * C, C)]
        best = 0
        for h, n_chunks in zip(chunk_hash_chain(chunks),
                               range(1, len(chunks) + 1)):
            n = summary["boundaries"].get(h)
            if n == n_chunks * C:       # depth must agree, not just hash
                best = n
        return best

    def route(self, request: Request, *, _exclude: str | None = None) -> str:
        """Pick the landing replica: deepest advertised cached prefix
        wins; cold prompts (or all-zero scores) go least-loaded.
        Replicas with a full admission queue never win. ``_exclude``
        bars a replica that is being drained — it is still live while
        ``preempt`` walks its requests, but must not win them back."""
        cands = [r for r in self.live
                 if r != _exclude and not self.replicas[r].queue.full]
        if not cands:
            raise RuntimeError("no live replica with admission capacity")
        with tracer.span("router_route", request=request.request_id):
            scored = []
            for rid in cands:
                eng = self.replicas[rid]
                summ = (eng.prefix_cache.summary()
                        if eng.prefix_cache is not None else None)
                load = eng.queue.depth + len(eng.sequences)
                scored.append((self._score(summ, request.prompt),
                               -load, rid))
            score, _, rid = max(scored)
        (self._prefix_c if score > 0 else self._loaded_c).inc()
        return rid

    def submit(self, request: Request) -> str:
        """Route + submit one request; returns the landing replica."""
        rid = self.route(request)
        self.replicas[rid].submit(request)
        self._requests[request.request_id] = request
        self._owner[request.request_id] = rid
        self._emitted.setdefault(request.request_id, 0)
        self._requests_c.labels(replica=rid).inc()
        return rid

    def _resubmit(self, request: Request, *,
                  exclude: str | None = None) -> str:
        rid = self.route(request, _exclude=exclude)
        self.replicas[rid].submit(request)
        self._owner[request.request_id] = rid
        self._resub_c.inc()
        return rid

    # ------------------------------------------------------------------
    # Serving loop
    # ------------------------------------------------------------------

    def step(self) -> list[TokenEvent]:
        """One fleet step: step every non-idle replica, heartbeat the
        live ones, sweep for expiries, replay the dead one's requests.

        Duplicate suppression: a replayed request re-emits from index 0
        on its new replica; events below the already-delivered index are
        dropped here, so the merged stream the caller sees is each
        request's tokens exactly once, in order — and bit-identical to
        an undisturbed run."""
        events: list[TokenEvent] = []
        for rid in list(self.replicas):
            eng = self.replicas[rid]
            if not eng.idle:
                t0 = time.perf_counter()
                _, evs = eng.step()
                self._stragglers[rid].observe(time.perf_counter() - t0)
                for ev in evs:
                    seen = self._emitted.get(ev.request_id, 0)
                    if ev.index < seen:
                        continue            # replay of a delivered token
                    self._emitted[ev.request_id] = ev.index + 1
                    events.append(ev)
                    if ev.finished:
                        self.results[ev.request_id] = eng.pop_result(
                            ev.request_id)
                        self._requests.pop(ev.request_id, None)
                        self._owner.pop(ev.request_id, None)
                        self._emitted.pop(ev.request_id, None)
            self.membership.heartbeat(rid)
        for dead in self.membership.sweep():
            self._handle_failure(dead)
        return events

    def _handle_failure(self, rid: str) -> None:
        """A peer's heartbeats expired: drop whatever is left of it and
        replay its unfinished requests on the survivors."""
        self._failures_c.inc()
        self.kill(rid)
        orphans = [r for r, o in self._owner.items() if o == rid]
        log.warning("replica %s expired; replaying %d requests",
                    rid, len(orphans))
        for req_id in orphans:
            self._resubmit(self._requests[req_id])

    @property
    def idle(self) -> bool:
        return (all(e.idle for e in self.replicas.values())
                and not self._owner)

    def run(self) -> Iterator[TokenEvent]:
        """Drive fleet steps until idle, streaming merged TokenEvents."""
        while not self.idle:
            yield from self.step()

    def generate(self, requests: list[Request]) -> dict[str, list[int]]:
        """Batch convenience mirroring ``Engine.generate``."""
        for r in requests:
            self.submit(r)
        for _ in self.run():
            pass
        return {r.request_id: self.results[r.request_id].out_tokens
                for r in requests}

    # ------------------------------------------------------------------
    # Migration + cache federation
    # ------------------------------------------------------------------

    def migrate(self, request_id: str, dst: str) -> int:
        """Move one decoding stream to replica ``dst`` through the wire
        format; returns the blob size in bytes. The continued stream is
        bit-identical to an unmigrated run (tests/test_router.py pins
        the whole matrix: greedy/sampled × taylor/kv × spec on/off)."""
        src = self._owner.get(request_id)
        if src is None:
            raise KeyError(f"unknown request {request_id!r}")
        if dst not in self.replicas:
            raise KeyError(f"unknown replica {dst!r}")
        if dst == src:
            raise ValueError(f"request already on {dst!r}")
        with tracer.span("router_migrate", request=request_id,
                         src=src, dst=dst):
            blob = self.replicas[src].export_request(request_id)
            try:
                self.replicas[dst].import_request(blob)
            except Exception:
                # the stream is drained from src but intact in the blob;
                # replaying the request is always a safe landing
                log.exception("import on %s failed; replaying %s",
                              dst, request_id)
                self._resubmit(self._requests[request_id])
                raise
        self._owner[request_id] = dst
        self._migrations_c.inc()
        self._wire_c.inc(len(blob))
        return len(blob)

    def warm_from_peer(self, dst: str, src: str,
                       max_entries: int = 0) -> int:
        """Import ``src``'s prefix-cache entries into ``dst`` (both must
        have caches); returns entries stored."""
        s, d = self.replicas[src], self.replicas[dst]
        if s.prefix_cache is None or d.prefix_cache is None:
            raise ValueError("both replicas need a prefix cache")
        with tracer.span("router_cache_warm", src=src, dst=dst):
            blobs = s.prefix_cache.export_entries(max_entries)
            n = d.prefix_cache.import_entries(blobs)
        self._wire_c.inc(sum(len(b) for b in blobs))
        self._cache_import_c.inc(n)
        return n

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def snapshot_metrics(self) -> dict:
        """``repro.obs/v1`` snapshot of the router registry (router_*
        + the membership's ft_* series), tagged ``replica="router"`` so
        it merges cleanly next to the replicas' own snapshots."""
        from repro.obs import aggregate as OA
        self.membership.publish()
        return OA.snapshot(self.registry, replica="router")

    def fleet_snapshot(self) -> dict:
        """One merged ``repro.obs/v1`` snapshot: every replica's engine
        registries plus the router's own."""
        from repro.obs import aggregate as OA
        snaps = [eng.snapshot_metrics() for eng in self.replicas.values()]
        snaps.append(self.snapshot_metrics())
        return OA.merge_snapshots(*snaps)
