"""Scheduling policy and per-step metrics.

Policy (one engine step under a token budget):

  1. every DECODING sequence gets one token — decode latency (ITL) is
     kept flat by never starving the running batch;
  2. the remaining budget goes to chunked prefill of the *oldest*
     PREFILLING sequence (FIFO keeps TTFT fair); further prefilling
     sequences are advanced only if budget remains, and at least one
     chunk per step is always allowed so tiny budgets still progress.

Decode cost is one token per active slot; a prefill chunk costs its
length. This is the standard continuous-batching compromise: decode
steps amortize the weight reads over the whole batch while prefill
chunks keep the MXU busy between them.
"""

from __future__ import annotations

import statistics
from dataclasses import dataclass, field

from repro.serve.request import Sequence, SequenceStatus


@dataclass
class StepPlan:
    decode: list[Sequence]
    prefill: list[Sequence]      # in service order; engine stops on budget


@dataclass
class StepMetrics:
    step: int
    wall_s: float
    decode_tokens: int           # tokens *emitted* by the decode/verify path
    prefill_tokens: int
    queue_depth: int
    occupancy: float             # fraction of slots held
    active_decoding: int
    # --- speculative decoding (0 when speculation is off) ------------------
    draft_tokens: int = 0        # drafted tokens scored (k · decoding slots)
    accepted_tokens: int = 0     # drafts accepted by greedy verification
    #   (sampled rows score their drafts too but always reject)
    rollbacks: int = 0           # slots restored from snapshot (a < k)
    speculate_k: int = 0         # draft length the controller used
    # --- shared-prefix cache (0 when the cache is off) ----------------------
    cached_prefix_tokens: int = 0  # prompt tokens served from the prefix
    #   cache at admission this step (never scheduled, never charged)


@dataclass
class EngineStats:
    """Aggregated over a run; ``summary()`` gives the JSON-able dict.

    Contract: purely observational — nothing reads these back into
    scheduling decisions, so resetting them (``Engine.reset_metrics``)
    can never change emitted tokens. ``prefix_cache`` mirrors the
    engine's ``PrefixCache.stats()`` after the latest step (lifetime
    counters — a metrics reset does not clear the cache itself).
    """
    steps: list[StepMetrics] = field(default_factory=list)
    ttfts: list[float] = field(default_factory=list)
    completed: int = 0
    prefix_cache: dict | None = None

    def record_step(self, m: StepMetrics) -> None:
        self.steps.append(m)

    def record_first_token(self, ttft: float) -> None:
        self.ttfts.append(ttft)

    def record_finish(self) -> None:
        self.completed += 1

    def summary(self) -> dict:
        wall = sum(m.wall_s for m in self.steps)
        dec = sum(m.decode_tokens for m in self.steps)
        pre = sum(m.prefill_tokens for m in self.steps)
        drafted = sum(m.draft_tokens for m in self.steps)
        accepted = sum(m.accepted_tokens for m in self.steps)
        out = {
            "steps": len(self.steps),
            "completed_requests": self.completed,
            "wall_s": wall,
            "decode_tokens": dec,
            "prefill_tokens": pre,
            "decode_tok_s": dec / wall if wall else 0.0,
            "prefill_tok_s": pre / wall if wall else 0.0,
            "ttft_mean_s": statistics.mean(self.ttfts) if self.ttfts else 0.0,
            "ttft_max_s": max(self.ttfts) if self.ttfts else 0.0,
            "mean_occupancy": (statistics.mean(m.occupancy
                                               for m in self.steps)
                               if self.steps else 0.0),
        }
        if drafted:     # speculation ran: surface accept/rollback next
            out.update({   # to TTFT/tok-s (ISSUE 4 engine metrics)
                "draft_tokens": drafted,
                "accepted_tokens": accepted,
                "acceptance_rate": accepted / drafted,
                "rollbacks": sum(m.rollbacks for m in self.steps),
                "mean_speculate_k": statistics.mean(
                    m.speculate_k for m in self.steps if m.speculate_k),
            })
        cached = sum(m.cached_prefix_tokens for m in self.steps)
        if self.prefix_cache is not None:   # shared-prefix cache enabled
            out["cached_prefix_tokens"] = cached
            out["prefix_cache"] = self.prefix_cache
        return out


class Scheduler:
    """Token-budget step planner.

    Contract: ``plan()`` is pure — it never mutates sequences or pool
    state; the engine executes the plan and does all accounting. The
    budget charges real model work only: one token per decoding slot
    (``k+1`` with speculation — ``decode_cost``), each prefill chunk at
    its length, and *zero* for prompt tokens the prefix cache served
    (their chunks simply never appear in the sequence's plan), which is
    what lets a cache-hit engine spend its budget on other sequences'
    work instead.
    """

    def __init__(self, token_budget: int):
        if token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        self.token_budget = token_budget

    @staticmethod
    def decode_cost(n_decoding: int, draft_k: int = 0) -> int:
        """Scheduled-token cost of one decode/verify pass.

        Without speculation each decoding slot scores one token. With a
        draft length k the verify call scores k+1 tokens per slot —
        drafted tokens do real model work whether or not they are
        accepted, so they count against the step budget exactly like
        prefill tokens (otherwise speculation would silently starve
        prefill under a 'one token per slot' assumption)."""
        return n_decoding * (draft_k + 1)

    def plan(self, sequences: list[Sequence]) -> StepPlan:
        decode = [s for s in sequences
                  if s.status is SequenceStatus.DECODING]
        prefill = sorted((s for s in sequences
                          if s.status is SequenceStatus.PREFILLING),
                         key=lambda s: s.t_submit)
        return StepPlan(decode=decode, prefill=prefill)
