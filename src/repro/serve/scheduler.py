"""Scheduling policy and per-step metrics.

Policy (one engine step under a token budget):

  1. every DECODING sequence gets one token — decode latency (ITL) is
     kept flat by never starving the running batch;
  2. the remaining budget goes to chunked prefill of the *oldest*
     PREFILLING sequence (FIFO keeps TTFT fair); further prefilling
     sequences are advanced only if budget remains, and at least one
     chunk per step is always allowed so tiny budgets still progress.

Decode cost is one token per active slot; a prefill chunk costs its
length. This is the standard continuous-batching compromise: decode
steps amortize the weight reads over the whole batch while prefill
chunks keep the MXU busy between them.
"""

from __future__ import annotations

import statistics
from dataclasses import dataclass, field

from repro.obs.metrics import MetricsRegistry
from repro.serve.request import Sequence, SequenceStatus


@dataclass
class StepPlan:
    decode: list[Sequence]
    prefill: list[Sequence]      # in service order; engine stops on budget


@dataclass
class StepMetrics:
    step: int
    wall_s: float
    decode_tokens: int           # tokens *emitted* by the decode/verify path
    prefill_tokens: int
    queue_depth: int
    occupancy: float             # fraction of slots held
    active_decoding: int
    # --- speculative decoding (0 when speculation is off) ------------------
    draft_tokens: int = 0        # drafted tokens scored (k · decoding slots)
    accepted_tokens: int = 0     # drafts accepted by greedy verification
    #   (sampled rows score their drafts too but always reject)
    rollbacks: int = 0           # slots restored from snapshot (a < k)
    speculate_k: int = 0         # draft length the controller used
    # --- shared-prefix cache (0 when the cache is off) ----------------------
    cached_prefix_tokens: int = 0  # prompt tokens served from the prefix
    #   cache at admission this step (never scheduled, never charged)
    scheduled_tokens: int = 0    # tokens actually charged against the
    #   budget this step: decode_cost(...) net of the rejected-token
    #   refund, plus every prefill chunk at its length.  Invariant:
    #   scheduled_tokens == decode_tokens + draft rejections' refund
    #   complement + prefill_tokens, and never exceeds the budget
    #   except for the one-chunk-per-step starvation exemption.


# keys in ``PrefixCache.stats()`` that accumulate monotonically (the
# ``since_reset`` sub-dict diffs exactly these against the baseline
# captured at the last ``Engine.reset_metrics``; bytes/entries are
# point-in-time resident values and pass through undiffed)
_CACHE_COUNTER_KEYS = ("lookups", "hits", "misses", "hit_tokens",
                       "lookup_tokens", "inserts", "duplicate_inserts",
                       "evictions", "partial_hits", "truncated_tokens")


@dataclass
class EngineStats:
    """Aggregated over a run; ``summary()`` gives the JSON-able dict.

    A *view* over an ``obs.metrics.MetricsRegistry``: the record_*
    calls publish into registry counters/histograms (one Prometheus
    exposition covers the engine — ``launch/serve.py --metrics-file``),
    and ``summary()`` derives its numbers back out of the registry.
    ``steps`` keeps the per-step ``StepMetrics`` detail the summary's
    occupancy/speculation means and tests key on.

    Contract: purely observational — nothing reads these back into
    scheduling decisions, so resetting them (``Engine.reset_metrics``)
    can never change emitted tokens. ``prefix_cache`` mirrors the
    engine's ``PrefixCache.stats()`` after the latest step. Those are
    *lifetime* counters (a metrics reset does not clear the cache
    itself); ``summary()["prefix_cache"]["since_reset"]`` re-bases them
    on the baseline captured at the last reset so post-reset summaries
    are self-consistent.
    """
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    steps: list[StepMetrics] = field(default_factory=list)
    prefix_cache: dict | None = None
    prefix_cache_baseline: dict | None = None

    def __post_init__(self):
        r = self.registry
        self._steps_c = r.counter(
            "engine_steps_total", "engine scheduler steps taken")
        self._decode_c = r.counter(
            "engine_decode_tokens_total", "tokens emitted by decode/verify")
        self._prefill_c = r.counter(
            "engine_prefill_tokens_total", "prompt tokens absorbed")
        self._draft_c = r.counter(
            "engine_draft_tokens_total", "speculative tokens drafted")
        self._accept_c = r.counter(
            "engine_accepted_tokens_total", "drafted tokens accepted")
        self._rollback_c = r.counter(
            "engine_rollbacks_total", "slots restored from snapshot")
        self._cached_c = r.counter(
            "engine_cached_prefix_tokens_total",
            "prompt tokens served by the prefix cache at admission")
        self._completed_c = r.counter(
            "engine_completed_requests_total", "requests finished")
        self._queue_g = r.gauge(
            "engine_queue_depth", "admission queue depth after the step")
        self._occupancy_g = r.gauge(
            "engine_slot_occupancy", "fraction of slots held")
        self._ttft_h = r.histogram(
            "engine_ttft_seconds", "time to first token per request")
        self._itl_h = r.histogram(
            "engine_itl_seconds", "inter-token latency per emitted token")
        self._wall_h = r.histogram(
            "engine_step_wall_seconds", "wall time per engine step")

    def record_step(self, m: StepMetrics) -> None:
        self.steps.append(m)
        self._steps_c.inc()
        self._decode_c.inc(m.decode_tokens)
        self._prefill_c.inc(m.prefill_tokens)
        self._draft_c.inc(m.draft_tokens)
        self._accept_c.inc(m.accepted_tokens)
        self._rollback_c.inc(m.rollbacks)
        self._cached_c.inc(m.cached_prefix_tokens)
        self._queue_g.set(m.queue_depth)
        self._occupancy_g.set(m.occupancy)
        self._wall_h.observe(m.wall_s)

    def record_first_token(self, ttft: float) -> None:
        self._ttft_h.observe(ttft)

    def record_itl(self, itl: float) -> None:
        self._itl_h.observe(itl)

    def record_finish(self) -> None:
        self._completed_c.inc()

    # views kept for callers that predate the registry migration
    @property
    def ttfts(self) -> list[float]:
        return list(self._ttft_h.samples)

    @property
    def itls(self) -> list[float]:
        return list(self._itl_h.samples)

    @property
    def completed(self) -> int:
        return int(self._completed_c.value)

    def summary(self) -> dict:
        wall = self._wall_h.sum
        dec = int(self._decode_c.value)
        pre = int(self._prefill_c.value)
        drafted = int(self._draft_c.value)
        accepted = int(self._accept_c.value)
        ttft, itl = self._ttft_h, self._itl_h
        out = {
            "steps": int(self._steps_c.value),
            "completed_requests": self.completed,
            "wall_s": wall,
            "decode_tokens": dec,
            "prefill_tokens": pre,
            "decode_tok_s": dec / wall if wall else 0.0,
            "prefill_tok_s": pre / wall if wall else 0.0,
            "ttft_mean_s": (statistics.mean(ttft.samples)
                            if ttft.samples else 0.0),
            "ttft_max_s": max(ttft.samples) if ttft.samples else 0.0,
            "ttft_p50_s": ttft.quantile(0.50) if ttft.count else 0.0,
            "ttft_p95_s": ttft.quantile(0.95) if ttft.count else 0.0,
            "ttft_p99_s": ttft.quantile(0.99) if ttft.count else 0.0,
            "itl_mean_s": itl.mean,
            "itl_p50_s": itl.quantile(0.50) if itl.count else 0.0,
            "itl_p95_s": itl.quantile(0.95) if itl.count else 0.0,
            "itl_p99_s": itl.quantile(0.99) if itl.count else 0.0,
            "mean_occupancy": (statistics.mean(m.occupancy
                                               for m in self.steps)
                               if self.steps else 0.0),
        }
        if drafted:     # speculation ran: surface accept/rollback next
            out.update({   # to TTFT/tok-s (ISSUE 4 engine metrics)
                "draft_tokens": drafted,
                "accepted_tokens": accepted,
                "acceptance_rate": accepted / drafted,
                "rollbacks": int(self._rollback_c.value),
                "mean_speculate_k": statistics.mean(
                    m.speculate_k for m in self.steps if m.speculate_k),
            })
        if self.prefix_cache is not None:   # shared-prefix cache enabled
            out["cached_prefix_tokens"] = int(self._cached_c.value)
            out["prefix_cache"] = dict(self.prefix_cache)
            base = self.prefix_cache_baseline or {}
            since = {k: self.prefix_cache[k] - base.get(k, 0)
                     for k in _CACHE_COUNTER_KEYS
                     if k in self.prefix_cache}
            since["hit_rate"] = (since["hits"] / since["lookups"]
                                 if since.get("lookups") else 0.0)
            since["token_reuse"] = (
                since["hit_tokens"] / since["lookup_tokens"]
                if since.get("lookup_tokens") else 0.0)
            out["prefix_cache"]["since_reset"] = since
        return out


class Scheduler:
    """Token-budget step planner.

    Contract: ``plan()`` is pure — it never mutates sequences or pool
    state; the engine executes the plan and does all accounting. The
    budget charges real model work only: one token per decoding slot
    (``k+1`` with speculation — ``decode_cost``), each prefill chunk at
    its length, and *zero* for prompt tokens the prefix cache served
    (their chunks simply never appear in the sequence's plan), which is
    what lets a cache-hit engine spend its budget on other sequences'
    work instead.

    ``registry`` (optional, rebindable — the engine re-points it at the
    fresh registry on ``reset_metrics``): planning counters published
    per ``plan()`` call; observational only, never read back.
    """

    def __init__(self, token_budget: int,
                 registry: MetricsRegistry | None = None):
        if token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        self.token_budget = token_budget
        self.registry = registry

    def bind_registry(self, registry: MetricsRegistry | None) -> None:
        self.registry = registry
        if registry is not None:
            registry.gauge("scheduler_token_budget",
                           "per-step scheduled-token ceiling"
                           ).set(self.token_budget)

    @staticmethod
    def decode_cost(n_decoding: int, draft_k: int = 0,
                    rejected: int = 0) -> int:
        """Scheduled-token cost of one decode/verify pass.

        Without speculation each decoding slot scores one token. With a
        draft length k the verify call scores k+1 tokens per slot —
        drafted tokens do real model work whether or not they are
        accepted, so they count against the step budget exactly like
        prefill tokens (otherwise speculation would silently starve
        prefill under a 'one token per slot' assumption).

        ``rejected`` is the verified-and-rejected draft count for the
        step: those tokens were scored but their model state was rolled
        back, so the engine refunds them — the net charge equals the
        tokens that actually advanced a stream.  The caller must pass
        the draft length the controller *actually used* for this step
        (captured before ``DraftController.update`` runs), not the
        config ceiling, or the budget double-charges after the
        controller halves k."""
        return n_decoding * (draft_k + 1) - rejected

    def plan(self, sequences: list[Sequence]) -> StepPlan:
        decode = [s for s in sequences
                  if s.status is SequenceStatus.DECODING]
        prefill = sorted((s for s in sequences
                          if s.status is SequenceStatus.PREFILLING),
                         key=lambda s: s.t_submit)
        if self.registry is not None:
            r = self.registry
            r.counter("scheduler_plans_total",
                      "step plans produced").inc()
            r.counter("scheduler_decode_slots_planned_total",
                      "decoding sequences planned").inc(len(decode))
            r.counter("scheduler_prefill_seqs_planned_total",
                      "prefilling sequences planned").inc(len(prefill))
        return StepPlan(decode=decode, prefill=prefill)

    @staticmethod
    def group_prefill(prefill: list[Sequence], budget: int,
                      *, first_exempt: bool = True) -> list[Sequence]:
        """Sequences whose next chunk can run as ONE pooled dispatch.

        FIFO head first: the oldest prefilling sequence fixes the chunk
        length ``c0``; every later sequence whose next chunk is also
        ``c0`` long joins, as long as the accumulated charge fits the
        remaining ``budget`` (the FIFO head itself rides the usual
        one-chunk-per-step starvation exemption when ``first_exempt``).
        Same-length chunks are the batching condition because the
        pooled ``prefill_from_state`` call is a single (slots, c0)
        token block — ragged chunks would need padding, which changes
        the dispatch shape and costs real FLOPs.  Pure: no sequence or
        budget mutation; the engine charges per member as it executes.
        """
        group: list[Sequence] = []
        c0 = None
        for s in prefill:
            if s.prefill_done:
                continue
            c = s.next_chunk
            if c0 is None:
                if c > budget and not first_exempt:
                    break     # FIFO head can't fit: wait, don't skip ahead
                group.append(s)
                c0 = c
                continue
            if c == c0 and (len(group) + 1) * c0 <= budget:
                group.append(s)
        return group
