"""Optimizers: AdamW and LAMB (the paper trains with "fused LAMB").

Large-scale memory policy (docs/design.md §5):
  * ZeRO-1 — moments/master sharded over the ``data`` axis (sharding
    rules live in distributed/sharding.py; this module is layout-free).
  * ``moment_dtype=bfloat16`` halves optimizer memory for the ≥300B MoE
    archs.
  * ``master=False`` + stochastic rounding updates bf16 params directly
    (Gopher-style), removing the fp32 master copy entirely — this is what
    lets grok-1/llama4-maverick train_4k fit a single 256-chip pod.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"           # adamw | lamb
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # float32 | bfloat16
    master: bool = True            # fp32 master copy of bf16 params
    stochastic_round: bool = False # bf16 param update w/o master
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def _mdt(cfg: OptConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def adamw_init(cfg: OptConfig, params):
    mdt = _mdt(cfg)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
    }
    if cfg.master and not cfg.stochastic_round:
        # copy=True: an fp32 param must not alias its master (both are
        # donated by the train step)
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return state


def _stochastic_round_bf16(x32, key):
    """Round fp32 -> bf16 stochastically (unbiased)."""
    bits = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    noise = jax.random.randint(key, x32.shape, 0, 1 << 16, jnp.uint32)
    rounded = (bits + noise) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(jnp.bfloat16)


def _adamw_leaf(cfg, lr, t, p, g, mu, nu, master, key):
    g32 = g.astype(jnp.float32)
    mu32 = mu.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g32
    nu32 = nu.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g32 * g32
    mhat = mu32 / (1 - cfg.b1 ** t)
    nhat = nu32 / (1 - cfg.b2 ** t)
    base = master if master is not None else p.astype(jnp.float32)
    upd = mhat / (jnp.sqrt(nhat) + cfg.eps)
    if p.ndim >= 2:  # decoupled weight decay on matrices only
        upd = upd + cfg.weight_decay * base
    new32 = base - lr * upd
    if cfg.stochastic_round and p.dtype == jnp.bfloat16:
        newp = _stochastic_round_bf16(new32, key)
    else:
        newp = new32.astype(p.dtype)
    return newp, mu32.astype(mu.dtype), nu32.astype(nu.dtype), \
        (new32 if master is not None else None)


def adamw_update(cfg: OptConfig, params, grads, state, *, rng=None):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    lr = cosine_schedule(cfg, step)
    masters = state.get("master")
    leaves, treedef = jax.tree.flatten(params)
    gl = treedef.flatten_up_to(grads)
    mul = treedef.flatten_up_to(state["mu"])
    nul = treedef.flatten_up_to(state["nu"])
    mal = treedef.flatten_up_to(masters) if masters is not None \
        else [None] * len(leaves)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    keys = jax.random.split(rng, len(leaves))
    outs = [_adamw_leaf(cfg, lr, t, p, g, m, n, ma, k)
            for p, g, m, n, ma, k in zip(leaves, gl, mul, nul, mal, keys)]
    new_params = treedef.unflatten([o[0] for o in outs])
    new_state = {
        "step": step,
        "mu": treedef.unflatten([o[1] for o in outs]),
        "nu": treedef.unflatten([o[2] for o in outs]),
    }
    if masters is not None:
        new_state["master"] = treedef.unflatten([o[3] for o in outs])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# LAMB — the paper's optimizer (Appendix C: "fused LAMB")
# ---------------------------------------------------------------------------

def lamb_update(cfg: OptConfig, params, grads, state, *, rng=None):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    lr = cosine_schedule(cfg, step)

    def leaf(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g32
        nu32 = nu.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g32 * g32
        mhat = mu32 / (1 - cfg.b1 ** t)
        nhat = nu32 / (1 - cfg.b2 ** t)
        p32 = p.astype(jnp.float32)
        upd = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * p32
            wnorm = jnp.sqrt(jnp.sum(p32 * p32))
            unorm = jnp.sqrt(jnp.sum(upd * upd))
            trust = jnp.where((wnorm > 0) & (unorm > 0), wnorm / unorm, 1.0)
        else:
            trust = 1.0
        new = p32 - lr * trust * upd
        return new.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    out = jax.tree.map(leaf, params, grads, state["mu"], state["nu"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = treedef.unflatten([o[0] for o in flat])
    new_mu = treedef.unflatten([o[1] for o in flat])
    new_nu = treedef.unflatten([o[2] for o in flat])
    return new_params, {"step": step, "mu": new_mu, "nu": new_nu}, \
        {"grad_norm": gnorm, "lr": lr}


def lamb_init(cfg: OptConfig, params):
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, _mdt(cfg)), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, _mdt(cfg)), params),
    }


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return (lambda p: adamw_init(cfg, p),
                lambda p, g, s, rng=None: adamw_update(cfg, p, g, s, rng=rng))
    if cfg.name == "lamb":
        return (lambda p: lamb_init(cfg, p),
                lambda p, g, s, rng=None: lamb_update(cfg, p, g, s, rng=rng))
    raise ValueError(cfg.name)
