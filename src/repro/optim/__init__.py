from repro.optim.optimizers import (  # noqa: F401
    OptConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    lamb_init,
    lamb_update,
    make_optimizer,
)
