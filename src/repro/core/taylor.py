"""TaylorShift attention (Nauen et al., 2024) — core algorithms.

Implements, in pure JAX (jnp) at reference quality:

  * ``taylor_softmax``            — T-SM^(2), Eq. (1) building block
  * ``direct_taylorshift``        — O(N^2 d), materializes the N×N matrix
  * ``efficient_taylorshift``     — O(N d^3) via the ⊠ tensor-product trick,
                                    Algorithm 1 normalization
  * ``causal_*`` variants         — chunkwise prefix-state forms (beyond
                                    paper; needed for decoder LMs)
  * ``TaylorState`` + decode step — constant-memory recurrent decode

Shapes follow the paper: per-head ``q, k, v: (..., N, d)``. Batch/head
dims are leading ``...`` dims; everything vmaps/broadcasts over them.

Normalization (paper §3.3 / Algorithm 1):
  alpha   = d ** 0.25
  q <- alpha * tau * q / ||q||,  k <- alpha * k / ||k||
  v_hat   = (1/N) * concat(sqrt(d/N) * 1_N, v)          (denominator col 0)
  Y_hat   = 0.5 * Q^⊠2 A_mod + alpha^2 Q (K^T V̂) + alpha^4 Σ_i V̂_i
  Y       = Y_hat[..., 1:] / Y_hat[..., :1]

The division cancels the common 1/N factor; the sqrt(d/N) on the ones
column makes the output scale ~ sqrt(N/d) * convex-combination, which the
paper chooses so the output has mean size ~1 (Table 1).
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

EPS = 1e-6


# ---------------------------------------------------------------------------
# FLOP / memory models (paper §4) — used by auto-switching and benchmarks.
# ---------------------------------------------------------------------------

def ops_direct(N: int, d: int) -> int:
    """Eq. (5): FLOPs of direct-TaylorShift."""
    return 4 * N * N * d + 6 * N * N


def ops_efficient(N: int, d: int) -> int:
    """Eq. (6): FLOPs of efficient-TaylorShift."""
    return N * (4 * d**3 + 10 * d**2 + 9 * d + 4)


def crossover_n0(d: int) -> float:
    """Eq. (7): sequence length where efficient becomes FLOP-cheaper."""
    return (4 * d**3 + 10 * d**2 + 9 * d + 4) / (4 * d + 6)


def entries_direct(N: int, d: int) -> int:
    """§4.2: peak simultaneous tensor entries, direct."""
    return d * N + 2 * N * N


def entries_efficient(N: int, d: int) -> int:
    """Eq. (8): peak simultaneous tensor entries, efficient."""
    return d * d * (d + 1) + 2 * d * N + (d + 1) * N + d * d * N


def crossover_n1(d: int) -> float:
    """Eq. (9): sequence length where efficient becomes memory-cheaper."""
    return 0.25 * (
        d * d + 2 * d + 1
        + math.sqrt(d**4 + 12 * d**3 + 14 * d**2 + 4 * d + 1)
    )


# Measured-crossover override hook (repro.tune installs one): a callable
# ``hook(d, kind) -> float | None`` where kind is "n0" (speed, Eq. 7) or
# "n1" (memory, Eq. 9). None falls through to the analytic value, so an
# installed-but-sparse calibration table only overrides the head dims it
# actually measured. Module-global on purpose: every pick_mode caller —
# select_backend, select_serve_plan, attention-layer re-derivations at
# trace time — must see the same thresholds or routing decisions split.
_CROSSOVER_HOOK = None


def set_crossover_hook(hook) -> None:
    """Install (or with ``None`` clear) the measured-crossover hook."""
    global _CROSSOVER_HOOK
    _CROSSOVER_HOOK = hook


def effective_n0(d: int) -> float:
    """N0 with any calibrated override applied (else Eq. 7)."""
    if _CROSSOVER_HOOK is not None:
        v = _CROSSOVER_HOOK(d, "n0")
        if v is not None:
            return float(v)
    return crossover_n0(d)


def effective_n1(d: int) -> float:
    """N1 with any calibrated override applied (else Eq. 9)."""
    if _CROSSOVER_HOOK is not None:
        v = _CROSSOVER_HOOK(d, "n1")
        if v is not None:
            return float(v)
    return crossover_n1(d)


def pick_mode(N: int, d: int, *, optimize_for: str = "speed",
              n0: float | None = None, n1: float | None = None) -> str:
    """Paper's "and Back": choose direct vs efficient from the crossover.

    ``n0``/``n1`` pin explicit (e.g. site-calibrated) thresholds;
    otherwise the effective values — calibrated when a tuning table is
    installed (:func:`set_crossover_hook`), analytic Eq. (7)/(9) else —
    decide."""
    if optimize_for == "speed":
        thresh = n0 if n0 is not None else effective_n0(d)
    else:
        thresh = n1 if n1 is not None else effective_n1(d)
    return "efficient" if N >= thresh else "direct"


# ---------------------------------------------------------------------------
# Taylor softmax and input normalization
# ---------------------------------------------------------------------------

def taylor_exp(x: jnp.ndarray) -> jnp.ndarray:
    """2nd-order Taylor approximation of exp around 0: 1 + x + x^2/2."""
    return 1.0 + x + 0.5 * x * x


def taylor_softmax(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """T-SM^(2)(x) = normalize(1 + x + x^2/2); positive for even order."""
    t = taylor_exp(x)
    return t / jnp.sum(t, axis=axis, keepdims=True)


def l2_normalize(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Row-wise l2 normalization in fp32 (paper §3.3).

    Safe-norm formulation: the naive ``x / (||x|| + eps)`` family gives a
    spurious O(1/sqrt(eps)) gradient (or NaN, with eps outside the sqrt)
    for an all-zero row, because autodiff differentiates through the sqrt
    near 0. The double-``where`` below keeps sqrt's argument strictly
    positive on *both* autodiff branches, so a zero row returns zero with
    an exactly-zero gradient.
    """
    x32 = x.astype(jnp.float32)
    sq = jnp.sum(x32 * x32, axis=axis, keepdims=True)
    # threshold at EPS² (‖x‖ ≤ 1e-6 counts as zero): below it the
    # quotient-rule term x_i·x_j/‖x‖³ overflows fp32 even though the
    # true gradient is finite
    nonzero = sq > EPS * EPS
    inv = jax.lax.rsqrt(jnp.where(nonzero, sq, 1.0))
    return jnp.where(nonzero, x32 * inv, 0.0).astype(x.dtype)


def normalize_qk(q, k, tau):
    """q <- tau * q/||q||, k <- k/||k|| (the alpha factor is applied by
    each implementation together with its Taylor coefficients)."""
    q = l2_normalize(q) * tau
    k = l2_normalize(k)
    return q, k


# ---------------------------------------------------------------------------
# Direct TaylorShift — O(N^2 d)
# ---------------------------------------------------------------------------

def direct_taylorshift(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    tau: jnp.ndarray | float = 1.0,
    causal: bool = False,
    normalize_inputs: bool = True,
    output_scale: bool = True,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Direct implementation of Eq. (1) with §3.3 normalization.

    q, k, v: (..., N, d) / (..., M, d) — supports cross-attention (M keys).
    Returns (..., N, d_v).
    """
    N = q.shape[-2]
    d = q.shape[-1]
    if normalize_inputs:
        q, k = normalize_qk(q, k, tau)
    x = jnp.einsum("...nd,...md->...nm", q, k,
                   preferred_element_type=jnp.float32)
    a = taylor_exp(x)
    if causal:
        Nq, Nk = a.shape[-2], a.shape[-1]
        cm = jnp.tril(jnp.ones((Nq, Nk), dtype=bool), Nk - Nq)
        a = jnp.where(cm, a, 0.0)
    if mask is not None:
        a = jnp.where(mask, a, 0.0)
    denom = jnp.sum(a, axis=-1, keepdims=True)
    y = jnp.einsum("...nm,...md->...nd", a / denom, v.astype(a.dtype))
    if output_scale:
        # Paper multiplies the output by sqrt(N/d) so its mean size is ~1
        # (Table 1); N is the number of *keys* attended over. For the
        # causal form that count is per-row (i+1), matching the recurrent
        # decode convention.
        if causal:
            Nq, Nk = a.shape[-2], a.shape[-1]
            counts = jnp.arange(Nk - Nq + 1, Nk + 1, dtype=jnp.float32)
            y = y * jnp.sqrt(counts / d)[..., :, None]
        else:
            y = y * jnp.sqrt(k.shape[-2] / d)
    return y.astype(v.dtype)


# ---------------------------------------------------------------------------
# Efficient TaylorShift — O(N d^3), Algorithm 1
# ---------------------------------------------------------------------------

def boxtimes(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-row tensor product ⊠: (..., N, d1) x (..., N, d2) -> (..., N, d1*d2)."""
    out = a[..., :, :, None] * b[..., :, None, :]
    return out.reshape(*out.shape[:-2], a.shape[-1] * b.shape[-1])


def _vhat(v: jnp.ndarray, n_keys: int, d: int) -> jnp.ndarray:
    """Line 5 of Algorithm 1: V̂ = (1/N) concat(sqrt(d/N)·1, V), fp32."""
    ones = jnp.full((*v.shape[:-1], 1), math.sqrt(d / n_keys), v.dtype)
    return jnp.concatenate([ones, v], axis=-1).astype(jnp.float32) / n_keys


def efficient_taylorshift(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    tau: jnp.ndarray | float = 1.0,
    normalize_inputs: bool = True,
    output_scale: bool = True,
) -> jnp.ndarray:
    """Algorithm 1 (non-causal). q: (..., N, d); k, v: (..., M, d)."""
    d = q.shape[-1]
    M = k.shape[-2]
    alpha = d ** 0.25
    if normalize_inputs:
        q, k = normalize_qk(q, k, tau)
    q = (q * alpha).astype(jnp.float32)
    k = (k * alpha).astype(jnp.float32)
    vh = _vhat(v, M, d) if output_scale else _vhat_unit(v, M)

    a_mod = jnp.einsum("...me,...mf->...ef", boxtimes(k, k), vh)   # (d², d+1)
    y_hat = 0.5 * jnp.einsum("...ne,...ef->...nf", boxtimes(q, q), a_mod)
    kv = jnp.einsum("...md,...mf->...df", k, vh)                    # (d, d+1)
    y_hat += (alpha**2) * jnp.einsum("...nd,...df->...nf", q, kv)
    y_hat += (alpha**4) * jnp.sum(vh, axis=-2, keepdims=True)
    denom, nom = y_hat[..., :1], y_hat[..., 1:]
    return (nom / denom).astype(v.dtype)


def _vhat_unit(v: jnp.ndarray, n_keys: int) -> jnp.ndarray:
    """V̂ without the sqrt(d/N) output scaling (ones column = 1)."""
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    return jnp.concatenate([ones, v], axis=-1).astype(jnp.float32) / n_keys


def efficient_taylorshift_sharded(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    tau: jnp.ndarray | float = 1.0,
    axis_name: str | None = None,
    n_global: int | None = None,
    normalize_inputs: bool = True,
    output_scale: bool = True,
) -> jnp.ndarray:
    """Algorithm 1 with the key axis sharded over mesh axis ``axis_name``.

    For callers already inside a fully-manual shard_map region (the
    composed 3D train step): k/v hold this shard's keys, and the three
    key-side sums (A_mod, K^T V̂, ΣV̂) — each O(d³) floats, independent
    of sequence length — are the *only* cross-shard traffic, one psum
    apiece. ``n_global`` is the full (unsharded) key count; V̂'s 1/N and
    sqrt(d/N) factors use it so the psum of per-shard partial sums equals
    the single-device result exactly. Readout stays per-shard per-query.
    Differentiable by plain autodiff: psum's transpose is the true
    adjoint (cross-shard cotangents sum), so ∇k/∇v match the reference.
    """
    d = q.shape[-1]
    n_global = n_global if n_global is not None else k.shape[-2]
    alpha = d ** 0.25
    if normalize_inputs:
        q, k = normalize_qk(q, k, tau)
    q = (q * alpha).astype(jnp.float32)
    k = (k * alpha).astype(jnp.float32)
    vh = _vhat(v, n_global, d) if output_scale else _vhat_unit(v, n_global)

    a_mod = jnp.einsum("...me,...mf->...ef", boxtimes(k, k), vh)   # (d², d+1)
    kv = jnp.einsum("...md,...mf->...df", k, vh)                    # (d, d+1)
    s0 = jnp.sum(vh, axis=-2, keepdims=True)                        # (1, d+1)
    if axis_name is not None:
        a_mod = jax.lax.psum(a_mod, axis_name)
        kv = jax.lax.psum(kv, axis_name)
        s0 = jax.lax.psum(s0, axis_name)
    y_hat = 0.5 * jnp.einsum("...ne,...ef->...nf", boxtimes(q, q), a_mod)
    y_hat += (alpha**2) * jnp.einsum("...nd,...df->...nf", q, kv)
    y_hat += (alpha**4) * s0
    denom, nom = y_hat[..., :1], y_hat[..., 1:]
    return (nom / denom).astype(v.dtype)


# ---------------------------------------------------------------------------
# Causal TaylorShift (beyond paper): chunkwise prefix states
# ---------------------------------------------------------------------------
#
# Y_nom[i] = Σ_{j<=i} (½ x_ij² + α² x_ij + α⁴) v̂_j    with x_ij = q_i·k_j
#          = ½ q_i^⊠2 S2[i] + α² q_i S1[i] + α⁴ S0[i]
# where S2[i] = Σ_{j<=i} k_j^⊠2 ⊗ v̂_j ∈ R^{d²×(d+1)}, etc.
#
# Chunked: split N into chunks of C. Inter-chunk term uses the exclusive
# chunk-prefix state (a lax scan / associative cumsum over chunk sums);
# intra-chunk term is the masked direct form, O(C²d).

class TaylorState(NamedTuple):
    """Recurrent decode state — replaces the KV cache.

    s2: (..., d²,  d+1) fp32     s1: (..., d, d+1) fp32
    s0: (..., 1,   d+1) fp32     n:  tokens absorbed so far, int32 —
    scalar () for a single shared context length, or (B,) for per-sequence
    counts (continuous-batching slot pools, where every slot sits at a
    different position).
    """
    s2: jnp.ndarray
    s1: jnp.ndarray
    s0: jnp.ndarray
    n: jnp.ndarray

    @staticmethod
    def zeros(batch_dims: tuple, d: int, dtype=jnp.float32,
              n_dims: tuple = ()) -> "TaylorState":
        return TaylorState(
            s2=jnp.zeros((*batch_dims, d * d, d + 1), dtype),
            s1=jnp.zeros((*batch_dims, d, d + 1), dtype),
            s0=jnp.zeros((*batch_dims, 1, d + 1), dtype),
            n=jnp.zeros(n_dims, jnp.int32),
        )


def _nb(n: jnp.ndarray, ndim: int) -> jnp.ndarray:
    """Broadcast a token count — scalar () or per-sequence (B,) — against
    an (B, ..., T, d)-shaped tensor of rank ``ndim`` (B leading)."""
    n = jnp.asarray(n, jnp.float32)
    return n.reshape(n.shape + (1,) * (ndim - n.ndim))


def _chunk_sums(k, vh):
    """Per-chunk state contributions. k: (..., G, C, d), vh: (..., G, C, d+1)."""
    s2 = jnp.einsum("...gce,...gcf->...gef", boxtimes(k, k), vh)
    s1 = jnp.einsum("...gcd,...gcf->...gdf", k, vh)
    s0 = jnp.sum(vh, axis=-2, keepdims=True)
    return s2, s1, s0


def _reduce_to(x: jnp.ndarray, shape) -> jnp.ndarray:
    """Sum ``x`` down to ``shape`` along broadcast axes (GQA lead dims)."""
    if x.shape == tuple(shape):
        return x
    axes = tuple(i for i, (a, b) in enumerate(zip(x.shape, shape))
                 if b == 1 and a != 1)
    return jnp.sum(x, axis=axes, keepdims=True)


# -- chunkwise scan core with a recompute-based custom VJP -------------------
#
# jax.grad through a lax.scan saves every per-chunk carry — here the
# (d², d+1) prefix state, i.e. O((N/C)·d³) residual bytes, which defeats
# the linear-memory claim for training. The custom VJP below keeps only
# the *inputs* as residuals and recomputes the states in the backward:
#
#   pass 1 (forward scan):  re-derive the exclusive prefix state S_g and
#     produce dQ_g (readout is quadratic in q, linear in S) plus the
#     intra-chunk dK/dV (masked direct form inside the chunk);
#   pass 2 (reverse scan):  carry the state cotangent D_g = Σ_{g'>g}
#     ∂readout_{g'}/∂S (+ the final-state cotangent) and produce the
#     inter-chunk dK/dV through each chunk's state contribution.
#
# Both scans have O(1) carries, so backward peak memory is O(N·d + d³).

def _causal_scan_impl(sharder, qm, km, vm, s2_0, s1_0, s0_0):
    """Primal chunked scan. qm: (G, *lead, C, d); km/vm may have
    broadcastable lead dims (GQA). Returns (ys, s2, s1, s0)."""
    C, d = qm.shape[-2], qm.shape[-1]
    alpha = d ** 0.25
    cm = jnp.tril(jnp.ones((C, C), dtype=bool))

    def chunk_body(carry, inp):
        """One chunk: inter-chunk readout from the running state + masked
        intra-chunk direct term; then absorb this chunk into the state.
        Streaming (lax.scan) keeps exactly ONE (d², d+1) state live —
        materializing all N/C prefix states costs O(B·KV·(N/C)·d³) bytes,
        which at d=128 dominated HBM (§Perf iteration 5)."""
        s2, s1, s0 = carry
        qc, kc, vc = inp                       # (*lead, chunk, d/d+1)
        y = 0.5 * jnp.einsum("...ce,...ef->...cf", boxtimes(qc, qc), s2)
        y += (alpha**2) * jnp.einsum("...cd,...df->...cf", qc, s1)
        y += (alpha**4) * s0
        # intra-chunk: q,k are alpha-scaled, so the Taylor numerator
        # alpha^4*(1 + x_u + x_u^2/2) becomes x^2/2 + alpha^2 x + alpha^4
        # (Alg. 1 line 9 coefficients).
        x = jnp.einsum("...cd,...ed->...ce", qc, kc)
        a = 0.5 * x * x + (alpha**2) * x + alpha**4
        a = jnp.where(cm, a, 0.0)
        y += jnp.einsum("...ce,...ef->...cf", a, vc)
        s2 = s2 + jnp.einsum("...ce,...cf->...ef", boxtimes(kc, kc), vc)
        s1 = s1 + jnp.einsum("...cd,...cf->...df", kc, vc)
        s0 = s0 + jnp.sum(vc, axis=-2, keepdims=True)
        if sharder is not None:
            s2 = sharder(s2)
        return (s2, s1, s0), y

    (s2, s1, s0), ys = jax.lax.scan(chunk_body, (s2_0, s1_0, s0_0),
                                    (qm, km, vm))
    return ys, s2, s1, s0


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _causal_scan(sharder, qm, km, vm, s2_0, s1_0, s0_0):
    return _causal_scan_impl(sharder, qm, km, vm, s2_0, s1_0, s0_0)


def _causal_scan_fwd(sharder, qm, km, vm, s2_0, s1_0, s0_0):
    out = _causal_scan_impl(sharder, qm, km, vm, s2_0, s1_0, s0_0)
    return out, (qm, km, vm, s2_0, s1_0, s0_0)


def _causal_scan_bwd(sharder, res, cot):
    qm, km, vm, s2_0, s1_0, s0_0 = res
    yb_all, dS2_f, dS1_f, dS0_f = cot          # yb: (G, *lead, C, d+1)
    d = qm.shape[-1]
    C = qm.shape[-2]
    alpha = d ** 0.25
    cm = jnp.tril(jnp.ones((C, C), dtype=bool))

    def mat(r):                                 # (..., C, d²) -> (..., C, d, d)
        return r.reshape(*r.shape[:-1], d, d)

    def fwd_body(carry, inp):
        """Recompute the exclusive prefix state; emit dQ and the
        intra-chunk dK/dV parts."""
        s2, s1, s0 = carry
        qc, kc, vc, yb = inp
        M = mat(jnp.einsum("...ef,...cf->...ce", s2, yb))
        dq = 0.5 * (jnp.einsum("...cab,...cb->...ca", M, qc)
                    + jnp.einsum("...cba,...cb->...ca", M, qc))
        dq += (alpha**2) * jnp.einsum("...df,...cf->...cd", s1, yb)
        x = jnp.einsum("...cd,...ed->...ce", qc, kc)
        da = jnp.where(cm, jnp.einsum("...cf,...ef->...ce", yb, vc), 0.0)
        dx = da * (x + alpha**2)
        dq += jnp.einsum("...ce,...ed->...cd", dx, kc)
        dk_i = jnp.einsum("...ce,...cd->...ed", dx, qc)
        a = jnp.where(cm, 0.5 * x * x + (alpha**2) * x + alpha**4, 0.0)
        dv_i = jnp.einsum("...ce,...cf->...ef", a, yb)
        s2n = s2 + jnp.einsum("...ce,...cf->...ef", boxtimes(kc, kc), vc)
        s1n = s1 + jnp.einsum("...cd,...cf->...df", kc, vc)
        s0n = s0 + jnp.sum(vc, axis=-2, keepdims=True)
        if sharder is not None:
            s2n = sharder(s2n)
        return (s2n, s1n, s0n), (dq, dk_i, dv_i)

    _, (dq, dk_i, dv_i) = jax.lax.scan(
        fwd_body, (s2_0, s1_0, s0_0), (qm, km, vm, yb_all))

    def rev_body(carry, inp):
        """Carry D = cotangent of the state *after* this chunk's
        contribution; emit the inter-chunk dK/dV, then fold this chunk's
        readout cotangent into D (its own readout saw the state *before*
        the contribution)."""
        D2, D1, D0 = carry
        qc, kc, vc, yb = inp
        W = mat(jnp.einsum("...ef,...cf->...ce", D2, vc))
        dk_s = (jnp.einsum("...cab,...cb->...ca", W, kc)
                + jnp.einsum("...cba,...cb->...ca", W, kc))
        dk_s += jnp.einsum("...df,...cf->...cd", D1, vc)
        dv_s = jnp.einsum("...ce,...ef->...cf", boxtimes(kc, kc), D2)
        dv_s += jnp.einsum("...cd,...df->...cf", kc, D1)
        dv_s = dv_s + D0
        D2n = D2 + _reduce_to(
            0.5 * jnp.einsum("...ce,...cf->...ef", boxtimes(qc, qc), yb),
            D2.shape)
        D1n = D1 + _reduce_to(
            (alpha**2) * jnp.einsum("...cd,...cf->...df", qc, yb), D1.shape)
        D0n = D0 + _reduce_to(
            (alpha**4) * jnp.sum(yb, axis=-2, keepdims=True), D0.shape)
        return (D2n, D1n, D0n), (dk_s, dv_s)

    (dS2_0, dS1_0, dS0_0), (dk_s, dv_s) = jax.lax.scan(
        rev_body, (dS2_f, dS1_f, dS0_f), (qm, km, vm, yb_all), reverse=True)

    dk = _reduce_to(dk_i, km.shape) + _reduce_to(dk_s, km.shape)
    dv = _reduce_to(dv_i, vm.shape) + _reduce_to(dv_s, vm.shape)
    return dq, dk, dv, dS2_0, dS1_0, dS0_0


_causal_scan.defvjp(_causal_scan_fwd, _causal_scan_bwd)


# -- sequence-parallel chunk scan (associative formulation) ------------------
#
# The prefix states S2/S1/S0 are plain sums of per-chunk contributions, so
# they compose *associatively*: combine(a, b) of two segment partials is the
# partial of the concatenated segment ("Transformers are RNNs", but with a
# trivially associative ⊕). That licenses
#
#   1. within a device: jax.lax.associative_scan over the chunk axis — the
#      G chunk states materialize at once (O(G·d³) memory, vs the O(d³)
#      streaming scan) but every chunk's readout runs in parallel;
#   2. across devices: a chunk-boundary exchange over a `seq` mesh axis —
#      each shard all-gathers the *totals* of the other shards and adds the
#      ones before (forward) / after (backward) its own index. The exchange
#      lives in distributed/seqscan.py (shard_map); the impl functions here
#      take an ``axis_name`` so the same math serves both layers.
#
# The backward is the same recompute strategy as _causal_scan_bwd, but with
# both passes parallel: pass 1 re-derives the exclusive prefix states with
# the associative scan; pass 2 turns the per-chunk readout cotangents into
# suffix sums (a reverse associative scan + the cross-shard suffix
# exchange) instead of a reverse lax.scan.

def combine_states(a: TaylorState, b: TaylorState) -> TaylorState:
    """Associative combine: state of segment A ++ segment B.

    Elementwise sums (and token-count addition), hence associative *and*
    commutative — the property the sequence-parallel scan rests on
    (tests/test_seq_parallel.py pins it).
    """
    return TaylorState(s2=a.s2 + b.s2, s1=a.s1 + b.s1, s0=a.s0 + b.s0,
                       n=a.n + b.n)


def _tuple_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _par_partials(km, vm):
    """Per-chunk state contributions, all chunks at once.

    km: (G, *klead, C, d); vm: (G, *vlead, C, d+1).
    Returns (p2, p1, p0) with a leading chunk axis.
    """
    p2 = jnp.einsum("...ce,...cf->...ef", boxtimes(km, km), vm)
    p1 = jnp.einsum("...cd,...cf->...df", km, vm)
    p0 = jnp.sum(vm, axis=-2, keepdims=True)
    return p2, p1, p0


def _pshift(x, axis_name, axis_size, shift):
    """x from the shard ``shift`` positions earlier on the axis (exact
    zeros where no source exists — non-wrapping ppermute semantics).
    ``shift < 0`` pulls from later shards."""
    if shift >= 0:
        perm = [(i, i + shift) for i in range(axis_size - shift)]
    else:
        perm = [(i, i + shift) for i in range(-shift, axis_size)]
    return jax.lax.ppermute(x, axis_name, perm)


def _shard_prefix_exchange(totals, axis_name, axis_size):
    """Exclusive prefix over the `seq` mesh axis of per-shard totals.

    Returns (incoming, global_total): the sum of every shard strictly
    before this one, and the sum over all shards (for the final state).
    Log-depth Hillis–Steele over ppermute + one psum — deliberately no
    ``axis_index``: a mask built from partition-id does not lower when
    the surrounding mesh axes are in GSPMD `auto` mode.
    """
    def one(t):
        inc, shift = t, 1
        while shift < axis_size:                 # inclusive prefix
            inc = inc + _pshift(inc, axis_name, axis_size, shift)
            shift *= 2
        return (_pshift(inc, axis_name, axis_size, 1),
                jax.lax.psum(t, axis_name))
    pairs = [one(t) for t in totals]
    return tuple(p[0] for p in pairs), tuple(p[1] for p in pairs)


def _shard_suffix_exchange(totals, axis_name, axis_size):
    """Exclusive *suffix* over the `seq` axis (backward direction)."""
    def one(t):
        inc, shift = t, 1
        while shift < axis_size:                 # inclusive suffix
            inc = inc + _pshift(inc, axis_name, axis_size, -shift)
            shift *= 2
        return (_pshift(inc, axis_name, axis_size, -1),
                jax.lax.psum(t, axis_name))
    pairs = [one(t) for t in totals]
    return tuple(p[0] for p in pairs), tuple(p[1] for p in pairs)


def _par_states(km, vm, s2_0, s1_0, s0_0, axis_name=None, axis_size=0):
    """Exclusive per-chunk prefix states + the global final state.

    Returns ((e2, e1, e0), (f2, f1, f0)): e* carry a leading chunk axis
    (the state each chunk's readout sees), f* are the state after every
    chunk — across *all* shards when ``axis_name`` is given.
    """
    parts = _par_partials(km, vm)
    inc = jax.lax.associative_scan(_tuple_add, parts, axis=0)
    tot = tuple(t[-1] for t in inc)
    base = (s2_0, s1_0, s0_0)
    if axis_name is not None:
        incoming, global_tot = _shard_prefix_exchange(tot, axis_name,
                                                      axis_size)
        base = _tuple_add(base, incoming)
        fin = _tuple_add((s2_0, s1_0, s0_0), global_tot)
    else:
        fin = _tuple_add(base, tot)
    excl = tuple(
        b[None] + jnp.concatenate([jnp.zeros_like(i[:1]), i[:-1]], axis=0)
        for b, i in zip(base, inc))
    return excl, fin


def _par_readout(qm, km, vm, e2, e1, e0):
    """Inter-chunk readout from per-chunk exclusive states + masked
    intra-chunk direct term — chunk_body's math, all chunks at once."""
    C, d = qm.shape[-2], qm.shape[-1]
    alpha = d ** 0.25
    cm = jnp.tril(jnp.ones((C, C), dtype=bool))
    y = 0.5 * jnp.einsum("...ce,...ef->...cf", boxtimes(qm, qm), e2)
    y += (alpha**2) * jnp.einsum("...cd,...df->...cf", qm, e1)
    y += (alpha**4) * e0
    x = jnp.einsum("...cd,...ed->...ce", qm, km)
    a = jnp.where(cm, 0.5 * x * x + (alpha**2) * x + alpha**4, 0.0)
    y += jnp.einsum("...ce,...ef->...cf", a, vm)
    return y


def _causal_scan_par_impl(qm, km, vm, s2_0, s1_0, s0_0, axis_name=None,
                          axis_size=0):
    """Sequence-parallel primal. Same contract as _causal_scan_impl."""
    (e2, e1, e0), (f2, f1, f0) = _par_states(km, vm, s2_0, s1_0, s0_0,
                                             axis_name, axis_size)
    ys = _par_readout(qm, km, vm, e2, e1, e0)
    return ys, f2, f1, f0


def _causal_scan_par_bwd_impl(qm, km, vm, s2_0, s1_0, s0_0,
                              yb, dS2_f, dS1_f, dS0_f, axis_name=None,
                              axis_size=0):
    """Recompute backward, both passes parallel.

    Pass 1: re-derive the exclusive prefix states (associative scan) and
    emit dQ plus the intra-chunk dK/dV — per chunk, no carry. Pass 2:
    the state cotangent each chunk sees is the *suffix* sum of later
    chunks' readout cotangent contributions (+ the final-state
    cotangent); a reverse associative scan and the suffix boundary
    exchange replace the reverse lax.scan.
    """
    d = qm.shape[-1]
    C = qm.shape[-2]
    alpha = d ** 0.25
    cm = jnp.tril(jnp.ones((C, C), dtype=bool))

    def mat(r):                                 # (..., C, d²) -> (..., C, d, d)
        return r.reshape(*r.shape[:-1], d, d)

    # pass 1: recompute exclusive states; dQ + intra-chunk dK/dV
    (e2, e1, e0), _ = _par_states(km, vm, s2_0, s1_0, s0_0, axis_name,
                                  axis_size)
    M = mat(jnp.einsum("...ef,...cf->...ce", e2, yb))
    dq = 0.5 * (jnp.einsum("...cab,...cb->...ca", M, qm)
                + jnp.einsum("...cba,...cb->...ca", M, qm))
    dq += (alpha**2) * jnp.einsum("...df,...cf->...cd", e1, yb)
    x = jnp.einsum("...cd,...ed->...ce", qm, km)
    da = jnp.where(cm, jnp.einsum("...cf,...ef->...ce", yb, vm), 0.0)
    dx = da * (x + alpha**2)
    dq += jnp.einsum("...ce,...ed->...cd", dx, km)
    dk_i = jnp.einsum("...ce,...cd->...ed", dx, qm)
    a = jnp.where(cm, 0.5 * x * x + (alpha**2) * x + alpha**4, 0.0)
    dv_i = jnp.einsum("...ce,...cf->...ef", a, yb)

    # pass 2: per-chunk readout cotangent contributions -> suffix sums
    R2 = _reduce_to(
        0.5 * jnp.einsum("...ce,...cf->...ef", boxtimes(qm, qm), yb),
        (qm.shape[0], *s2_0.shape))
    R1 = _reduce_to((alpha**2) * jnp.einsum("...cd,...cf->...df", qm, yb),
                    (qm.shape[0], *s1_0.shape))
    R0 = _reduce_to((alpha**4) * jnp.sum(yb, axis=-2, keepdims=True),
                    (qm.shape[0], *s0_0.shape))
    suf = jax.lax.associative_scan(_tuple_add, (R2, R1, R0), axis=0,
                                   reverse=True)          # inclusive suffix
    tot = tuple(t[0] for t in suf)                        # all local chunks
    Dbase = (dS2_f, dS1_f, dS0_f)
    if axis_name is not None:
        outgoing, global_tot = _shard_suffix_exchange(tot, axis_name,
                                                      axis_size)
        Dbase = _tuple_add(Dbase, outgoing)
        dS0s = _tuple_add((dS2_f, dS1_f, dS0_f), global_tot)
    else:
        dS0s = _tuple_add(Dbase, tot)
    # exclusive suffix: chunk g's readout saw the state *before* its own
    # contribution, so its own R folds in only for earlier chunks
    Dex = tuple(
        b[None] + jnp.concatenate([s[1:], jnp.zeros_like(s[:1])], axis=0)
        for b, s in zip(Dbase, suf))
    D2, D1, D0 = Dex

    W = mat(jnp.einsum("...ef,...cf->...ce", D2, vm))
    dk_s = (jnp.einsum("...cab,...cb->...ca", W, km)
            + jnp.einsum("...cba,...cb->...ca", W, km))
    dk_s += jnp.einsum("...df,...cf->...cd", D1, vm)
    dv_s = jnp.einsum("...ce,...ef->...cf", boxtimes(km, km), D2)
    dv_s += jnp.einsum("...cd,...df->...cf", km, D1)
    dv_s = dv_s + D0

    dk = _reduce_to(dk_i, km.shape) + _reduce_to(dk_s, km.shape)
    dv = _reduce_to(dv_i, vm.shape) + _reduce_to(dv_s, vm.shape)
    return dq, dk, dv, dS0s[0], dS0s[1], dS0s[2]


@jax.custom_vjp
def _causal_scan_par(qm, km, vm, s2_0, s1_0, s0_0):
    return _causal_scan_par_impl(qm, km, vm, s2_0, s1_0, s0_0)


def _causal_scan_par_fwd(qm, km, vm, s2_0, s1_0, s0_0):
    out = _causal_scan_par_impl(qm, km, vm, s2_0, s1_0, s0_0)
    return out, (qm, km, vm, s2_0, s1_0, s0_0)


def _causal_scan_par_bwd(res, cot):
    yb, dS2_f, dS1_f, dS0_f = cot
    return _causal_scan_par_bwd_impl(*res, yb, dS2_f, dS1_f, dS0_f)


_causal_scan_par.defvjp(_causal_scan_par_fwd, _causal_scan_par_bwd)


def causal_taylorshift(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    tau: jnp.ndarray | float = 1.0,
    chunk: int = 128,
    normalize_inputs: bool = True,
    output_scale: bool = True,
    initial_state: TaylorState | None = None,
    return_state: bool = False,
    state_sharder=None,
    scan_impl: str = "sequential",
    scan_fn=None,
):
    """Chunkwise-parallel causal efficient-TaylorShift.

    q, k, v: (..., N, d) with N divisible by ``chunk`` (pad upstream).
    ``initial_state`` continues from previous context (chunked prefill).

    ``scan_impl`` selects the chunk-scan core: ``"sequential"`` streams
    one state through ``lax.scan`` (O(d³) live state — the training
    default, §Perf iteration 5); ``"parallel"`` runs the associative
    formulation (all chunk states live, every readout parallel — the
    per-shard body of the sequence-parallel path). ``scan_fn``, when
    given, overrides both: it must match ``_causal_scan``'s
    ``(qm, km, vm, s2_0, s1_0, s0_0) -> (ys, s2, s1, s0)`` contract —
    this is how ``distributed.seqscan`` injects the mesh-level scan.

    State convention (shared with :func:`taylor_decode_step`): raw,
    *unnormalized* prefix sums in fp32 with ones-column = 1. Algorithm 1's
    1/N factor cancels in nom/denom; the sqrt(N/d) output scaling is
    applied per-row with the row's true context length, matching what the
    decode step produces token by token.
    """
    *lead, N, d = q.shape
    assert N % chunk == 0, f"N={N} must be divisible by chunk={chunk}"
    G = N // chunk
    alpha = d ** 0.25
    if normalize_inputs:
        q, k = normalize_qk(q, k, tau)
    q = (q * alpha).astype(jnp.float32)
    k = (k * alpha).astype(jnp.float32)
    n_prev = (initial_state.n if initial_state is not None
              else jnp.zeros((), jnp.int32))
    ones = jnp.ones((*v.shape[:-1], 1), jnp.float32)
    vh = jnp.concatenate([ones, v.astype(jnp.float32)], axis=-1)

    # k/v may have broadcastable lead dims (GQA: (B, KV, 1, N, d) against
    # q's (B, KV, G_q, N, d)) — reshape each with its own leads.
    klead = k.shape[:-2]
    vlead = vh.shape[:-2]
    qg = q.reshape(*lead, G, chunk, d)
    kg = k.reshape(*klead, G, chunk, d)
    vg = vh.reshape(*vlead, G, chunk, d + 1)

    slead = klead  # state lead = k's lead (shared across GQA groups)
    if initial_state is not None:
        s2_0 = jnp.broadcast_to(initial_state.s2,
                                (*slead, d * d, d + 1)).astype(jnp.float32)
        s1_0 = jnp.broadcast_to(initial_state.s1,
                                (*slead, d, d + 1)).astype(jnp.float32)
        s0_0 = jnp.broadcast_to(initial_state.s0,
                                (*slead, 1, d + 1)).astype(jnp.float32)
    else:
        s2_0 = jnp.zeros((*slead, d * d, d + 1), jnp.float32)
        s1_0 = jnp.zeros((*slead, d, d + 1), jnp.float32)
        s0_0 = jnp.zeros((*slead, 1, d + 1), jnp.float32)

    gax = len(lead)
    move = lambda t: jnp.moveaxis(t, gax, 0)
    # Chunkwise scan with a recompute-based custom VJP (see _causal_scan /
    # _causal_scan_par): training through either path keeps backward
    # memory free of the O((N/C)·d³) per-chunk-state checkpoints a plain
    # autodiff-of-scan would save.
    if scan_fn is not None:
        ys, s2, s1, s0 = scan_fn(move(qg), move(kg), move(vg),
                                 s2_0, s1_0, s0_0)
    elif scan_impl == "parallel":
        ys, s2, s1, s0 = _causal_scan_par(move(qg), move(kg), move(vg),
                                          s2_0, s1_0, s0_0)
    else:
        ys, s2, s1, s0 = _causal_scan(state_sharder, move(qg), move(kg),
                                      move(vg), s2_0, s1_0, s0_0)
    y_hat = jnp.moveaxis(ys, 0, gax).reshape(*lead, N, d + 1)

    denom, nom = y_hat[..., :1], y_hat[..., 1:]
    y = nom / denom
    if output_scale:
        counts = _nb(n_prev, y.ndim - 1) + jnp.arange(1, N + 1,
                                                      dtype=jnp.float32)
        y = y * jnp.sqrt(counts / d)[..., None]
    y = y.astype(v.dtype)
    if not return_state:
        return y
    state = TaylorState(s2=s2, s1=s1, s0=s0, n=n_prev + N)
    return y, state


# ---------------------------------------------------------------------------
# Recurrent decode — one token, O(d^2 (d+1)), constant memory
# ---------------------------------------------------------------------------

def taylor_decode_step(
    state: TaylorState,
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    tau: jnp.ndarray | float = 1.0,
    normalize_inputs: bool = True,
    output_scale: bool = True,
):
    """Absorb one (k, v) into the state and attend with one q.

    q, k, v: (..., 1, d). State tensors are *unnormalized* sums (the 1/N
    of Algorithm 1 cancels in the division; we apply only the output
    scaling column explicitly). Returns (y, new_state), y: (..., 1, d).
    """
    d = q.shape[-1]
    alpha = d ** 0.25
    if normalize_inputs:
        q, k = normalize_qk(q, k, tau)
    q = (q * alpha).astype(jnp.float32)
    k = (k * alpha).astype(jnp.float32)
    ones = jnp.ones((*v.shape[:-1], 1), jnp.float32)
    vh = jnp.concatenate([ones, v.astype(jnp.float32)], axis=-1)  # (...,1,d+1)

    s2 = state.s2 + jnp.einsum("...ce,...cf->...ef", boxtimes(k, k), vh)
    s1 = state.s1 + jnp.einsum("...cd,...cf->...df", k, vh)
    s0 = state.s0 + vh
    n = state.n + 1

    y_hat = 0.5 * jnp.einsum("...ce,...ef->...cf", boxtimes(q, q), s2)
    y_hat += (alpha**2) * jnp.einsum("...cd,...df->...cf", q, s1)
    y_hat += (alpha**4) * s0
    denom, nom = y_hat[..., :1], y_hat[..., 1:]
    y = nom / denom
    if output_scale:
        y = y * jnp.sqrt(_nb(n, y.ndim) / d)
    return y.astype(v.dtype), TaylorState(s2=s2, s1=s1, s0=s0, n=n)


def taylor_encode_state(
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    normalize_inputs: bool = True,
) -> TaylorState:
    """Summarize a key/value set into a TaylorState without attending.

    Used for cross-attention serving (whisper): the encoder's K/V are
    folded into a constant-size state once; every decode step is then a
    :func:`taylor_readout`. k, v: (..., M, d).
    """
    d = k.shape[-1]
    alpha = d ** 0.25
    if normalize_inputs:
        k = l2_normalize(k)
    k = (k * alpha).astype(jnp.float32)
    ones = jnp.ones((*v.shape[:-1], 1), jnp.float32)
    vh = jnp.concatenate([ones, v.astype(jnp.float32)], axis=-1)
    return TaylorState(
        s2=jnp.einsum("...me,...mf->...ef", boxtimes(k, k), vh),
        s1=jnp.einsum("...md,...mf->...df", k, vh),
        s0=jnp.sum(vh, axis=-2, keepdims=True),
        n=jnp.asarray(k.shape[-2], jnp.int32),
    )


def taylor_readout(
    state: TaylorState,
    q: jnp.ndarray,
    *,
    tau: jnp.ndarray | float = 1.0,
    normalize_inputs: bool = True,
    output_scale: bool = True,
) -> jnp.ndarray:
    """Attend with q over a frozen TaylorState (no update). q: (..., T, d)."""
    d = q.shape[-1]
    alpha = d ** 0.25
    if normalize_inputs:
        q = l2_normalize(q) * tau
    q = (q * alpha).astype(jnp.float32)
    y_hat = 0.5 * jnp.einsum("...te,...ef->...tf", boxtimes(q, q), state.s2)
    y_hat += (alpha**2) * jnp.einsum("...td,...df->...tf", q, state.s1)
    y_hat += (alpha**4) * state.s0
    denom, nom = y_hat[..., :1], y_hat[..., 1:]
    y = nom / denom
    if output_scale:
        y = y * jnp.sqrt(_nb(state.n, y.ndim) / d)
    return y


# ---------------------------------------------------------------------------
# Causal direct (oracle for the causal variants) and auto dispatch
# ---------------------------------------------------------------------------

def causal_direct_taylorshift(q, k, v, *, tau=1.0, normalize_inputs=True,
                              output_scale=True):
    """O(N²d) masked direct form — oracle for causal_taylorshift.

    Output scaling uses per-row context counts sqrt((i+1)/d), matching
    both the chunked and the recurrent decode conventions exactly.
    """
    return direct_taylorshift(q, k, v, tau=tau, causal=True,
                              normalize_inputs=normalize_inputs,
                              output_scale=output_scale)


def taylorshift_attention(q, k, v, *, tau=1.0, causal=False, mode="auto",
                          chunk=128, normalize_inputs=True, output_scale=True):
    """Front door: dispatches on mode ∈ {auto, direct, efficient}."""
    N, d = q.shape[-2], q.shape[-1]
    if mode == "auto":
        mode = pick_mode(N, d)
    if mode == "direct":
        return direct_taylorshift(q, k, v, tau=tau, causal=causal,
                                  normalize_inputs=normalize_inputs,
                                  output_scale=output_scale)
    if causal:
        c = min(chunk, N)
        while N % c:
            c //= 2
        return causal_taylorshift(q, k, v, tau=tau, chunk=max(c, 1),
                                  normalize_inputs=normalize_inputs,
                                  output_scale=output_scale)
    return efficient_taylorshift(q, k, v, tau=tau,
                                 normalize_inputs=normalize_inputs,
                                 output_scale=output_scale)
