"""CLI: calibrate, validate, and smoke-check tuning tables.

  # measure crossovers + kernel blocks, persist the table
  PYTHONPATH=src python -m repro.tune --calibrate --out tuning.json

  # tiny CI sweep (coarse grids, 1 rep)
  PYTHONPATH=src python -m repro.tune --calibrate --quick --out t.json

  # validate schema + assert select_backend honors the table
  PYTHONPATH=src python -m repro.tune --check t.json

``--decision-log PATH`` seeds ``--calibrate`` with the head dims whose
recorded choices diverged from the analytic N0 (PR 6 obs machinery as
ground truth).
"""

from __future__ import annotations

import argparse
import json
import sys


def _check(path: str) -> None:
    """Schema-validate, install, and assert select_backend consults it."""
    from repro.configs import get_config
    from repro.models import backend as B
    from repro.tune import table as TT

    with open(path) as f:
        doc = json.load(f)
    problems = TT.validate_table(doc)
    if problems:
        raise SystemExit(f"{path}: invalid table:\n  "
                         + "\n  ".join(problems))
    table = TT.TuningTable.from_doc(doc)
    print(f"{path}: schema OK ({len(table.entries)} entries, "
          f"backend={table.backend})")
    if not table.entries:
        print("table is empty — nothing to assert against select_backend")
        return
    TT.install(table, strict=False)
    try:
        e = table.entries[0]
        cfg = get_config("stablelm-1.6b").reduced()
        cfg = cfg.with_(head_dim=e.d)
        n = int(e.n0) if e.n0 else 64
        s = B.select_backend(cfg, N=n, d=e.d, site="full")
        if s.provenance != "calibrated":
            raise SystemExit(
                f"select_backend ignored the installed table at d={e.d} "
                f"(provenance={s.provenance!r})")
        want_n0 = e.n0 if e.n0 is not None else s.n0
        if e.n0 is not None and abs(s.n0 - e.n0) > 0.5:
            raise SystemExit(f"selection n0={s.n0} != table n0={want_n0}")
        print(f"select_backend honors the table: d={e.d} -> "
              f"provenance=calibrated, n0={s.n0:.0f}, n1={s.n1:.0f}")
    finally:
        TT.uninstall()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the measurement sweep")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the calibrated table here (JSON)")
    ap.add_argument("--d", type=int, nargs="*", default=[16, 32],
                    help="head dims to sweep")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="coarse grids, 1 rep — the CI smoke mode")
    ap.add_argument("--no-blocks", action="store_true",
                    help="skip the Pallas block-shape sweep")
    ap.add_argument("--decision-log", default=None, metavar="PATH",
                    help="seed the sweep with dims whose recorded "
                         "decisions diverged from the analytic N0")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="validate a table and assert select_backend "
                         "honors it")
    args = ap.parse_args(argv)

    if args.check:
        _check(args.check)
        return
    if not args.calibrate:
        ap.error("pass --calibrate (with --out) or --check PATH")

    from repro.tune.calibrate import calibrate, divergent_dims

    ds = list(args.d)
    if args.decision_log:
        from repro.obs.decisions import read_jsonl
        seeds = divergent_dims(read_jsonl(args.decision_log))
        if seeds:
            print(f"decision log flags divergent head dims: {sorted(seeds)}")
            ds = sorted(set(ds) | seeds)
    reps = 1 if args.quick else args.reps
    table = calibrate(ds, reps=reps, quick=args.quick,
                      blocks=not args.no_blocks, verbose=True)
    doc = table.to_doc()
    if args.out:
        table.save(args.out)
        print(f"wrote {args.out} ({len(table.entries)} entries)")
    else:
        json.dump(doc, sys.stdout, indent=2, sort_keys=True)
        print()


if __name__ == "__main__":
    main()
