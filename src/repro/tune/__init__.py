"""Empirical autotuning for backend selection (docs/benchmarks.md).

``repro.tune`` closes the loop between the paper's *analytic* crossover
points N0/N1 (core/taylor.py Eq. 7/9) and what the target backend
actually measures: ``calibrate`` runs crossover.py-style timing sweeps
plus a Pallas block-shape sweep and persists per-(backend, d, H, site)
overrides to a JSON :class:`TuningTable`; ``install`` makes
``models.backend.select_backend`` consult the table before falling back
to the algebra, with the provenance ("analytic" vs "calibrated")
recorded in every Selection and obs decision-log record.

CLI::

    PYTHONPATH=src python -m repro.tune --calibrate --out tuning.json
    PYTHONPATH=src python -m repro.tune --check tuning.json
"""

from repro.tune.table import (SCHEMA, TuneEntry, TuningTable, active,
                              install, kernel_blocks, uninstall,
                              validate_table)
from repro.tune.calibrate import calibrate

__all__ = ["SCHEMA", "TuneEntry", "TuningTable", "active", "install",
           "uninstall", "kernel_blocks", "validate_table", "calibrate"]
