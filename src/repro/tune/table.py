"""The persisted measured-override table ``select_backend`` consults.

A :class:`TuningTable` is a list of :class:`TuneEntry` rows keyed on
``(backend_platform, d, H, site)`` with two wildcard axes: ``H=None``
matches any head count and ``site="*"`` matches any attention site.
``lookup`` resolves most-specific-first, so a site-specific measurement
beats a whole-model one and both beat the analytic fallback (which is
simply "no entry found").

Entries carry the *measured* crossovers ``n0``/``n1`` (either may be
None — a timing sweep that never saw a sign change leaves the analytic
value in charge) plus optional Pallas block shapes ``block_q``/
``block_k`` for the fused kernels. Installation is process-global and
two-pronged:

* ``models.backend.select_backend`` asks the active table per site and
  stamps ``Selection.provenance = "calibrated"`` when an override
  applied (visible in the obs decision log);
* ``core.taylor.set_crossover_hook`` is pointed at the table's
  wildcard rows, so *every* ``pick_mode`` caller — including
  ``select_serve_plan``'s cache_kind="auto" memory resolution and the
  attention layers' trace-time re-derivations — sees the same measured
  thresholds. One global, or routing decisions would split.

The JSON schema (``validate_table`` is the CI gate)::

    {"schema": "repro.tune/v1",
     "backend": "cpu",                  # jax.default_backend() at calibration
     "meta": {...},                     # free-form provenance
     "entries": [{"d": 16, "H": null, "site": "*",
                  "n0": 1234.0, "n1": 301.0,
                  "block_q": 128, "block_k": 128,
                  "source": "measured"}, ...]}
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

from repro.core import taylor as T

SCHEMA = "repro.tune/v1"
SITES = ("full", "prefill", "decode", "verify", "*")


@dataclass(frozen=True)
class TuneEntry:
    """One measured override row. ``H=None`` / ``site="*"`` wildcard."""
    d: int
    H: int | None = None
    site: str = "*"
    n0: float | None = None       # measured speed crossover (None = analytic)
    n1: float | None = None       # measured memory crossover
    block_q: int | None = None    # Pallas kernel block shapes (None = default)
    block_k: int | None = None
    source: str = "measured"


@dataclass
class TuningTable:
    backend: str                  # jax platform the sweeps ran on
    entries: list[TuneEntry] = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    def lookup(self, *, d: int, H: int | None = None,
               site: str = "*") -> TuneEntry | None:
        """Most-specific entry for (d, H, site), wildcards last.

        Precedence: exact (d, H, site) > (d, H, "*") > (d, None, site)
        > (d, None, "*"). A stored ``H=None`` row matches any requested
        H; a stored concrete H only matches itself.
        """
        best, best_rank = None, -1
        for e in self.entries:
            if e.d != d:
                continue
            if e.H is not None and e.H != H:
                continue
            if e.site != "*" and e.site != site:
                continue
            rank = (2 if e.H is not None else 0) + (1 if e.site != "*" else 0)
            if rank > best_rank:
                best, best_rank = e, rank
        return best

    # -- persistence --------------------------------------------------------

    def to_doc(self) -> dict:
        return {"schema": SCHEMA, "backend": self.backend,
                "meta": dict(self.meta),
                "entries": [asdict(e) for e in self.entries]}

    @classmethod
    def from_doc(cls, doc: dict) -> "TuningTable":
        problems = validate_table(doc)
        if problems:
            raise ValueError("invalid tuning table:\n  "
                             + "\n  ".join(problems))
        return cls(backend=doc["backend"],
                   entries=[TuneEntry(**e) for e in doc["entries"]],
                   meta=dict(doc.get("meta", {})))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_doc(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "TuningTable":
        with open(path) as f:
            return cls.from_doc(json.load(f))


_ENTRY_FIELDS = {"d", "H", "site", "n0", "n1", "block_q", "block_k",
                 "source"}


def validate_table(doc) -> list[str]:
    """Schema check; returns problem strings (empty = valid)."""
    problems: list[str] = []
    if not isinstance(doc, dict):
        return ["table document is not an object"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema {doc.get('schema')!r} != {SCHEMA!r}")
    if not isinstance(doc.get("backend"), str) or not doc.get("backend"):
        problems.append("backend missing or not a string")
    entries = doc.get("entries")
    if not isinstance(entries, list):
        return problems + ["entries missing or not a list"]
    for i, e in enumerate(entries):
        if not isinstance(e, dict):
            problems.append(f"entry {i}: not an object")
            continue
        extra = set(e) - _ENTRY_FIELDS
        if extra:
            problems.append(f"entry {i}: unknown fields {sorted(extra)}")
        if not isinstance(e.get("d"), int) or e.get("d", 0) < 1:
            problems.append(f"entry {i}: d must be a positive int")
        if e.get("H") is not None and (not isinstance(e["H"], int)
                                       or e["H"] < 1):
            problems.append(f"entry {i}: H must be null or a positive int")
        if e.get("site", "*") not in SITES:
            problems.append(f"entry {i}: site {e.get('site')!r} not in "
                            f"{SITES}")
        for k in ("n0", "n1"):
            v = e.get(k)
            if v is not None and (not isinstance(v, (int, float))
                                  or v <= 0):
                problems.append(f"entry {i}: {k} must be null or > 0")
        for k in ("block_q", "block_k"):
            v = e.get(k)
            if v is not None and (not isinstance(v, int) or v < 1
                                  or v & (v - 1)):
                problems.append(f"entry {i}: {k} must be null or a "
                                "positive power of two")
        if e.get("n0") is None and e.get("n1") is None \
                and e.get("block_q") is None and e.get("block_k") is None:
            problems.append(f"entry {i}: overrides nothing")
    return problems


# ---------------------------------------------------------------------------
# Process-global installation
# ---------------------------------------------------------------------------

_ACTIVE: TuningTable | None = None


def active() -> TuningTable | None:
    return _ACTIVE


def _hook(d: int, kind: str):
    """core.taylor crossover hook over the active table's wildcard rows
    (no site/H context exists at a bare ``pick_mode`` call)."""
    if _ACTIVE is None:
        return None
    e = _ACTIVE.lookup(d=d)
    if e is None:
        return None
    return e.n0 if kind == "n0" else e.n1


def install(table: TuningTable, *, strict: bool = True) -> None:
    """Make ``table`` the process-global measured-override source.

    ``strict`` refuses a table calibrated on a different jax platform —
    a cpu-measured crossover says nothing about a TPU. Install before
    the first traced dispatch: jitted callers resolve overrides at
    trace time and will not retrace on a later install."""
    import jax
    platform = jax.default_backend()
    if strict and table.backend != platform:
        raise ValueError(
            f"tuning table was calibrated on {table.backend!r} but this "
            f"process runs {platform!r}; pass strict=False to force")
    global _ACTIVE
    _ACTIVE = table
    T.set_crossover_hook(_hook)


def uninstall() -> None:
    """Clear the active table; everything falls back to Eq. (7)/(9)."""
    global _ACTIVE
    _ACTIVE = None
    T.set_crossover_hook(None)


def kernel_blocks(d: int, *, default: int = 128) -> tuple[int, int]:
    """(block_q, block_k) for the fused Pallas kernels at head dim d —
    the calibrated sweep's pick when a table is installed, ``default``
    otherwise. Kernel entry points call this when the caller left the
    block shape unspecified."""
    if _ACTIVE is not None:
        e = _ACTIVE.lookup(d=d)
        if e is not None:
            return (e.block_q or default, e.block_k or default)
    return (default, default)
