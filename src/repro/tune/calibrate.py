"""Measure the real crossovers and kernel block shapes on this backend.

The analytic N0/N1 (core/taylor.py Eq. 7/9) count FLOPs and tensor
entries; a real backend adds constants the algebra cannot see — fusion
quality, cache hierarchy, dispatch overhead. ``calibrate`` runs the
``benchmarks/crossover.py``-style sweep directly against the reference
implementations and writes what it *measured*:

* **N0 (speed)**: ``direct_taylorshift`` vs ``efficient_taylorshift``
  timed (best-of-``reps``, blocked until ready) over a geometric N grid
  bracketing the analytic value; the empirical crossover is the
  geometric midpoint of the last direct-faster and first
  efficient-faster grid points. No sign change inside the grid leaves
  ``n0=None`` — the analytic value stays in charge for that d.
* **N1 (memory)**: compiled-executable temp-byte accounting
  (``.memory_analysis()``) where the backend reports it, bisected the
  same way; backends that report nothing fall back to the Eq. (8)
  entries model evaluated at real dtype widths (``source`` records
  which).
* **block shapes**: the fused Pallas kernels timed over a candidate
  ``(block_q, block_k)`` grid (interpret mode off-TPU), best wall time
  wins.

A recorded decision log (PR 6 ``--decision-log`` JSONL) seeds the sweep:
``divergent_dims`` extracts the (d, site) cells where the recorded
choice sat on the wrong side of the analytic N0 — exactly the cells
worth measuring first.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import taylor as T
from repro.tune.table import TuneEntry, TuningTable


def _time_best(fn, *args, reps: int = 3) -> float:
    """Best-of-``reps`` wall seconds; compiles on the warmup call."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _n_grid(d: int, *, quick: bool) -> list[int]:
    """Geometric N grid bracketing the analytic N0 (multiples of 8)."""
    n0 = T.crossover_n0(d)
    factors = (0.5, 1.0, 2.0) if quick else (0.25, 0.5, 0.71, 1.0,
                                             1.41, 2.0, 4.0)
    return sorted({max(8, int(round(n0 * f / 8)) * 8) for f in factors})


def _cross_from_sweep(ns: list[int], direct_wins: list[bool]
                      ) -> float | None:
    """Geometric midpoint of the last direct-win / first efficient-win
    pair; None when the grid never sees a sign change."""
    for i in range(len(ns) - 1):
        if direct_wins[i] and not direct_wins[i + 1]:
            return float((ns[i] * ns[i + 1]) ** 0.5)
    return None


def measure_n0(d: int, *, reps: int = 3, quick: bool = False,
               batch: int = 1) -> tuple[float | None, dict]:
    """Empirical speed crossover for head dim d (None = no crossing)."""
    key = jax.random.PRNGKey(0)
    direct = jax.jit(lambda q, k, v: T.direct_taylorshift(q, k, v))
    efficient = jax.jit(lambda q, k, v: T.efficient_taylorshift(q, k, v))
    ns, wins, cells = _n_grid(d, quick=quick), [], {}
    for n in ns:
        q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                     (batch, n, d), jnp.float32)
                   for i in range(3))
        td = _time_best(direct, q, k, v, reps=reps)
        te = _time_best(efficient, q, k, v, reps=reps)
        wins.append(td <= te)
        cells[n] = {"direct_s": td, "efficient_s": te}
    return _cross_from_sweep(ns, wins), cells


def _temp_bytes(fn, *args) -> int | None:
    """Compiled temp allocation in bytes, when the backend reports it."""
    try:
        mem = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(mem.temp_size_in_bytes) if mem is not None else None
    except Exception:
        return None


def measure_n1(d: int, *, quick: bool = False,
               batch: int = 1) -> tuple[float | None, str]:
    """Empirical memory crossover; falls back to the entries model
    (Eq. 8 at fp32 widths) when the backend reports no temp bytes."""
    key = jax.random.PRNGKey(1)
    n0 = T.crossover_n1(d)
    factors = (0.5, 1.0, 2.0) if quick else (0.25, 0.5, 1.0, 2.0, 4.0)
    ns = sorted({max(8, int(round(n0 * f / 8)) * 8) for f in factors})
    wins, measured = [], True
    for n in ns:
        q, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                     (batch, n, d), jnp.float32)
                   for i in range(3))
        bd = _temp_bytes(lambda q, k, v: T.direct_taylorshift(q, k, v),
                         q, k, v)
        be = _temp_bytes(lambda q, k, v: T.efficient_taylorshift(q, k, v),
                         q, k, v)
        if bd is None or be is None or not (bd and be):
            measured = False
            break
        wins.append(bd <= be)
    if measured:
        cross = _cross_from_sweep(ns, wins)
        if cross is not None:
            return cross, "measured"
    # entries model at real widths — same crossover as Eq. (9), recorded
    # as modeled so the table is honest about its provenance
    wins = [T.entries_direct(n, d) <= T.entries_efficient(n, d) for n in ns]
    return _cross_from_sweep(ns, wins), "modeled"


BLOCK_CANDIDATES = ((64, 64), (128, 128), (64, 128), (128, 64))


def sweep_kernel_blocks(d: int, *, n: int = 256, reps: int = 3,
                        candidates=BLOCK_CANDIDATES,
                        quick: bool = False) -> tuple[int, int]:
    """Best (block_q, block_k) for the fused Pallas kernels at this d.

    Times ``taylor_direct_attention`` + ``taylor_efficient_attention``
    per candidate (interpret mode on non-TPU hosts, where the sweep
    still orders candidates by the work the grid shape implies)."""
    from repro.kernels.taylor_direct import taylor_direct_attention
    from repro.kernels.taylor_efficient import taylor_efficient_attention

    interpret = jax.default_backend() not in ("tpu",)
    if quick:
        candidates = candidates[:2]
        n, reps = min(n, 128), 1
    key = jax.random.PRNGKey(2)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (2, n, d),
                                 jnp.float32) for i in range(3))
    best, best_t = candidates[0], float("inf")
    for bq, bk in candidates:
        if n % min(bq, n) or n % min(bk, n):
            continue
        t = _time_best(
            lambda q, k, v: taylor_direct_attention(
                q, k, v, block_q=bq, block_k=bk, interpret=interpret),
            q, k, v, reps=reps)
        t += _time_best(
            lambda q, k, v: taylor_efficient_attention(
                q, k, v, block_q=bq, block_k=bk, interpret=interpret),
            q, k, v, reps=reps)
        if t < best_t:
            best, best_t = (bq, bk), t
    return best


def divergent_dims(records: list[dict]) -> set[int]:
    """Head dims whose recorded direct/efficient choice sat on the wrong
    side of the analytic N0 — the decision-log seed for calibration."""
    out = set()
    for r in records:
        if r.get("mode") in ("direct", "efficient") \
                and r.get("cache_kind") != "kv":
            predicted = ("direct" if r["N"] <= T.crossover_n0(r["d"])
                         else "efficient")
            if r["mode"] != predicted:
                out.add(int(r["d"]))
    return out


def calibrate(ds=(16, 32), *, reps: int = 3, quick: bool = False,
              blocks: bool = True, verbose: bool = False) -> TuningTable:
    """Run the full sweep and return the persisted-form table."""
    entries, meta_cells = [], {}
    for d in ds:
        n0, cells = measure_n0(d, reps=reps, quick=quick)
        n1, n1_source = measure_n1(d, quick=quick)
        bq = bk = None
        if blocks:
            bq, bk = sweep_kernel_blocks(d, reps=reps, quick=quick)
        source = "measured" if n1_source == "measured" else \
            "measured-n0-modeled-n1"
        if n0 is None and n1 is None and bq is None:
            continue          # nothing measured — leave analytic in charge
        entries.append(TuneEntry(d=d, n0=n0, n1=n1, block_q=bq,
                                 block_k=bk, source=source))
        meta_cells[str(d)] = cells
        if verbose:
            print(f"d={d}: measured N0={n0 and round(n0)} "
                  f"(analytic {T.crossover_n0(d):.0f}), "
                  f"N1={n1 and round(n1)} [{n1_source}] "
                  f"(analytic {T.crossover_n1(d):.0f}), "
                  f"blocks=({bq},{bk})")
    return TuningTable(backend=jax.default_backend(), entries=entries,
                       meta={"reps": reps, "quick": quick,
                             "cells": meta_cells})
