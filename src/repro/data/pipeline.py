"""Data pipeline: deterministic sharded token streams with prefetch.

Production shape: each host materializes only its shard of the global
batch (``host_slice``), a background thread keeps ``prefetch`` batches
ready, and every batch is addressable by step index so a restart resumes
*exactly* where the failed run stopped (no data replay / skip drift).

Generators are pure functions of (seed, step) — the same property real
deterministic loaders (grain, SSTable readers) provide.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    kind: str = "lm_synthetic"   # lm_synthetic | listops | bytes
    n_hosts: int = 1
    host_id: int = 0


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def host_slice(cfg: DataConfig) -> tuple[int, int]:
    per = cfg.global_batch // cfg.n_hosts
    return cfg.host_id * per, per


# ---------------------------------------------------------------------------
# Generators (pure in (seed, step))
# ---------------------------------------------------------------------------

def lm_synthetic(cfg: DataConfig, step: int) -> dict:
    """Markov-ish token stream with learnable local structure: the model
    can reduce loss by learning short-range bigram rules."""
    rng = _rng_for(cfg, step)
    _, per = host_slice(cfg)
    base = rng.integers(0, cfg.vocab, size=(per, cfg.seq_len), dtype=np.int32)
    # inject copy structure: token[t] = token[t-1] + 1 (mod V) with p=0.5
    copy_mask = rng.random((per, cfg.seq_len)) < 0.5
    shifted = np.roll(base, 1, axis=1) + 1
    tokens = np.where(copy_mask, shifted % cfg.vocab, base).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return {"tokens": tokens, "labels": labels}


def listops_like(cfg: DataConfig, step: int) -> dict:
    """ListOps-style classification sequences (paper §5.3): nested
    MIN/MAX/MED/SUM-mod-10 over digits, encoded at character level.
    Label = value of the expression. Vocab: 0-9 digits, 10-13 ops,
    14 '(' 15 ')'."""
    rng = _rng_for(cfg, step)
    _, per = host_slice(cfg)
    N = cfg.seq_len
    toks = np.zeros((per, N), dtype=np.int32)
    labels = np.zeros((per,), dtype=np.int32)
    for i in range(per):
        toks[i], labels[i] = _gen_listops(rng, N)
    return {"tokens": toks, "label": labels}


_OPS = [("MIN", min), ("MAX", max),
        ("MED", lambda xs: sorted(xs)[len(xs) // 2]),
        ("SUM", lambda xs: sum(xs) % 10)]


def _gen_listops(rng, n, depth=2):
    seq: list[int] = []

    def emit(d):
        if d == 0 or rng.random() < 0.3 or len(seq) > n - 8:
            v = int(rng.integers(0, 10))
            seq.append(v)
            return v
        op = int(rng.integers(0, 4))
        seq.append(14)          # '('
        seq.append(10 + op)
        vals = [emit(d - 1) for _ in range(int(rng.integers(2, 5)))
                if len(seq) < n - 4]
        seq.append(15)          # ')'
        return _OPS[op][1](vals) if vals else 0

    label = emit(depth)
    seq = seq[:n]
    out = np.zeros(n, dtype=np.int32)
    out[:len(seq)] = seq
    return out, int(label)


_GENERATORS: dict[str, Callable] = {
    "lm_synthetic": lm_synthetic,
    "listops": listops_like,
}


def device_put_batch(batch: dict, mesh=None) -> dict:
    """Place a host batch on devices in the layout the train steps
    consume: batch dim over the data axes, token dim over ``seq`` when
    the mesh carries one (so composed-mesh steps read their
    ``P("data", "seq")`` shards without an all-to-all). ``mesh=None``
    falls back to a plain ``device_put``. jax and the sharding rules
    import lazily — this module stays numpy-only for host-side tests."""
    import jax

    if mesh is None:
        return jax.device_put(batch)
    from repro.distributed.sharding import batch_shardings

    return jax.device_put(batch, batch_shardings(batch, mesh))


# ---------------------------------------------------------------------------
# Prefetching loader
# ---------------------------------------------------------------------------

class DataLoader:
    """Step-addressable loader with background prefetch."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.gen = _GENERATORS[cfg.kind]
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.gen(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def batch_at(self, step: int) -> dict:
        """Random access (used by tests and restart validation)."""
        return self.gen(self.cfg, step)

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
