"""Sharding rules: param-tree paths → PartitionSpec (MaxText-style).

Parameters get semantic rules (contraction-aware TP/EP placement);
caches/optimizer extras use a greedy divisibility-based sharder (any
placement is *correct* under GSPMD — the rules only control memory and
collective traffic).

ZeRO-1: optimizer moments/master get the param's spec plus the ``data``
axis on the first still-unsharded, divisible dimension.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes


# (regex over 'a/b/c' tree path) -> spec for the *trailing* dims;
# stacked leading layer dims are padded with None automatically.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/emb$",                    ("model", None)),
    (r"(wq|wk|wv)/w$",                 (None, "model")),
    (r"wo/w$",                         ("model", None)),
    (r"(up|gate)/w$",                  (None, "model")),
    (r"down/w$",                       ("model", None)),
    (r"unembed/w$",                    (None, "model")),
    (r"router/w$",                     (None, None)),
    (r"w_(up|gate)$",                  ("model", None, "data")),   # MoE EP
    (r"w_down$",                       ("model", "data", None)),
    (r"shared/(up|gate)/w$",           (None, "model")),
    (r"shared/down/w$",                ("model", None)),
    (r"in_proj/w$",                    (None, "model")),
    (r"out_proj/w$",                   ("model", None)),
    (r"conv_w$",                       (None, "model")),
    (r"(A_log|D|dt_bias)$",            ("model",)),
    (r"(up_proj|w_gates|r_gates)/w$",  (None, "model")),
    (r"down_proj/w$",                  ("model", None)),
    (r"w_if/w$",                       (None, None)),
    (r"pos/pos$",                      (None, None)),
    (r"tau$",                          (None,)),
    (r"(scale|bias)$",                 (None,)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return dim % size == 0


def _spec_for_param(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    # --- MoE expert weights: EP when E divides 'model', else FSDP-style
    # 2D weight sharding with just-in-time all-gather over 'data'
    # (docs/design.md §5; grok-1 has 8 experts on 16-way model axes). ---
    m = re.search(r"w_(up|gate|down)$", path)
    if m and len(shape) >= 3:
        E = shape[-3]
        if E % mesh.shape["model"] == 0:
            trailing = (("model", None, "data") if m.group(1) in ("up", "gate")
                        else ("model", "data", None))
        else:
            trailing = ((None, "data", "model") if m.group(1) in ("up", "gate")
                        else (None, "model", "data"))
        spec = [None] * (len(shape) - 3) + list(trailing)
        spec = [s if (s is None or _fits(shape[i], mesh, s)) else None
                for i, s in enumerate(spec)]
        return P(*spec)

    for pat, trailing in _PARAM_RULES:
        if re.search(pat, path):
            spec = list(trailing)
            # pad for stacked layer dims
            while len(spec) < len(shape):
                spec.insert(0, None)
            spec = spec[-len(shape):] if len(spec) > len(shape) else spec
            # drop axes that don't divide (grok's 8 experts on 16 devices
            # would pad 2x — prefer dropping to silent padding for params)
            spec = [s if (s is None or _fits(shape[i], mesh, s)) else None
                    for i, s in enumerate(spec)]
            return P(*spec)
    return P()  # replicate


def param_shardings(shapes_tree, mesh: Mesh):
    """Tree of NamedShardings matching an eval_shape'd param tree."""
    def one(path, leaf):
        return NamedSharding(mesh, _spec_for_param(_path_str(path),
                                                   leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, shapes_tree)


# ---------------------------------------------------------------------------
# Greedy sharder for caches / activations-like trees
# ---------------------------------------------------------------------------

def greedy_spec(shape: tuple[int, ...], mesh: Mesh, *,
                batch_dim: int | None = None, skip_dims: tuple = ()) -> P:
    """Shard batch_dim over dp axes if divisible, then the largest
    remaining dim over 'model'."""
    spec: list = [None] * len(shape)
    dp = dp_axes(mesh)
    used_model = False
    if batch_dim is not None and len(shape) > batch_dim:
        if _fits(shape[batch_dim], mesh, tuple(dp)) and shape[batch_dim] > 1:
            spec[batch_dim] = tuple(dp) if len(dp) > 1 else dp[0]
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is not None or i == batch_dim or i in skip_dims:
            continue
        if not used_model and shape[i] >= mesh.shape["model"] \
                and shape[i] % mesh.shape["model"] == 0:
            spec[i] = "model"
            used_model = True
    return P(*spec)


def cache_shardings(cache_tree, mesh: Mesh, *, stacked: bool = True):
    """Decode-cache tree: leading layer-stack dim (if any) replicated,
    batch dim sharded over dp, biggest dim over model.

    Leaf name heuristics:
      TaylorState.s2 (…, d², d+1): shard d² over model — universal since
      d ≡ 0 (mod 4) ⇒ d² ≡ 0 (mod 16); this is also what makes batch=1
      long_500k shardable at all.
    """
    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        base = 1 if stacked and nd > 1 else 0   # skip layer-stack dim
        if re.search(r"s2$", ps) and nd >= 2:
            spec = [None] * nd
            if shape[base] > 1:
                dp = dp_axes(mesh)
                if _fits(shape[base], mesh, tuple(dp)):
                    spec[base] = tuple(dp) if len(dp) > 1 else dp[0]
            if shape[-2] % mesh.shape["model"] == 0:
                spec[-2] = "model"
            return NamedSharding(mesh, P(*spec))
        spec = greedy_spec(shape[base:], mesh, batch_dim=0)
        full = [None] * base + list(spec)
        return NamedSharding(mesh, P(*full))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


def batch_shardings(batch_tree, mesh: Mesh):
    """Input batches: dim 0 over dp axes, token dim over `seq` (when the
    mesh carries a sequence-parallel axis and the length divides), rest
    replicated."""
    dp = dp_axes(mesh)
    dpspec = tuple(dp) if len(dp) > 1 else dp[0]
    seq = ("seq" if "seq" in mesh.axis_names and mesh.shape["seq"] > 1
           else None)

    def one(leaf):
        if leaf.shape and leaf.shape[0] > 1 and _fits(leaf.shape[0], mesh,
                                                      tuple(dp)):
            spec = [dpspec] + [None] * (len(leaf.shape) - 1)
            if (seq and len(leaf.shape) > 1
                    and _fits(leaf.shape[1], mesh, seq)):
                spec[1] = seq
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(one, batch_tree)


def zero1_shardings(param_shardings_tree, shapes_tree, mesh: Mesh):
    """Optimizer-state sharding: param spec + 'data' on the first
    unsharded divisible dim (ZeRO-1)."""
    def one(sh, leaf):
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        used = set()
        for s in spec:
            for a in (s if isinstance(s, tuple) else (s,)):
                if a:
                    used.add(a)
        if "data" not in used:
            for i, s in enumerate(spec):
                if s is None and leaf.shape[i] % mesh.shape["data"] == 0 \
                        and leaf.shape[i] >= mesh.shape["data"]:
                    spec[i] = "data"
                    break
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(one, param_shardings_tree, shapes_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Composed (data, pipe, seq) mesh — distributed/composed.py
# ---------------------------------------------------------------------------

def composed_fsdp_dim(shape: tuple[int, ...], data: int) -> int:
    """FSDP shard dim for a stage-stacked leaf ``(S, L, ...)`` on the
    composed mesh, or -1 for replicated-over-data.

    Only weight matrices (ndim ≥ 4 after stage/layer stacking) shard:
    norm scales and tau vectors are a rounding error of the footprint
    and all-gathering them per tick costs more latency than the bytes
    save. First trailing dim divisible by the data-axis size wins.
    """
    if len(shape) < 4:
        return -1
    for dim in range(2, len(shape)):
        if shape[dim] % data == 0 and shape[dim] >= data:
            return dim
    return -1


def composed_param_specs(split_tree, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpecs for the composed ``{"outer", "stages"}`` tree.

    outer (embed/pos/final_norm/unembed) is replicated — it is touched
    once per step, not once per layer, so FSDP buys little there.
    stages leaves ``(S, L, ...)`` shard dim 0 over ``pipe``; with
    ``fsdp`` the :func:`composed_fsdp_dim` dim additionally shards over
    ``data``, to be all-gathered just-in-time inside the composed step
    (the gather's transpose is the gradient reduce-scatter — ZeRO-3).
    """
    data = mesh.shape["data"]

    def stage_spec(leaf):
        shape = tuple(leaf.shape)
        spec: list[Any] = ["pipe"] + [None] * (len(shape) - 1)
        if fsdp:
            dim = composed_fsdp_dim(shape, data)
            if dim >= 0:
                spec[dim] = "data"
        return P(*spec)

    return {
        "outer": jax.tree.map(lambda _: P(), split_tree["outer"]),
        "stages": jax.tree.map(stage_spec, split_tree["stages"]),
    }
