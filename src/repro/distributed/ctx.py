"""Ambient sharding context for activation constraints inside model code.

Model code calls ``constrain(x, spec_fn)`` at strategic points; with no
mesh configured these are no-ops, so tests/benches on a single device are
unaffected. The launch layer activates the context for dryrun/train/serve.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class ShardCtx:
    enabled: bool = False
    dp: tuple[str, ...] = ("data",)
    model_axis: str = "model"
    seq_axis: str = "seq"     # sequence-parallel (context) axis, if meshed
    mesh: object | None = None
    sp_carry: bool = True     # Megatron-SP carry sharding (d_model@model)

    @property
    def dp_spec(self):
        return tuple(self.dp) if len(self.dp) > 1 else self.dp[0]

    @property
    def seq_size(self) -> int:
        """Size of the `seq` mesh axis (1 = no sequence parallelism)."""
        if self.mesh is None or self.seq_axis not in getattr(
                self.mesh, "axis_names", ()):
            return 1
        return self.mesh.shape[self.seq_axis]

    @property
    def seq_spec(self):
        """Token-axis spec: 'seq' when the mesh carries the axis."""
        return self.seq_axis if self.seq_size > 1 else None

    @property
    def multi_device(self) -> bool:
        """True when constraints are active on a >1-device mesh — the
        regime where un-partitionable paths (pallas_call) must not be
        selected (models/backend.py)."""
        return self.enabled and (self.mesh is None
                                 or self.mesh.devices.size > 1)


_CTX = ShardCtx()


def get() -> ShardCtx:
    return _CTX


@contextlib.contextmanager
def use(mesh, *, sp_carry: bool = True):
    """Activate activation-sharding constraints for this mesh."""
    global _CTX
    prev = _CTX
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    _CTX = ShardCtx(enabled=True, dp=dp, mesh=mesh, sp_carry=sp_carry)
    try:
        yield _CTX
    finally:
        _CTX = prev


def _divisible(dim: int, *axes) -> bool:
    if _CTX.mesh is None:
        return False
    size = 1
    for a in axes:
        for name in (a if isinstance(a, tuple) else (a,)):
            size *= _CTX.mesh.shape[name]
    return dim % size == 0 and dim >= size


def constrain(x, *spec):
    """with_sharding_constraint if the context is active and divisible."""
    if not _CTX.enabled:
        return x
    clean = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            clean.append(None)
        elif _divisible(dim, s):
            clean.append(s)
        else:
            clean.append(None)
    clean += [None] * (len(x.shape) - len(clean))
    return jax.lax.with_sharding_constraint(x, P(*clean))


def activations(x):
    """(B, N, d) activation sharding: batch over dp, d_model over 'model'.

    Sharding the layer-scan carry over 'model' (Megatron-SP style) is what
    keeps the remat-saved residual stream at n_layers·B·N·d/(dp·tp) per
    device instead of n_layers·B·N·d/dp — the dominant training buffer.
    Forward: reduce-scatter onto d; backward: pinned bf16 all-gather.
    """
    if not _CTX.enabled:
        return x
    carry = "model" if _CTX.sp_carry else None
    seq = _CTX.seq_spec   # token axis stays seq-sharded in both directions
    f = _boundary_fwd_bwd(
        lambda t: _spec_or_none(t, _CTX.dp_spec, seq, carry),
        lambda t: _spec_or_none(t, _CTX.dp_spec, seq, None),
    )(x.dtype)
    return f(x)


def _spec_or_none(x, *spec):
    clean = []
    for dim, s in zip(x.shape, spec):
        clean.append(s if (s is None or _divisible(dim, s)) else None)
    clean += [None] * (len(x.shape) - len(clean))
    return P(*clean)


def _boundary_fwd_bwd(fwd_spec_fn, bwd_spec_fn):
    """A sharding boundary with PINNED collectives in both directions.

    Forward: constrain to fwd_spec (e.g. all-gather the feature dim).
    Backward: cast the cotangent to the primal dtype (bf16) and constrain
    to bwd_spec (e.g. reduce-scatter back onto the feature dim). Without
    this, GSPMD transposes the forward all-gather into an fp32
    all-reduce of the cotangent — 4× the wire bytes of a bf16
    reduce-scatter (§Perf iteration 1).
    """
    def make(dtype):
        @jax.custom_vjp
        def f(x):
            return jax.lax.with_sharding_constraint(x, fwd_spec_fn(x))

        def fwd(x):
            return f(x), ()

        def bwd(_, g):
            g = g.astype(dtype)
            return (jax.lax.with_sharding_constraint(g, bwd_spec_fn(g)),)

        f.defvjp(fwd, bwd)
        return f
    return make


def gathered(x):
    """Replicate the feature dim (explicit bf16 all-gather point).

    Placed on the *post-norm, post-cast* tensor entering each dense
    projection so GSPMD gathers 2-byte activations — without this it
    gathers the norm's fp32 internals (2× the wire bytes). The backward
    direction is pinned to a bf16 reduce-scatter.
    """
    if not _CTX.enabled:
        return x
    carry = "model" if _CTX.sp_carry else None
    seq = _CTX.seq_spec
    f = _boundary_fwd_bwd(
        lambda t: _spec_or_none(t, _CTX.dp_spec, seq, None),
        lambda t: _spec_or_none(t, _CTX.dp_spec, seq, carry),
    )(x.dtype)
    return f(x)
