"""Sequence-parallel causal Taylor scan across the `seq` mesh axis.

The chunked causal scan's carry (`TaylorState` = S2/S1/S0 prefix sums)
composes associatively (`core.taylor.combine_states`), so the sequence
axis can be sharded: each shard runs the associative chunk scan over its
local chunks and the only cross-device traffic is a *chunk-boundary
state exchange* — a log-depth ppermute prefix (plus one psum for the
final state) over the shards' segment totals,
``(d², d+1) + (d, d+1) + (1, d+1)`` floats per head per hop, independent
of sequence length.

Layering (who owns what):

  * `core/taylor.py` owns the math: `_causal_scan_par_impl` /
    `_causal_scan_par_bwd_impl` take an ``axis_name`` and do the
    exchange with `all_gather` when given one.
  * this module owns the mesh: `shard_map` over the `seq` axis around
    those impls, with the custom VJP at the *global* level — forward
    and backward are each one shard_map call over non-differentiated
    bodies, so shard_map's autodiff/replication rules never enter the
    picture. Mesh axes other than `seq` are left in GSPMD `auto` mode,
    so batch/head/model sharding of the surrounding jit program passes
    straight through.

`make_seq_scan(mesh)` returns a drop-in for the ``scan_fn`` hook of
:func:`core.taylor.causal_taylorshift`; selection (when the mesh has a
`seq` axis, N divides, …) lives in `models/backend.py`.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import taylor as T


def _seq_spec(ndim: int, axis: str) -> P:
    """Chunk-major arrays (G, *lead, C, d): shard the chunk axis."""
    return P(axis, *([None] * (ndim - 1)))


def _wrap(mesh, axis, body, n_sharded_in, n_rep_in, n_sharded_out,
          n_rep_out, arrs):
    """shard_map ``body`` with the first ``n_sharded_in`` args sharded
    over ``axis`` on dim 0, the rest replicated (same split for
    outputs).

    Fully-manual mode over every mesh axis: dims not naming an axis are
    replicated across it inside the scan region. The batch/head dims
    *could* ride the data/model axes instead of replicating, but
    shard_map's `auto` mode (leave non-seq axes to GSPMD) trips an XLA
    SPMD-partitioner check in this jax version whenever an auto axis is
    non-trivial — revisit when the partitioner accepts manual subgroups
    next to auto axes. The jit wrapper makes the call traceable from
    eager callers; it is free when the caller is already jitted.
    """
    in_specs = tuple(_seq_spec(a.ndim, axis) for a in arrs[:n_sharded_in]) \
        + tuple(P() for _ in range(n_rep_in))
    out_specs = tuple([_seq_spec(arrs[0].ndim, axis)] * n_sharded_out
                      + [P()] * n_rep_out)
    return jax.jit(shard_map(body, mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _seq_scan(mesh, axis, qm, km, vm, s2_0, s1_0, s0_0):
    def body(qm, km, vm, s2_0, s1_0, s0_0):
        return T._causal_scan_par_impl(qm, km, vm, s2_0, s1_0, s0_0,
                                       axis_name=axis,
                                       axis_size=mesh.shape[axis])
    f = _wrap(mesh, axis, body, 3, 3, 1, 3, (qm, km, vm))
    return f(qm, km, vm, s2_0, s1_0, s0_0)


def _seq_scan_fwd(mesh, axis, qm, km, vm, s2_0, s1_0, s0_0):
    out = _seq_scan(mesh, axis, qm, km, vm, s2_0, s1_0, s0_0)
    return out, (qm, km, vm, s2_0, s1_0, s0_0)


def _seq_scan_bwd(mesh, axis, res, cot):
    qm, km, vm, s2_0, s1_0, s0_0 = res
    yb, dS2_f, dS1_f, dS0_f = cot

    def body(qm, km, vm, yb, s2_0, s1_0, s0_0, dS2_f, dS1_f, dS0_f):
        return T._causal_scan_par_bwd_impl(
            qm, km, vm, s2_0, s1_0, s0_0, yb, dS2_f, dS1_f, dS0_f,
            axis_name=axis, axis_size=mesh.shape[axis])

    f = _wrap(mesh, axis, body, 4, 6, 3, 3, (qm, km, vm, yb))
    return f(qm, km, vm, yb, s2_0, s1_0, s0_0, dS2_f, dS1_f, dS0_f)


_seq_scan.defvjp(_seq_scan_fwd, _seq_scan_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _axis_scan(axis, axis_size, qm, km, vm, s2_0, s1_0, s0_0):
    return T._causal_scan_par_impl(qm, km, vm, s2_0, s1_0, s0_0,
                                   axis_name=axis, axis_size=axis_size)


def _axis_scan_fwd(axis, axis_size, qm, km, vm, s2_0, s1_0, s0_0):
    out = _axis_scan(axis, axis_size, qm, km, vm, s2_0, s1_0, s0_0)
    return out, (qm, km, vm, s2_0, s1_0, s0_0)


def _axis_scan_bwd(axis, axis_size, res, cot):
    qm, km, vm, s2_0, s1_0, s0_0 = res
    yb, dS2_f, dS1_f, dS0_f = cot
    return T._causal_scan_par_bwd_impl(
        qm, km, vm, s2_0, s1_0, s0_0, yb, dS2_f, dS1_f, dS0_f,
        axis_name=axis, axis_size=axis_size)


_axis_scan.defvjp(_axis_scan_fwd, _axis_scan_bwd)


def make_axis_seq_scan(axis: str, axis_size: int):
    """A ``scan_fn`` for callers *already inside* a fully-manual
    shard_map region over ``axis`` — the composed 3D train step
    (distributed/composed.py), where the pipeline ring, FSDP gathers and
    this scan all live in one manual region and a nested shard_map is
    unavailable. Same boundary-exchange impls as :func:`make_seq_scan`,
    same recompute custom VJP, minus the mesh wrapper: the prefix/suffix
    state exchange runs over the ambient named axis, so Taylor-state
    continuity holds across seq shards at every pipeline stage."""
    def scan_fn(qm, km, vm, s2_0, s1_0, s0_0):
        return _axis_scan(axis, axis_size, qm, km, vm, s2_0, s1_0, s0_0)

    return scan_fn


def make_seq_scan(mesh, axis: str = "seq"):
    """A ``scan_fn`` for :func:`core.taylor.causal_taylorshift`: the
    chunk scan sharded over ``mesh``'s ``axis``. Requires the leading
    chunk count G to be divisible by the axis size (the selector in
    `models/backend.py` guarantees it, falling back to the sequential
    scan otherwise)."""
    size = mesh.shape[axis]

    def scan_fn(qm, km, vm, s2_0, s1_0, s0_0):
        if qm.shape[0] % size:
            raise ValueError(
                f"chunk count {qm.shape[0]} not divisible by seq axis "
                f"size {size}")
        return _seq_scan(mesh, axis, qm, km, vm, s2_0, s1_0, s0_0)

    return scan_fn
