"""Fault tolerance for long-running multi-pod jobs.

  * ``PreemptionHandler`` — converts SIGTERM/SIGINT into a cooperative
    "checkpoint and exit" request (TPU pods get ~30s eviction notice).
  * ``StragglerDetector`` — EWMA step-time monitor; flags steps slower
    than ``threshold×`` the running mean. On a real pod the flag feeds
    the controller that triggers replacement of the slow host; here it
    logs and counts (and the train loop exposes the count as a metric).
  * ``Membership`` — heartbeat-based replica membership: peers
    ``heartbeat()``, ``sweep()`` expires the silent ones, and every
    join/leave bumps the *epoch* (the router invalidation signal the
    ROADMAP's fleet-serving tier keys on). Visible to obs: membership
    size, per-peer heartbeat age, heartbeat and epoch-change counters
    all publish into ``repro.obs.metrics.default_registry`` (override
    with ``registry=``), so fleet snapshots carry replica health.
  * ``run_with_restarts`` — the supervision loop: run → on exception,
    restore from the last checkpoint and continue; gives up after
    ``max_failures`` within one step window (a poison-pill guard).
  * ``elastic_remesh`` — rebuild a smaller/larger mesh after losing or
    gaining hosts and re-place a restored checkpoint onto it (the
    checkpoint format is topology-free; see checkpoint/manager.py).
"""

from __future__ import annotations

import logging
import signal
import time
from dataclasses import dataclass, field

import jax

from repro.obs import metrics as OM

log = logging.getLogger("repro.ft")


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._signals = signals
        self._prev = {}

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handle)
            except ValueError:        # not on main thread (tests)
                pass
        return self

    def _handle(self, signum, frame):
        log.warning("preemption signal %s received — requesting checkpoint",
                    signum)
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False


@dataclass
class StragglerDetector:
    """EWMA step-time monitor. Pass ``registry=`` (a
    ``MetricsRegistry``) to also publish ``ft_straggler_events_total``
    and ``ft_step_time_ewma_seconds`` — the per-host spread of that
    gauge across merged fleet snapshots is the straggler signal."""

    threshold: float = 2.0       # step slower than 2× EWMA = straggler
    alpha: float = 0.1
    ewma: float | None = None
    stragglers: int = 0
    history: list = field(default_factory=list)
    registry: object | None = None

    def __post_init__(self):
        if self.registry is not None:
            self._straggler_c = self.registry.counter(
                "ft_straggler_events_total",
                "steps flagged slower than threshold x EWMA")
            self._ewma_g = self.registry.gauge(
                "ft_step_time_ewma_seconds",
                "EWMA of step wall time on this host")

    def observe(self, step_time_s: float) -> bool:
        is_straggler = False
        if self.ewma is not None and step_time_s > self.threshold * self.ewma:
            self.stragglers += 1
            is_straggler = True
            log.warning("straggler step: %.3fs vs EWMA %.3fs",
                        step_time_s, self.ewma)
            if self.registry is not None:
                self._straggler_c.inc()
        self.ewma = (step_time_s if self.ewma is None
                     else (1 - self.alpha) * self.ewma
                     + self.alpha * step_time_s)
        if self.registry is not None:
            self._ewma_g.set(self.ewma)
        self.history.append((step_time_s, is_straggler))
        return is_straggler


class Membership:
    """Heartbeat membership over replica/host peers, obs-visible.

    Pure bookkeeping — transport is the caller's problem (a real
    deployment forwards peer pings here; tests drive the clock). Every
    *change* of the member set bumps ``epoch``: the future router
    invalidates its placement on epoch changes rather than diffing
    member lists.

    Published metrics (``registry`` defaults to the process-global
    ``repro.obs.metrics.default_registry``):

      ft_members                    gauge    current live peers
      ft_heartbeat_age_seconds{peer} gauge   seconds since last beat
      ft_heartbeats_total           counter  beats received
      ft_epoch_changes_total        counter  joins + leaves
    """

    def __init__(self, *, timeout_s: float = 10.0,
                 registry: OM.MetricsRegistry | None = None,
                 clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last_beat: dict[str, float] = {}
        self.epoch = 0
        reg = registry if registry is not None else OM.default_registry
        self._members_g = reg.gauge("ft_members",
                                    "live peers in the membership")
        self._age_g = reg.gauge("ft_heartbeat_age_seconds",
                                "seconds since each peer's last beat",
                                labelnames=("peer",))
        self._beats_c = reg.counter("ft_heartbeats_total",
                                    "heartbeats received")
        self._epoch_c = reg.counter("ft_epoch_changes_total",
                                    "membership epoch bumps (join/leave)")

    @property
    def members(self) -> list[str]:
        return sorted(self._last_beat)

    def heartbeat(self, peer: str) -> None:
        """Record one beat; a first beat is a join (epoch bump)."""
        now = self._clock()
        joined = peer not in self._last_beat
        self._last_beat[peer] = now
        self._beats_c.inc()
        if joined:
            self.epoch += 1
            self._epoch_c.inc()
            log.info("peer %s joined (epoch %d, %d members)",
                     peer, self.epoch, len(self._last_beat))
        self.publish()

    def leave(self, peer: str) -> None:
        """Explicit departure (cooperative preemption / drain): drop the
        peer now, without waiting for its heartbeats to time out. A
        leave bumps the epoch exactly like an expiry."""
        if peer not in self._last_beat:
            return
        del self._last_beat[peer]
        self.epoch += 1
        self._epoch_c.inc()
        self._age_g.labels(peer=peer).set(self.timeout_s)
        log.info("peer %s left (epoch %d, %d members)",
                 peer, self.epoch, len(self._last_beat))
        self.publish()

    def sweep(self) -> list[str]:
        """Expire peers silent for ``timeout_s``; each is a leave
        (epoch bump). Returns the expired peers."""
        now = self._clock()
        dead = [p for p, t in self._last_beat.items()
                if now - t > self.timeout_s]
        for p in dead:
            del self._last_beat[p]
            self.epoch += 1
            self._epoch_c.inc()
            # the expired peer's age series freezes at the timeout: a
            # flat-lined series reads as "gone", not "infinitely stale"
            self._age_g.labels(peer=p).set(self.timeout_s)
            log.warning("peer %s expired (epoch %d, %d members)",
                        p, self.epoch, len(self._last_beat))
        self.publish()
        return dead

    def publish(self) -> None:
        """Refresh the gauges (called on every beat/sweep; callers may
        also call it right before snapshotting)."""
        now = self._clock()
        self._members_g.set(len(self._last_beat))
        for p, t in self._last_beat.items():
            self._age_g.labels(peer=p).set(now - t)


def run_with_restarts(make_state, run_fn, *, max_failures: int = 3):
    """Supervision loop.

    make_state() -> state      (fresh or restored-from-checkpoint)
    run_fn(state) -> state     (raises on failure; returns on completion)
    """
    failures = 0
    while True:
        state = make_state()
        try:
            return run_fn(state)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure
            failures += 1
            log.error("run failed (%d/%d): %s", failures, max_failures, e)
            if failures >= max_failures:
                raise


def elastic_remesh(n_devices: int | None = None, model_parallel: int = 1):
    """Build the largest (data, model) mesh the surviving devices allow."""
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    n = min(n, len(devs))
    data = max(n // model_parallel, 1)
    return jax.make_mesh((data, model_parallel), ("data", "model"),
                         devices=devs[:data * model_parallel])
