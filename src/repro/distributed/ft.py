"""Fault tolerance for long-running multi-pod jobs.

  * ``PreemptionHandler`` — converts SIGTERM/SIGINT into a cooperative
    "checkpoint and exit" request (TPU pods get ~30s eviction notice).
  * ``StragglerDetector`` — EWMA step-time monitor; flags steps slower
    than ``threshold×`` the running mean. On a real pod the flag feeds
    the controller that triggers replacement of the slow host; here it
    logs and counts (and the train loop exposes the count as a metric).
  * ``run_with_restarts`` — the supervision loop: run → on exception,
    restore from the last checkpoint and continue; gives up after
    ``max_failures`` within one step window (a poison-pill guard).
  * ``elastic_remesh`` — rebuild a smaller/larger mesh after losing or
    gaining hosts and re-place a restored checkpoint onto it (the
    checkpoint format is topology-free; see checkpoint/manager.py).
"""

from __future__ import annotations

import logging
import signal
import time
from dataclasses import dataclass, field

import jax

log = logging.getLogger("repro.ft")


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._signals = signals
        self._prev = {}

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handle)
            except ValueError:        # not on main thread (tests)
                pass
        return self

    def _handle(self, signum, frame):
        log.warning("preemption signal %s received — requesting checkpoint",
                    signum)
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False


@dataclass
class StragglerDetector:
    threshold: float = 2.0       # step slower than 2× EWMA = straggler
    alpha: float = 0.1
    ewma: float | None = None
    stragglers: int = 0
    history: list = field(default_factory=list)

    def observe(self, step_time_s: float) -> bool:
        is_straggler = False
        if self.ewma is not None and step_time_s > self.threshold * self.ewma:
            self.stragglers += 1
            is_straggler = True
            log.warning("straggler step: %.3fs vs EWMA %.3fs",
                        step_time_s, self.ewma)
        self.ewma = (step_time_s if self.ewma is None
                     else (1 - self.alpha) * self.ewma
                     + self.alpha * step_time_s)
        self.history.append((step_time_s, is_straggler))
        return is_straggler


def run_with_restarts(make_state, run_fn, *, max_failures: int = 3):
    """Supervision loop.

    make_state() -> state      (fresh or restored-from-checkpoint)
    run_fn(state) -> state     (raises on failure; returns on completion)
    """
    failures = 0
    while True:
        state = make_state()
        try:
            return run_fn(state)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure
            failures += 1
            log.error("run failed (%d/%d): %s", failures, max_failures, e)
            if failures >= max_failures:
                raise


def elastic_remesh(n_devices: int | None = None, model_parallel: int = 1):
    """Build the largest (data, model) mesh the surviving devices allow."""
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    n = min(n, len(devs))
    data = max(n // model_parallel, 1)
    return jax.make_mesh((data, model_parallel), ("data", "model"),
                         devices=devs[:data * model_parallel])
