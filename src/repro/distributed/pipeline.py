"""Pipeline parallelism: GPipe-style schedule over a stage-sharded mesh.

First-class PP option (docs/design.md §5): layers are partitioned into S
stages along a ``stage`` mesh axis; microbatches flow through stages
with `shard_map` + `ppermute` rotation. With M microbatches and S
stages the bubble fraction is (S-1)/(M+S-1) — the driver picks M ≥ 4·S.

This module is self-contained (used by tests and available to the
launcher via ``--pp``); the production dry-run table uses DP×TP(+EP)
which fits every assigned model at 256–512 chips, so PP here is
validated at feature level rather than swept over all 40 cells.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_pp_mesh(n_stages: int, data: int = 1):
    devs = jax.devices()
    assert len(devs) >= n_stages * data
    return jax.make_mesh((data, n_stages), ("data", "stage"),
                         devices=devs[:data * n_stages])


def pipeline_forward(stage_fn: Callable, params_stacked, x,
                     mesh: Mesh, *, n_microbatches: int,
                     remainder: str = "error"):
    """Run ``stage_fn(stage_params, h) -> h`` over S stages.

    params_stacked: pytree with leading dim S (stage-sharded).
    x: (B, ...) global batch.
    Returns y with the same shape as stage_fn's composition.

    ``remainder`` makes the ``B % n_microbatches != 0`` case an explicit
    policy instead of a shape accident:

      * ``"error"`` (default): raise — the caller sized the batch wrong;
      * ``"pad"``: zero-pad B up to the next multiple, run the padded
        schedule, slice the pad rows off the output (all B rows kept;
        costs up to one extra row per microbatch);
      * ``"drop"``: truncate to the largest multiple and return only the
        kept rows (output batch may be smaller than B — the caller
        owns loss re-weighting).

    GPipe schedule via shard_map: each device holds one stage; the
    activation ring rotates with ppermute. T = M + S - 1 ticks.
    """
    S = mesh.shape["stage"]
    M = n_microbatches
    B = x.shape[0]
    n_keep = B
    if B % M:
        if remainder == "error":
            raise ValueError(
                f"batch {B} not divisible by n_microbatches {M}; pass "
                f"remainder='pad' or 'drop' for an explicit policy")
        if remainder == "pad":
            pad = M - B % M
            x = jnp.concatenate(
                [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
        elif remainder == "drop":
            n_keep = (B // M) * M
            x = x[:n_keep]
        else:
            raise ValueError(f"unknown remainder policy {remainder!r}")
    mb = x.reshape(M, x.shape[0] // M, *x.shape[1:])

    def body(params, mb):
        # params: (1, ...) local stage slice; mb: (M, b, ...) replicated
        stage = jax.lax.axis_index("stage")
        p_local = jax.tree.map(lambda a: a[0], params)
        # check_rep=False: no replication annotations needed (pvary is
        # not available on this jax version)
        buf = jnp.zeros_like(mb[0])
        outs = jnp.zeros_like(mb)
        T = M + S - 1

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any)
            inject = jnp.where(t < M, t, M - 1)
            buf = jnp.where(stage == 0,
                            jnp.where(t < M, mb[inject], buf), buf)
            buf = stage_fn(p_local, buf)
            # last stage emits microbatch t-S+1
            emit = t - (S - 1)
            emit_c = jnp.clip(emit, 0, M - 1)
            outs = jnp.where(
                (stage == S - 1) & (emit >= 0),
                outs.at[emit_c].set(buf), outs)
            # rotate ring: stage i -> i+1
            buf = jax.lax.ppermute(
                buf, "stage", [(i, (i + 1) % S) for i in range(S)])
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        # collect outputs from the last stage to all (psum of one-hot)
        outs = jax.lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), "stage")
        return outs

    shmap = shard_map(
        body, mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
        check_rep=False,
    )
    y = shmap(params_stacked, mb)
    return y.reshape(-1, *y.shape[2:])[:n_keep]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def stage_occupancy(n_stages: int, n_microbatches: int) -> list[dict]:
    """Per-stage tick attribution of the GPipe schedule.

    The forward schedule runs ``T = M + S - 1`` ticks; stage ``s`` is
    busy exactly on ticks ``[s, s + M - 1]`` — ``s`` idle warmup ticks
    (waiting for the first microbatch to arrive) and ``S - 1 - s`` idle
    drain ticks (done while later stages finish). Deterministic, so the
    trainer publishes it as the per-stage bubble breakdown instead of
    timing inside the compiled scan.
    """
    ticks = n_microbatches + n_stages - 1
    return [{"stage": s, "warmup_idle": s, "busy": n_microbatches,
             "drain_idle": n_stages - 1 - s,
             "idle_fraction": (n_stages - 1) / ticks}
            for s in range(n_stages)]
