"""Pipeline parallelism: GPipe-style schedule over a stage-sharded mesh.

First-class PP option (docs/design.md §5): layers are partitioned into S
stages along a ``stage`` mesh axis; microbatches flow through stages
with `shard_map` + `ppermute` rotation. With M microbatches and S
stages the bubble fraction is (S-1)/(M+S-1) — the driver picks M ≥ 4·S.

This module is self-contained (used by tests and available to the
launcher via ``--pp``); the production dry-run table uses DP×TP(+EP)
which fits every assigned model at 256–512 chips, so PP here is
validated at feature level rather than swept over all 40 cells.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_pp_mesh(n_stages: int, data: int = 1):
    devs = jax.devices()
    assert len(devs) >= n_stages * data
    return jax.make_mesh((data, n_stages), ("data", "stage"),
                         devices=devs[:data * n_stages])


def pipeline_forward(stage_fn: Callable, params_stacked, x,
                     mesh: Mesh, *, n_microbatches: int):
    """Run ``stage_fn(stage_params, h) -> h`` over S stages.

    params_stacked: pytree with leading dim S (stage-sharded).
    x: (B, ...) global batch; B divisible by n_microbatches.
    Returns y with the same shape as stage_fn's composition.

    GPipe schedule via shard_map: each device holds one stage; the
    activation ring rotates with ppermute. T = M + S - 1 ticks.
    """
    S = mesh.shape["stage"]
    M = n_microbatches
    B = x.shape[0]
    assert B % M == 0
    mb = x.reshape(M, B // M, *x.shape[1:])

    def body(params, mb):
        # params: (1, ...) local stage slice; mb: (M, b, ...) replicated
        stage = jax.lax.axis_index("stage")
        p_local = jax.tree.map(lambda a: a[0], params)
        buf = jax.lax.pvary(jnp.zeros_like(mb[0]), ("stage",))
        outs = jax.lax.pvary(jnp.zeros_like(mb), ("stage",))
        mb = jax.lax.pvary(mb, ("stage",))
        T = M + S - 1

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any)
            inject = jnp.where(t < M, t, M - 1)
            buf = jnp.where(stage == 0,
                            jnp.where(t < M, mb[inject], buf), buf)
            buf = stage_fn(p_local, buf)
            # last stage emits microbatch t-S+1
            emit = t - (S - 1)
            emit_c = jnp.clip(emit, 0, M - 1)
            outs = jnp.where(
                (stage == S - 1) & (emit >= 0),
                outs.at[emit_c].set(buf), outs)
            # rotate ring: stage i -> i+1
            buf = jax.lax.ppermute(
                buf, "stage", [(i, (i + 1) % S) for i in range(S)])
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        # collect outputs from the last stage to all (psum of one-hot)
        outs = jax.lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), "stage")
        return outs

    shmap = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
    )
    y = shmap(params_stacked, mb)
    return y.reshape(B, *y.shape[2:])


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
