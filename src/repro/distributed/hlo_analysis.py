"""HLO-level analysis: collective byte accounting + roofline terms.

The compiled module (post-SPMD) is a per-device program, so every shape
below is per-device. Wire-byte models per collective (ring algorithms):

  all-reduce        2·B·(g-1)/g      (B = buffer bytes, g = group size)
  all-gather        B_out·(g-1)/g
  reduce-scatter    B_out·(g-1)
  all-to-all        B·(g-1)/g
  collective-permute B

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16,
819 GB/s HBM, 50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2  # conservative default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    buffer_bytes: dict
    wire_bytes_per_device: float

    def as_dict(self):
        return {"counts": self.counts, "buffer_bytes": self.buffer_bytes,
                "wire_bytes_per_device": self.wire_bytes_per_device}


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    buf: dict[str, int] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_str, op, _ = m.groups()
        b = _shape_bytes(shape_str)
        g = _group_size(line)
        counts[op] = counts.get(op, 0) + 1
        buf[op] = buf.get(op, 0) + b
        if op == "all-reduce":
            wire += 2 * b * (g - 1) / g
        elif op == "all-gather":
            wire += b * (g - 1) / g
        elif op == "reduce-scatter":
            wire += b * (g - 1)
        elif op == "all-to-all":
            wire += b * (g - 1) / g
        else:  # collective-permute
            wire += b
    return CollectiveStats(counts, buf, wire)


def roofline_terms(cost: dict, coll: CollectiveStats) -> dict:
    """Three per-device roofline times (seconds)."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    bytes_lower = float(cost.get("bytes_out", bytes_hbm))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_memory_lower = bytes_lower / HBM_BW
    t_collective = coll.wire_bytes_per_device / LINK_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)),
        key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_collective)
    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": bytes_hbm,
        "wire_bytes_per_device": coll.wire_bytes_per_device,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_lower_s": t_memory_lower,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "roofline_bound_s": bound,
        "compute_fraction_of_bound": t_compute / bound if bound else 0.0,
    }


def count_hlo_ops(hlo_text: str, *patterns: str) -> dict[str, int]:
    out = {}
    for p in patterns:
        out[p] = len(re.findall(rf"\b{re.escape(p)}", hlo_text))
    return out
