"""Composed 3D-parallel training: FSDP × pipeline × sequence scan on
one ``(data, pipe, seq)`` mesh.

The paper's headline is linear *memory* for full token-to-token
attention; PR 2 proved it per-kernel, PR 3 per-scan-shard. This module
composes the three proven parts into one measured training path:

  * ``seq``  — the causal Taylor chunk scan runs per sequence shard with
    the log-depth boundary state exchange (`seqscan.make_axis_seq_scan`,
    same impls as the standalone sequence-parallel path), so the
    TaylorState crosses seq shards *at every pipeline stage*; the
    non-causal form psums its O(d³) key-side sums instead
    (`taylor.efficient_taylorshift_sharded`).
  * ``pipe`` — a GPipe microbatch ring over stage-stacked layer
    parameters, written with `lax.scan` over T = M + S - 1 ticks (the
    scan is reverse-differentiable where `fori_loop` is not) and
    `ppermute` rotation.
  * ``data`` — batch parallelism, plus ZeRO-3-style FSDP: weight
    matrices rest sharded over ``data`` and are all-gathered
    just-in-time inside the step; the gather's transpose is the gradient
    reduce-scatter, so data-axis gradient reduction costs nothing extra.

Everything lives in ONE fully-manual `shard_map` region
(``check_rep=False``) with `jax.value_and_grad` *inside* the body.
Rationale: nesting the existing mesh-level shard_map wrappers
(`seqscan.make_seq_scan`, `pipeline.pipeline_forward`) is impossible
(shard_map does not nest), and `auto` mode next to manual axes trips an
XLA SPMD-partitioner check on this jax version (see seqscan._wrap). The
collective transposes this relies on — psum ↔ psum of cotangents,
ppermute ↔ inverse ppermute, all_gather(tiled) ↔ psum_scatter — are the
true adjoints on this jax version (verified by the parity tests in
tests/test_composed_parallel.py at ≤1e-4 against single-device grads).

Gradient bookkeeping (grad-of-local-loss + explicit psums): the body
differentiates the *local* scalar loss. Because reverse-mode seeds every
shard's own scalar with 1 and the transposed collectives mix cotangents
across shards, each shard ends holding ∂(Σ_shards local_loss)/∂(its
param copy). The logical gradient of a leaf is then the psum of those
partials over exactly the axes the leaf is *replicated* on:

  * outer leaves (embed/pos/final_norm/unembed): psum over all three
    axes (the loss head is computed redundantly per pipe shard at weight
    1/S, so the head contributions sum back to 1× — while the embedding
    path, masked to the injecting stage, contributes once);
  * stage leaves: psum over ``seq`` (+ ``data`` for non-FSDP leaves;
    FSDP leaves already got their data-sum from the gather transpose).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import taylor as T
from repro.distributed import seqscan
from repro.distributed import sharding as S
from repro.models import attention as A
from repro.models import backend as B
from repro.models import layers as L
from repro.optim.optimizers import make_optimizer


# ---------------------------------------------------------------------------
# Parameter layout: {"outer", "stages"} ⟷ models.model.init_params
# ---------------------------------------------------------------------------

def check_composed_config(cfg, n_stages: int) -> None:
    """The composed path needs a uniform stacked decoder: one repeating
    'global' block so layers split evenly into S stages of L each."""
    pattern = tuple(cfg.layer_pattern)
    if pattern != ("global",):
        raise ValueError(
            f"composed path needs layer_pattern=('global',), got {pattern}")
    if cfg.n_layers % n_stages:
        raise ValueError(
            f"n_layers={cfg.n_layers} not divisible by {n_stages} stages")
    if getattr(cfg, "family", "decoder") == "encdec":
        raise ValueError("composed path does not support encdec")


def split_params(cfg, params, n_stages: int):
    """init_params tree -> {"outer": head/embed leaves,
    "stages": block leaves reshaped (S, L, ...)}."""
    check_composed_config(cfg, n_stages)
    if params.get("rem"):
        raise ValueError("composed path requires a fully-stacked layout "
                         "(no remainder blocks)")
    L_per = cfg.n_layers // n_stages
    stages = jax.tree.map(
        lambda a: a.reshape(n_stages, L_per, *a.shape[1:]),
        params["groups"][0])
    outer = {k: v for k, v in params.items() if k not in ("groups", "rem")}
    return {"outer": outer, "stages": stages}


def merge_params(split):
    """Inverse of :func:`split_params` (grads map back the same way)."""
    blocks = jax.tree.map(
        lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]),
        split["stages"])
    out = dict(split["outer"])
    out["groups"] = [blocks]
    out["rem"] = []
    return out


def _fsdp_dims(split, mesh, fsdp: bool):
    """Int tree matching stages: the data-sharded dim per leaf, -1 = none."""
    data = mesh.shape["data"]
    return jax.tree.map(
        lambda a: S.composed_fsdp_dim(tuple(a.shape), data) if fsdp else -1,
        split["stages"])


def _split_shapes_thunk(cfg, n_stages: int):
    from repro.models import model as M

    def thunk():
        return split_params(
            cfg, M.init_params(cfg, jax.random.PRNGKey(0)), n_stages)

    return thunk


# ---------------------------------------------------------------------------
# The composed loss+grad step (one shard_map over the whole mesh)
# ---------------------------------------------------------------------------

def build_composed_grad_fn(cfg, mesh, *, global_batch: int, seq_len: int,
                           n_microbatches: int, fsdp: bool = False):
    """Returns ``(grad_fn, specs)`` where ``grad_fn(split_params, batch)
    -> (loss, grads_split)`` runs the full composed step and ``specs``
    is the PartitionSpec tree for the split params (grads share it).

    batch: {"tokens","labels"} of (global_batch, seq_len) int32, laid
    out P("data","seq") — data/pipeline.py's device_put_batch does this.
    """
    Dd = mesh.shape["data"]
    Sp = mesh.shape["pipe"]
    Sq = mesh.shape["seq"]
    check_composed_config(cfg, Sp)
    if global_batch % (Dd * n_microbatches):
        raise ValueError(
            f"global_batch={global_batch} must divide by data axis {Dd} × "
            f"microbatches {n_microbatches} (remainders: size the batch "
            f"explicitly; see pipeline.pipeline_forward's remainder "
            f"policy for the standalone path)")
    if seq_len % Sq:
        raise ValueError(f"seq_len={seq_len} not divisible by seq={Sq}")
    N_loc = seq_len // Sq
    B_loc = global_batch // Dd
    mb_rows = B_loc // n_microbatches
    M = n_microbatches
    d_model = cfg.d_model
    _, norm = L.make_norm(cfg.norm)
    tc = cfg.taylor

    sel = B.select_composed_scan(cfg, N=seq_len, d=cfg.dim_head,
                                 causal=cfg.causal, mesh=mesh)
    if cfg.causal:
        chunk = sel.chunk
        if N_loc % chunk:
            raise ValueError(f"chunk {chunk} does not divide local seq "
                             f"{N_loc}")
        scan_fn = (seqscan.make_axis_seq_scan("seq", Sq)
                   if sel.scan == "seq-parallel" else None)

    def _attn(p_attn, x, positions, n_prev):
        q, k, v = A._project_qkv(p_attn, cfg, x, positions)
        qg = A._group_q(q, cfg.kv_heads)
        kg, vg = k[:, :, None], v[:, :, None]
        tau = A._tau(p_attn, cfg, True)
        if cfg.causal:
            init = T.TaylorState.zeros((), q.shape[-1])._replace(n=n_prev)
            y = T.causal_taylorshift(
                qg, kg, vg, tau=tau, chunk=chunk,
                normalize_inputs=tc.normalize_inputs,
                output_scale=tc.output_scale,
                initial_state=init, scan_fn=scan_fn,
                scan_impl="sequential")
        else:
            y = T.efficient_taylorshift_sharded(
                qg, kg, vg, tau=tau,
                axis_name="seq" if Sq > 1 else None, n_global=seq_len,
                normalize_inputs=tc.normalize_inputs,
                output_scale=tc.output_scale)
        y = y.reshape(q.shape)
        return L.dense(p_attn["wo"], A._merge_heads(y).astype(x.dtype))

    def _block(p, x, positions, n_prev):
        z = norm(p["norm1"], x)
        h = _attn(p["attn"], z, positions, n_prev)
        if cfg.post_norm:
            h = norm(p["norm1_post"], h)
        x = x + h
        if cfg.d_ff:
            z = norm(p["norm2"], x)
            h = L.mlp(p["mlp"], z, act=cfg.act)
            if cfg.post_norm:
                h = norm(p["norm2_post"], h)
            x = x + h
        return x

    def _stage_fn(p_stage, h, positions, n_prev):
        def body(x, bp):
            return _block(bp, x, positions, n_prev), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        h, _ = jax.lax.scan(body_fn, h, p_stage)
        return h

    # static per-leaf FSDP dims (python ints, closed over — the psum-axis
    # choice below must be resolved at trace time)
    split_shapes = jax.eval_shape(_split_shapes_thunk(cfg, Sp))
    dims = _fsdp_dims(split_shapes, mesh, fsdp)
    specs = S.composed_param_specs(split_shapes, mesh, fsdp=fsdp)

    def body(outer, stages, batch):
        r_seq = jax.lax.axis_index("seq")
        stage_idx = jax.lax.axis_index("pipe")
        tokens, labels = batch["tokens"], batch["labels"]
        positions = r_seq * N_loc + jnp.arange(N_loc)
        n_prev = r_seq * N_loc

        def f(outer, stages):
            # FSDP: reconstruct the full local stage slice; the gather's
            # transpose reduce-scatters the gradient over `data`.
            full = jax.tree.map(
                lambda a, dim: (jax.lax.all_gather(a, "data", axis=dim,
                                                   tiled=True)
                                if dim >= 0 else a),
                stages, dims)
            p_local = jax.tree.map(lambda a: a[0], full)

            x = L.embed(outer["embed"], tokens) * jnp.asarray(
                jnp.sqrt(d_model), cfg.param_dtype)
            if cfg.pos_embed == "learned":
                x = L.add_learned_pos(outer["pos"], x, positions)
            mb = x.reshape(M, mb_rows, N_loc, d_model)

            def tick(buf, t):
                inj = jax.lax.dynamic_index_in_dim(
                    mb, jnp.minimum(t, M - 1), 0, keepdims=False)
                buf = jnp.where((stage_idx == 0) & (t < M), inj, buf)
                buf = _stage_fn(p_local, buf, positions, n_prev)
                y = jnp.where((stage_idx == Sp - 1) & (t >= Sp - 1),
                              buf, jnp.zeros_like(buf))
                buf = jax.lax.ppermute(
                    buf, "pipe", [(i, (i + 1) % Sp) for i in range(Sp)])
                return buf, y

            buf0 = jnp.zeros((mb_rows, N_loc, d_model), mb.dtype)
            _, ys = jax.lax.scan(tick, buf0, jnp.arange(M + Sp - 1))
            # only the last stage emitted non-zeros; replicate over pipe
            outs = jax.lax.psum(ys[Sp - 1:], "pipe")
            hidden = norm(outer["final_norm"],
                          outs.reshape(B_loc, N_loc, d_model))

            # loss head, computed redundantly on each pipe shard at
            # weight 1/S so Σ_shards local_loss == the global mean loss
            if cfg.tie_embeddings:
                lg = L.unembed(outer["embed"], hidden)
            else:
                lg = L.dense(outer["unembed"], hidden).astype(jnp.float32)
            if cfg.softcap_final:
                lg = L.softcap(lg, cfg.softcap_final)
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, labels[..., None],
                                       axis=-1)[..., 0]
            total = jnp.sum(lse - gold)
            return total / (global_batch * seq_len * Sp)

        loss_local, (g_outer, g_stages) = jax.value_and_grad(
            f, argnums=(0, 1))(outer, stages)
        loss = jax.lax.psum(loss_local, ("data", "pipe", "seq"))
        g_outer = jax.tree.map(
            lambda g: jax.lax.psum(g, ("data", "pipe", "seq")), g_outer)
        g_stages = jax.tree.map(
            lambda g, dim: jax.lax.psum(
                g, ("seq",) if dim >= 0 else ("data", "seq")),
            g_stages, dims)
        return loss, g_outer, g_stages

    batch_specs = {"tokens": P("data", "seq"), "labels": P("data", "seq")}
    fn = shard_map(
        body, mesh,
        in_specs=(specs["outer"], specs["stages"], batch_specs),
        out_specs=(P(), specs["outer"], specs["stages"]),
        check_rep=False)

    def grad_fn(split, batch):
        loss, g_outer, g_stages = fn(split["outer"], split["stages"], batch)
        return loss, {"outer": g_outer, "stages": g_stages}

    return grad_fn, specs


# ---------------------------------------------------------------------------
# Full train step (grad + optimizer), jitted over the composed mesh
# ---------------------------------------------------------------------------

def composed_param_shardings(split, mesh, *, fsdp: bool = False):
    specs = S.composed_param_specs(split, mesh, fsdp=fsdp)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def composed_opt_shardings(opt_state, pshard, mesh):
    """Moments (and master copies) shard like their params; step scalar
    is replicated."""
    rep = NamedSharding(mesh, P())
    out = {"step": rep}
    for k in opt_state:
        if k != "step":
            out[k] = pshard
    return out


def build_composed_train_step(cfg, opt_cfg, mesh, *, global_batch: int,
                              seq_len: int, n_microbatches: int,
                              fsdp: bool = False):
    """Returns ``(init_fn, step_fn, shard_fn)``:

      * ``init_fn(rng) -> (params_split, opt_state)`` device-placed on
        the composed mesh;
      * ``step_fn(params, opt_state, batch) -> (params, opt_state,
        metrics)`` — jitted, donates params/opt_state;
      * ``shard_fn(params_split) -> shardings tree`` for checkpointing.
    """
    from repro.models import model as M

    grad_fn, specs = build_composed_grad_fn(
        cfg, mesh, global_batch=global_batch, seq_len=seq_len,
        n_microbatches=n_microbatches, fsdp=fsdp)
    init_opt, update = make_optimizer(opt_cfg)
    Sp = mesh.shape["pipe"]

    split_shapes = jax.eval_shape(_split_shapes_thunk(cfg, Sp))
    pshard = composed_param_shardings(split_shapes, mesh, fsdp=fsdp)
    oshard = composed_opt_shardings(
        jax.eval_shape(init_opt, split_shapes), pshard, mesh)

    def init_fn(rng):
        params = M.init_params(cfg, rng)
        split = jax.device_put(split_params(cfg, params, Sp), pshard)
        opt_state = jax.jit(init_opt, out_shardings=oshard)(split)
        return split, opt_state

    def step(params, opt_state, batch):
        loss, grads = grad_fn(params, batch)
        rng = jax.random.fold_in(jax.random.PRNGKey(17), opt_state["step"])
        params, opt_state, metrics = update(params, grads, opt_state,
                                            rng=rng)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    step_fn = jax.jit(step, in_shardings=(pshard, oshard, None),
                      out_shardings=(pshard, oshard, None),
                      donate_argnums=(0, 1))

    def shard_fn(split):
        return composed_param_shardings(split, mesh, fsdp=fsdp)

    return init_fn, step_fn, shard_fn


def measure_seq_exchange(mesh, *, d: int, heads: int = 1,
                         seq_axis: str = "seq", repeats: int = 3) -> dict:
    """One-shot probe of the seq-axis chunk-boundary state exchange.

    Times a jitted shard_map whose body performs the same communication
    pattern as the scan's boundary exchange (seqscan.py): a log-depth
    ``ppermute`` chain plus one final ``psum``, each hop moving one
    TaylorState-sized segment total — ``(d², d+1) + (d, d+1) + (1,
    d+1)`` floats per head, independent of sequence length. Runs once
    at trainer startup (never inside the step), so the published
    ``train_seq_exchange_*`` gauges cost nothing on the training path.

    Returns ``{"seconds", "bytes_per_device", "rounds"}``; bytes are
    the analytic per-device wire total (state bytes × (rounds + 1)).
    """
    import time as _time

    S_seq = int(mesh.shape[seq_axis]) if seq_axis in mesh.shape else 1
    if S_seq <= 1:
        return {"seconds": 0.0, "bytes_per_device": 0, "rounds": 0}
    rounds = int(math.ceil(math.log2(S_seq)))
    state = (jnp.zeros((heads, d * d, d + 1), jnp.float32),
             jnp.zeros((heads, d, d + 1), jnp.float32),
             jnp.zeros((heads, 1, d + 1), jnp.float32))

    def body(s2, s1, s0):
        st = (s2, s1, s0)
        hop = 1
        while hop < S_seq:
            perm = [(i, (i + hop) % S_seq) for i in range(S_seq)]
            st = tuple(x + jax.lax.ppermute(x, seq_axis, perm)
                       for x in st)
            hop *= 2
        return tuple(jax.lax.psum(x, seq_axis) for x in st)

    f = jax.jit(shard_map(body, mesh, in_specs=(P(), P(), P()),
                          out_specs=(P(), P(), P()), check_rep=False))
    jax.block_until_ready(f(*state))            # compile + warm
    t0 = _time.perf_counter()
    out = None
    for _ in range(repeats):
        out = f(*state)
    jax.block_until_ready(out)
    seconds = (_time.perf_counter() - t0) / repeats
    state_bytes = 4 * heads * (d * d + d + 1) * (d + 1)
    return {"seconds": seconds,
            "bytes_per_device": state_bytes * (rounds + 1),
            "rounds": rounds}
