"""Loop-aware cost model over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count, which under-counts every scanned layer by n_layers× (we scan
layer groups precisely to keep compile time down). This module parses the
HLO text, builds the computation call graph (while/fusion/call/
conditional edges, with ``known_trip_count`` multipliers on whiles), and
accumulates:

  * flops       — 2·M·N·K for dots (+1 flop/element for elementwise)
  * hbm bytes   — operands + results of top-level fusions/dots/etc.
                  (a fusion is the unit of HBM traffic)
  * collectives — buffer + wire bytes per op type, trip-scaled

All shapes are per-device (post-partitioning), so results feed the
per-chip roofline directly.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "logistic", "sine", "cosine",
    "tan", "atan2", "remainder", "and", "or", "xor", "not", "compare",
    "select", "clamp", "convert", "is-finite", "erf",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*((?:\((?:[^()]|\([^()]*\))*\))|"
    r"(?:[\w]+\[[^\]]*\](?:\{[^}]*\})?))\s+([\w\-]+)(?:\.\d+)?\(")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")
_CALLED = {
    "while": re.compile(r"body=(%[\w.\-]+)"),
    "fusion": re.compile(r"calls=(%[\w.\-]+)"),
    "call": re.compile(r"to_apply=(%[\w.\-]+)"),
    "conditional": re.compile(
        r"(?:true_computation|false_computation|branch_computations=\{)"
        r"(%[\w.\-]+)"),
    "reduce": re.compile(r"to_apply=(%[\w.\-]+)"),
    "sort": re.compile(r"to_apply=(%[\w.\-]+)"),
    "scatter": re.compile(r"to_apply=(%[\w.\-]+)"),
    "reduce-window": re.compile(r"to_apply=(%[\w.\-]+)"),
    "select-and-scatter": re.compile(r"(?:select|scatter)=(%[\w.\-]+)"),
    "all-reduce": re.compile(r"to_apply=(%[\w.\-]+)"),
    "reduce-scatter": re.compile(r"to_apply=(%[\w.\-]+)"),
}


def _shape_numel_bytes(shape_str: str):
    total_n, total_b = 0, 0
    for dt, dims in _SHAPE_TOKEN.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_n += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_n, total_b


@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_out: float = 0.0   # outputs-only (lower bound: TPU fuses reads)
    coll_buffer: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_wire: float = 0.0
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "OpCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_out += other.bytes_out * mult
        self.coll_wire += other.coll_wire * mult
        for k, v in other.coll_buffer.items():
            self.coll_buffer[k] += v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        self._split_computations(hlo_text)
        self._memo: dict[str, OpCost] = {}

    def _split_computations(self, text: str):
        cur = None
        for line in text.splitlines():
            m = _COMP_HEADER.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                self.comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
            elif line.startswith("}"):
                cur = None
            elif cur is not None:
                self.comps[cur].append(line)

    # -- per-computation symbol table (name -> shape string) ----------------
    @staticmethod
    def _symtable(lines):
        tab = {}
        for ln in lines:
            m = re.match(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
                         r"((?:\([^)]*\))|(?:\w+\[[^\]]*\]))", ln)
            if m:
                tab[m.group(1)] = m.group(2)
        return tab

    def _dot_flops(self, line, result_shape, symtab) -> float:
        ops = _OPERANDS_RE.search(line)
        if not ops:
            return 0.0
        names = [o.strip() for o in ops.group(1).split(",")]
        if not names:
            return 0.0
        lhs_shape = symtab.get(names[0], "")
        dims = _SHAPE_TOKEN.findall(lhs_shape)
        if not dims:
            return 0.0
        lhs_dims = [int(d) for d in dims[0][1].split(",") if d]
        cmatch = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        k = 1
        if cmatch and cmatch.group(1):
            for i in cmatch.group(1).split(","):
                if int(i) < len(lhs_dims):
                    k *= lhs_dims[int(i)]
        out_n, _ = _shape_numel_bytes(result_shape)
        return 2.0 * out_n * k

    def cost_of(self, comp: str) -> OpCost:
        if comp in self._memo:
            return self._memo[comp]
        total = OpCost()
        self._memo[comp] = total  # guards (benign) cycles
        lines = self.comps.get(comp, [])
        symtab = self._symtable(lines)
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            _, result_shape, op = m.groups()
            out_n, out_b = _shape_numel_bytes(result_shape)

            # ---- child computations ----
            base_op = op
            mult = 1.0
            child_cost = None
            if base_op in _CALLED:
                cm = _CALLED[base_op].search(ln)
                if cm and cm.group(1) in self.comps:
                    child_cost = self.cost_of(cm.group(1))
                    if base_op == "while":
                        t = _TRIP_RE.search(ln)
                        mult = float(t.group(1)) if t else 1.0

            if base_op == "while" and child_cost is not None:
                total.add(child_cost, mult)
                continue
            if base_op == "fusion" and child_cost is not None:
                # fusion = ONE HBM round trip (operands in, result out);
                # internal ops contribute flops only. Operands consumed
                # exclusively through dynamic-slice/gather inside the
                # fusion are charged at slice size, not operand size.
                cm2 = _CALLED["fusion"].search(ln)
                total.flops += child_cost.flops
                total.bytes += self._fusion_read_bytes(
                    ln, symtab, cm2.group(1)) + out_b
                total.bytes_out += out_b
                continue
            if base_op in ("call", "conditional") and child_cost is not None:
                total.add(child_cost, 1.0)
                continue

            # ---- collectives ----
            cop = next((c for c in _COLLECTIVES
                        if op == c or op == c + "-start"), None)
            if cop:
                g = self._group_size(ln)
                b = out_b if cop != "reduce-scatter" else out_b
                total.coll_counts[cop] += 1
                total.coll_buffer[cop] += b
                if cop == "all-reduce":
                    total.coll_wire += 2 * b * (g - 1) / g
                elif cop == "all-gather":
                    total.coll_wire += b * (g - 1) / g
                elif cop == "reduce-scatter":
                    total.coll_wire += b * (g - 1)
                elif cop == "all-to-all":
                    total.coll_wire += b * (g - 1) / g
                else:
                    total.coll_wire += b
                total.bytes += out_b + self._operand_bytes(ln, symtab)
                total.bytes_out += out_b
                continue

            # ---- compute ops ----
            if op == "dot":
                total.flops += self._dot_flops(ln, result_shape, symtab)
                total.bytes += out_b + self._operand_bytes(ln, symtab)
                total.bytes_out += out_b
            elif op == "convolution":
                # rough: 2 * out_n * prod(kernel spatial+feature) — parse rhs
                total.flops += 2.0 * out_n * 1  # conservative floor
                total.bytes += out_b + self._operand_bytes(ln, symtab)
            elif op in _ELEMENTWISE:
                total.flops += out_n
                total.bytes += out_b + self._operand_bytes(ln, symtab)
                total.bytes_out += out_b
            elif op in ("reduce", "reduce-window"):
                total.flops += self._operand_numel(ln, symtab)
                total.bytes += out_b + self._operand_bytes(ln, symtab)
                total.bytes_out += out_b
            elif op in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced window, not the whole operand —
                # counting operands here over-stated xlstm's sLSTM scan
                # traffic by ~2 orders of magnitude (§Perf measurement fix)
                total.bytes += 2 * out_b
                total.bytes_out += out_b
            elif op in ("dynamic-update-slice", "scatter"):
                upd = self._min_operand_bytes(ln, symtab)
                total.bytes += 2 * upd
                total.bytes_out += upd
            elif op in ("copy", "copy-start", "transpose", "reshape",
                        "broadcast", "concatenate", "pad",
                        "reverse", "iota", "sort", "bitcast-convert"):
                if op != "bitcast":
                    total.bytes += out_b + self._operand_bytes(ln, symtab)
                    total.bytes_out += out_b
            # parameter/constant/tuple/gte/bitcast: no traffic
        return total

    def _operand_bytes(self, line, symtab) -> float:
        ops = _OPERANDS_RE.search(line)
        if not ops:
            return 0.0
        b = 0.0
        for name in ops.group(1).split(","):
            shp = symtab.get(name.strip())
            if shp:
                b += _shape_numel_bytes(shp)[1]
        return b

    def _fusion_read_bytes(self, line, symtab, child: str) -> float:
        """Bytes a fusion reads: full operand size, except operands whose
        in-fusion parameter is consumed only by dynamic-slice/gather —
        those read the slice window per execution."""
        ops = _OPERANDS_RE.search(line)
        if not ops:
            return 0.0
        names = [n.strip() for n in ops.group(1).split(",")]
        lines = self.comps.get(child, [])
        # param index -> (sliced_only, sliced_bytes)
        param_names = {}
        for ln2 in lines:
            pm = re.match(r"^\s*(%[\w.\-]+)\s*=\s*[^=]*parameter\((\d+)\)",
                          ln2)
            if pm:
                param_names[pm.group(1)] = int(pm.group(2))
        sliced_bytes = {}
        other_use = set()
        for ln2 in lines:
            d2 = _DEF_RE.match(ln2)
            if not d2:
                continue
            opnds = _OPERANDS_RE.search(ln2)
            used = ([n.strip() for n in opnds.group(1).split(",")]
                    if opnds else [])
            is_slice = d2.group(3) in ("dynamic-slice", "gather")
            for j, u in enumerate(used):
                if u in param_names:
                    idx = param_names[u]
                    if is_slice and j == 0:
                        b = _shape_numel_bytes(d2.group(2))[1]
                        sliced_bytes[idx] = sliced_bytes.get(idx, 0.0) + b
                    else:
                        other_use.add(idx)
        total = 0.0
        for i, name in enumerate(names):
            full = _shape_numel_bytes(symtab.get(name, ""))[1]
            if i in sliced_bytes and i not in other_use:
                total += min(sliced_bytes[i], full)
            else:
                total += full
        return total

    def _min_operand_bytes(self, line, symtab) -> float:
        ops = _OPERANDS_RE.search(line)
        if not ops:
            return 0.0
        sizes = [_shape_numel_bytes(symtab[n.strip()])[1]
                 for n in ops.group(1).split(",") if n.strip() in symtab]
        return min(sizes) if sizes else 0.0

    def _operand_numel(self, line, symtab) -> float:
        ops = _OPERANDS_RE.search(line)
        if not ops:
            return 0.0
        n = 0.0
        for name in ops.group(1).split(","):
            shp = symtab.get(name.strip())
            if shp:
                n += _shape_numel_bytes(shp)[0]
        return n

    @staticmethod
    def _group_size(line) -> int:
        m = _GROUPS_RE.search(line)
        if m:
            return len(m.group(1).split(","))
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return int(m.group(2))
        return 2

    def entry_cost(self) -> OpCost:
        entry = self.entry
        if entry is None:
            entry = next((c for c in self.comps if "main" in c),
                         next(iter(self.comps)))
        return self.cost_of(entry)


def analyze(hlo_text: str) -> dict:
    cost = HloCostModel(hlo_text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "bytes_out": cost.bytes_out,
        "coll_wire_bytes": cost.coll_wire,
        "coll_buffer_bytes": dict(cost.coll_buffer),
        "coll_counts": dict(cost.coll_counts),
    }
