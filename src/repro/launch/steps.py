"""Step builders: train / prefill / serve, with their shardings.

Everything here is mesh-aware but allocation-free: shapes come from
``jax.eval_shape`` and shardings from distributed/sharding.py, so the
dry-run can lower+compile 400B-parameter configurations on a CPU host.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as S
from repro.launch import shapes as SH
from repro.launch.mesh import dp_axes
from repro.models import model as M
from repro.optim import OptConfig, make_optimizer


def default_opt_config(cfg: ModelConfig) -> OptConfig:
    """Memory policy scales with model size (docs/design.md §5)."""
    n = M.count_params_analytic(cfg)
    if n > 100e9:
        return OptConfig(moment_dtype="bfloat16", master=False,
                         stochastic_round=True)
    if n > 20e9:
        return OptConfig(moment_dtype="bfloat16")
    return OptConfig()


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                     *, microbatches: int = 1):
    """Train step with optional gradient accumulation.

    ``microbatches > 1`` scans over batch slices, accumulating fp32
    gradients — activation memory drops ~M× at the cost of M sequential
    passes (the standard fit-the-HBM lever for the ≥300B MoE cells and
    the §Perf stablelm `sp_carry=False` variant)."""
    _, update = make_optimizer(opt_cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: M.loss_fn(p, cfg, batch))(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                        *x.shape[1:])[i], batch)

            def body(carry, i):
                acc, loss_acc = carry
                loss_i, g_i = grads_of(params, slice_mb(i))
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, g_i)
                return (acc, loss_acc + loss_i), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: (g / microbatches), gsum)
        rng = jax.random.fold_in(jax.random.PRNGKey(17), opt_state["step"])
        params, opt_state, metrics = update(params, grads, opt_state, rng=rng)
        return params, opt_state, {"loss": loss, **metrics}

    return train_step


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        hidden, _ = M.forward(params, cfg, batch)
        logits = M.logits_from_hidden(params, cfg, hidden[:, -1:])
        return logits

    return prefill_step


def build_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        return M.decode_step(params, cfg, batch, cache)

    return serve_step


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(M.init_params, cfg),
                          jax.random.PRNGKey(0))


def opt_state_shapes(cfg: ModelConfig, opt_cfg: OptConfig, pshapes):
    init_opt, _ = make_optimizer(opt_cfg)
    return jax.eval_shape(init_opt, pshapes)


def opt_state_shardings(cfg, opt_cfg, pshapes, pshardings, mesh):
    """mu/nu/master: ZeRO-1 (param spec + data axis); step: replicated."""
    oshapes = opt_state_shapes(cfg, opt_cfg, pshapes)
    out = {}
    for k, v in oshapes.items():
        if k == "step":
            out[k] = S.replicated(mesh)
        else:
            out[k] = S.zero1_shardings(pshardings, v, mesh)
    return out


def model_cache_shardings(cache_shapes, mesh):
    """Shardings for the model-level decode cache pytree."""
    out: dict[str, Any] = {}
    out["groups"] = [S.cache_shardings(g, mesh, stacked=True)
                     for g in cache_shapes["groups"]]
    out["rem"] = [S.cache_shardings(r, mesh, stacked=False)
                  for r in cache_shapes["rem"]]
    out["pos"] = S.replicated(mesh)
    if "cross" in cache_shapes:
        out["cross"] = S.cache_shardings(cache_shapes["cross"], mesh,
                                         stacked=True)
    return out


def logits_sharding(cfg: ModelConfig, mesh, batch: int = 0):
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dpspec = (tuple(dp) if len(dp) > 1 else dp[0]) \
        if (batch == 0 or batch % dp_size == 0) and batch != 1 else None
    vspec = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
    return NamedSharding(mesh, P(dpspec, None, vspec))


# ---------------------------------------------------------------------------
# Cell assembly: everything dryrun/train/serve needs for one (arch, shape)
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, cell_name: str, mesh, *,
               cache_kind: str = "taylor", microbatches: int = 1):
    """Returns (jitted_fn, example_args) where every arg is a
    ShapeDtypeStruct with sharding attached — ready to .lower()."""
    cell = SH.SHAPE_CELLS[cell_name]
    cfg = SH.adapt_config(cfg, cell)
    pshapes = param_shapes(cfg)
    pshard = S.param_shardings(pshapes, mesh)
    batch = SH.input_specs(cfg, cell_name)
    bshard = S.batch_shardings(batch, mesh)

    def with_sharding(shapes, shardings):
        return jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            shapes, shardings)

    if cell.kind == "train":
        opt_cfg = default_opt_config(cfg)
        ostates = opt_state_shapes(cfg, opt_cfg, pshapes)
        oshard = opt_state_shardings(cfg, opt_cfg, pshapes, pshard, mesh)
        fn = build_train_step(cfg, opt_cfg, microbatches=microbatches)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, S.replicated(mesh)),
            donate_argnums=(0, 1),
        )
        args = (with_sharding(pshapes, pshard),
                with_sharding(ostates, oshard),
                with_sharding(batch, bshard))
        return jitted, args, cfg

    if cell.kind == "prefill":
        fn = build_prefill_step(cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, bshard),
            out_shardings=logits_sharding(cfg, mesh, cell.global_batch),
        )
        args = (with_sharding(pshapes, pshard), with_sharding(batch, bshard))
        return jitted, args, cfg

    # decode
    cache_shapes = jax.eval_shape(
        lambda: M.init_decode_state(cfg, cell.global_batch,
                                    cache_len=cell.seq_len,
                                    cache_kind=cache_kind))
    cshard = model_cache_shardings(cache_shapes, mesh)
    fn = build_serve_step(cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(pshard, cshard, bshard),
        out_shardings=(logits_sharding(cfg, mesh, cell.global_batch), cshard),
        donate_argnums=(1,),
    )
    args = (with_sharding(pshapes, pshard),
            with_sharding(cache_shapes, cshard),
            with_sharding(batch, bshard))
    return jitted, args, cfg
