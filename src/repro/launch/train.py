"""End-to-end training driver.

Wires every substrate layer together: config registry → data pipeline →
sharded train step (pjit) → checkpoint manager → fault tolerance
(preemption handler, straggler detector, restart supervision).

Scales from CPU smoke runs to the production mesh unchanged:

  PYTHONPATH=src python -m repro.launch.train --arch taylorshift-lra \
      --steps 200 --batch 8 --seq 256 --d-model 128
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --mesh single …
"""

from __future__ import annotations

import argparse
import logging
import platform
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, DataLoader, device_put_batch
from repro.distributed import composed as C
from repro.distributed import ctx
from repro.distributed import sharding as S
from repro.distributed.ft import (PreemptionHandler, StragglerDetector,
                                  run_with_restarts)
from repro.distributed.pipeline import bubble_fraction
from repro.launch.mesh import (make_composed_mesh, make_local_mesh,
                               make_production_mesh, make_seq_mesh,
                               pipe_size, seq_size)
from repro.launch.steps import (build_train_step, default_opt_config,
                                opt_state_shardings, param_shapes)
from repro.models import backend as B
from repro.models import model as M
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import tracer
from repro.optim import make_optimizer

log = logging.getLogger("repro.train")


class TrainObs:
    """Step-loop observability, same surfaces as the serving path
    (docs/observability.md): a MetricsRegistry rendered to Prometheus
    text via :meth:`write`, plus the process-global tracer — callers
    enable it and one ``train_step`` span per step lands in the Chrome
    trace, so pipeline-bubble stalls show up in Perfetto next to the
    jit-warmup (``compile=true``) span."""

    def __init__(self):
        self.registry = MetricsRegistry()
        r = self.registry
        self.step_time = r.histogram(
            "train_step_seconds", "wall time per optimizer step",
            buckets=(0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
                     10.0, 30.0, 120.0))
        self.tokens_per_sec = r.gauge(
            "train_tokens_per_second",
            "global_batch x seq_len / last step wall time")
        self.loss = r.gauge("train_loss", "last step loss")
        self.steps_total = r.counter("train_steps_total",
                                     "optimizer steps run")
        self.activation_bytes = r.gauge(
            "train_activation_bytes",
            "per-device temp (activation+workspace) bytes of the "
            "compiled train step, from XLA's memory analysis")
        self.bubble = r.gauge(
            "train_pipeline_bubble_fraction",
            "(S-1)/(M+S-1) of the GPipe schedule; 0 off the composed "
            "path")
        # per-axis deep metrics (composed path, PR 9): bubble
        # attribution per stage from the deterministic GPipe schedule,
        # FSDP collective bytes from the compiled HLO, the seq-axis
        # boundary-exchange probe, and a per-host step gauge whose
        # spread across fleet snapshots is the straggler signal
        self.stage_busy = r.gauge(
            "train_pipeline_stage_busy_ticks",
            "GPipe ticks stage s computes (= microbatches)",
            labelnames=("stage",))
        self.stage_warmup = r.gauge(
            "train_pipeline_stage_warmup_ticks",
            "idle ticks before the first microbatch reaches stage s",
            labelnames=("stage",))
        self.stage_drain = r.gauge(
            "train_pipeline_stage_drain_ticks",
            "idle ticks after stage s's last microbatch",
            labelnames=("stage",))
        self.collective_count = r.gauge(
            "train_collective_count",
            "collectives per compiled step, from the post-SPMD HLO",
            labelnames=("op",))
        self.collective_bytes = r.gauge(
            "train_collective_buffer_bytes",
            "per-device buffer bytes per collective kind (FSDP "
            "all-gather / reduce-scatter live here)", labelnames=("op",))
        self.collective_wire = r.gauge(
            "train_collective_wire_bytes_per_device",
            "modeled per-device wire bytes of one compiled step")
        self.seq_exchange_s = r.gauge(
            "train_seq_exchange_seconds",
            "measured seq-axis boundary-exchange time (log-depth "
            "ppermute + psum probe, distributed/composed.py)")
        self.seq_exchange_b = r.gauge(
            "train_seq_exchange_bytes_per_device",
            "analytic per-device bytes of one boundary exchange")
        self.host_step = r.gauge(
            "train_host_step_seconds",
            "last step wall time on this host (fleet straggler signal)",
            labelnames=("host",))
        self._host = platform.node() or "host0"

    def record_compiled(self, step_fn, *example_args) -> None:
        """AOT-lower the step to read XLA's activation-memory figure
        and the per-collective byte accounting (hlo_analysis). Costs
        one extra compile, so only runs when obs is requested."""
        try:
            compiled = step_fn.lower(*example_args).compile()
        except Exception:   # pragma: no cover — backend without AOT
            log.debug("AOT compile unavailable", exc_info=True)
            return
        try:
            mem = compiled.memory_analysis()
            self.activation_bytes.set(float(mem.temp_size_in_bytes))
        except Exception:   # pragma: no cover — backend without analysis
            log.debug("memory_analysis unavailable", exc_info=True)
        try:
            from repro.distributed.hlo_analysis import collective_stats
            stats = collective_stats(compiled.as_text())
            for op, n in stats.counts.items():
                self.collective_count.labels(op=op).set(n)
            for op, b in stats.buffer_bytes.items():
                self.collective_bytes.labels(op=op).set(b)
            self.collective_wire.set(stats.wire_bytes_per_device)
        except Exception:   # pragma: no cover — no post-SPMD text
            log.debug("collective_stats unavailable", exc_info=True)

    def record_pipeline(self, n_stages: int, n_microbatches: int) -> None:
        """Whole-schedule bubble plus the per-stage warmup/busy/drain
        tick split (distributed/pipeline.py:stage_occupancy)."""
        from repro.distributed.pipeline import (bubble_fraction,
                                                stage_occupancy)
        self.bubble.set(bubble_fraction(n_stages, n_microbatches))
        for occ in stage_occupancy(n_stages, n_microbatches):
            s = str(occ["stage"])
            self.stage_busy.labels(stage=s).set(occ["busy"])
            self.stage_warmup.labels(stage=s).set(occ["warmup_idle"])
            self.stage_drain.labels(stage=s).set(occ["drain_idle"])

    def record_seq_exchange(self, probe: dict) -> None:
        self.seq_exchange_s.set(probe["seconds"])
        self.seq_exchange_b.set(probe["bytes_per_device"])

    def observe(self, *, dt: float, tokens: int, loss: float) -> None:
        self.step_time.observe(dt)
        self.tokens_per_sec.set(tokens / max(dt, 1e-9))
        self.loss.set(loss)
        self.steps_total.inc()
        self.host_step.labels(host=self._host).set(dt)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.registry.render())


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          mesh=None, ckpt_dir: str | None = None, ckpt_every: int = 50,
          log_every: int = 10, seed: int = 0, opt_cfg=None,
          obs: TrainObs | None = None):
    mesh = mesh or make_local_mesh()
    opt_cfg = opt_cfg or default_opt_config(cfg)
    init_opt, _ = make_optimizer(opt_cfg)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    data_cfg = DataConfig(vocab=cfg.vocab, global_batch=global_batch,
                          seq_len=seq_len, seed=seed)

    with mesh, ctx.use(mesh):
        sel = B.select_backend(cfg, N=seq_len, d=cfg.dim_head, site="full",
                               causal=cfg.causal)
        log.info("attention backend: %s mode=%s seq_shards=%d (%s)",
                 sel.name, sel.mode, sel.seq_shards, sel.reason)
        pshapes = param_shapes(cfg)
        pshard = S.param_shardings(pshapes, mesh)
        oshard = opt_state_shardings(cfg, opt_cfg, pshapes, pshard, mesh)
        step_fn = jax.jit(
            build_train_step(cfg, opt_cfg),
            in_shardings=(pshard, oshard, None),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )

        start_step = 0
        if mgr is not None and mgr.latest_step() is not None:
            ostates = jax.eval_shape(init_opt, pshapes)
            start_step, (params, opt_state) = mgr.restore(
                (pshapes, ostates), shardings=(pshard, oshard))
            log.info("restored checkpoint at step %d", start_step)
        else:
            params = jax.device_put(
                M.init_params(cfg, jax.random.PRNGKey(seed)), pshard)
            opt_state = jax.device_put(init_opt(params), oshard)

        loader = DataLoader(data_cfg, start_step=start_step)
        detector = StragglerDetector(
            registry=obs.registry if obs is not None else None)
        losses = []
        obs_compiled = obs is None
        with PreemptionHandler() as pre:
            try:
                for step, batch in loader:
                    if step >= steps:
                        break
                    t0 = time.time()
                    batch = device_put_batch(batch, mesh)
                    if not obs_compiled:
                        obs.record_compiled(step_fn, params, opt_state,
                                            batch)
                        obs_compiled = True
                    with tracer.span("train_step", step_num=step,
                                     compile_key="train_step"):
                        params, opt_state, metrics = step_fn(
                            params, opt_state, batch)
                        loss = float(metrics["loss"])
                    dt = time.time() - t0
                    detector.observe(dt)
                    if obs is not None:
                        obs.observe(dt=dt, tokens=global_batch * seq_len,
                                    loss=loss)
                    losses.append(loss)
                    if step % log_every == 0:
                        log.info("step %d loss %.4f gnorm %.3f (%.2fs)",
                                 step, loss,
                                 float(metrics["grad_norm"]),
                                 time.time() - t0)
                    if mgr is not None and step and step % ckpt_every == 0:
                        mgr.save(step + 1, (params, opt_state))
                    if pre.preempted:
                        log.warning("preempted — checkpointing at step %d",
                                    step)
                        if mgr is not None:
                            mgr.save(step + 1, (params, opt_state),
                                     blocking=True)
                        break
            finally:
                loader.close()
                if mgr is not None:
                    mgr.wait()
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "stragglers": detector.stragglers}


def train_composed(cfg, *, steps: int, global_batch: int, seq_len: int,
                   mesh, n_microbatches: int, fsdp: bool = False,
                   ckpt_dir: str | None = None, ckpt_every: int = 50,
                   log_every: int = 10, seed: int = 0, opt_cfg=None,
                   obs: TrainObs | None = None):
    """Composed 3D-parallel training loop: seq-scan × pipeline × FSDP on
    one ``(data, pipe, seq)`` mesh (distributed/composed.py). Same
    data / checkpoint / fault-tolerance wiring as :func:`train`; the
    step itself is the single fully-manual shard_map step, so there is
    no ``ctx.use`` — the composed selector pins the mesh explicitly."""
    opt_cfg = opt_cfg or default_opt_config(cfg)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    S_pipe = pipe_size(mesh)
    S_seq = seq_size(mesh)

    data_cfg = DataConfig(vocab=cfg.vocab, global_batch=global_batch,
                          seq_len=seq_len, seed=seed)

    sel = B.select_composed_scan(cfg, N=seq_len, d=cfg.dim_head,
                                 causal=cfg.causal, mesh=mesh)
    log.info("composed mesh %s: scan=%s chunk=%d microbatches=%d "
             "bubble=%.3f fsdp=%s (%s)",
             dict(mesh.shape), sel.scan, sel.chunk, n_microbatches,
             bubble_fraction(S_pipe, n_microbatches), fsdp, sel.reason)
    if obs is not None:
        obs.record_pipeline(S_pipe, n_microbatches)
        if S_seq > 1:
            # one-shot startup probe — never inside the step loop
            obs.record_seq_exchange(C.measure_seq_exchange(
                mesh, d=cfg.dim_head, heads=cfg.n_heads))
        else:
            obs.record_seq_exchange(
                {"seconds": 0.0, "bytes_per_device": 0, "rounds": 0})

    init_fn, step_fn, _ = C.build_composed_train_step(
        cfg, opt_cfg, mesh, global_batch=global_batch, seq_len=seq_len,
        n_microbatches=n_microbatches, fsdp=fsdp)

    with mesh:
        start_step = 0
        if mgr is not None and mgr.latest_step() is not None:
            split_shapes = jax.eval_shape(C._split_shapes_thunk(cfg, S_pipe))
            init_opt, _ = make_optimizer(opt_cfg)
            oshapes = jax.eval_shape(init_opt, split_shapes)
            pshard = C.composed_param_shardings(split_shapes, mesh,
                                                fsdp=fsdp)
            oshard = C.composed_opt_shardings(oshapes, pshard, mesh)
            start_step, (params, opt_state) = mgr.restore(
                (split_shapes, oshapes), shardings=(pshard, oshard))
            log.info("restored composed checkpoint at step %d", start_step)
        else:
            params, opt_state = init_fn(jax.random.PRNGKey(seed))

        loader = DataLoader(data_cfg, start_step=start_step)
        detector = StragglerDetector(
            registry=obs.registry if obs is not None else None)
        losses = []
        obs_compiled = obs is None
        with tracer.span("composed_schedule", stages=S_pipe, seq=S_seq,
                         data=mesh.shape["data"],
                         microbatches=n_microbatches,
                         bubble=bubble_fraction(S_pipe, n_microbatches)):
            pass
        with PreemptionHandler() as pre:
            try:
                for step, batch in loader:
                    if step >= steps:
                        break
                    t0 = time.time()
                    batch = device_put_batch(batch, mesh)
                    if not obs_compiled:
                        obs.record_compiled(step_fn, params, opt_state,
                                            batch)
                        obs_compiled = True
                    with tracer.span("train_step", step_num=step,
                                     compile_key="composed_step"):
                        params, opt_state, metrics = step_fn(
                            params, opt_state, batch)
                        loss = float(metrics["loss"])
                    dt = time.time() - t0
                    detector.observe(dt)
                    if obs is not None:
                        obs.observe(dt=dt, tokens=global_batch * seq_len,
                                    loss=loss)
                    losses.append(loss)
                    if step % log_every == 0:
                        log.info("step %d loss %.4f gnorm %.3f (%.2fs)",
                                 step, loss,
                                 float(metrics["grad_norm"]), dt)
                    if mgr is not None and step and step % ckpt_every == 0:
                        mgr.save(step + 1, (params, opt_state))
                    if pre.preempted:
                        log.warning("preempted — checkpointing at step %d",
                                    step)
                        if mgr is not None:
                            mgr.save(step + 1, (params, opt_state),
                                     blocking=True)
                        break
            finally:
                loader.close()
                if mgr is not None:
                    mgr.wait()
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "stragglers": detector.stragglers}


def main():
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="taylorshift-lra")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (CPU smoke runs)")
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--context-parallel", type=int, default=1,
                    help="size of the `seq` mesh axis: shards the causal "
                         "Taylor scan (and activations) over the sequence "
                         "(docs/sharding.md)")
    ap.add_argument("--pipeline-stages", type=int, default=1,
                    help="size of the `pipe` mesh axis: >1 switches to "
                         "the composed (data, pipe, seq) training path "
                         "(distributed/composed.py, docs/training.md)")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="GPipe microbatches on the composed path "
                         "(default: 2x stages, capped at the per-data-"
                         "shard batch)")
    ap.add_argument("--fsdp", action="store_true",
                    help="composed path: shard stage weight matrices "
                         "over `data` with just-in-time all-gather "
                         "(ZeRO-3)")
    ap.add_argument("--metrics-file", default="",
                    help="write Prometheus text metrics here at exit")
    ap.add_argument("--trace", default="",
                    help="write a Chrome trace of train_step spans here")
    ap.add_argument("--annotate-steps", action="store_true",
                    help="add jax.profiler step annotations to spans")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--restartable", action="store_true",
                    help="wrap in the fault-tolerant supervision loop")
    ap.add_argument("--no-kernels", action="store_true",
                    help="train through the pure-jnp reference attention "
                         "instead of the fused Pallas kernels (custom VJP)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.d_model:
        cfg = cfg.with_(d_model=args.d_model)
    if args.n_layers:
        cfg = cfg.with_(n_layers=args.n_layers)
    cfg = cfg.with_(max_seq_len=max(cfg.max_seq_len, args.seq))
    cfg = B.configure_for_training(cfg, use_kernels=not args.no_kernels)

    cp = args.context_parallel
    pp = args.pipeline_stages
    if pp > 1:
        mesh = (make_composed_mesh(pipe=pp, seq=cp)
                if args.mesh == "local"
                else make_production_mesh(multi_pod=args.mesh == "multi",
                                          seq=cp, pipe=pp))
    elif cp > 1:
        mesh = (make_seq_mesh(cp) if args.mesh == "local"
                else make_production_mesh(multi_pod=args.mesh == "multi",
                                          seq=cp))
    else:
        mesh = (make_local_mesh() if args.mesh == "local"
                else make_production_mesh(multi_pod=args.mesh == "multi"))

    obs = TrainObs() if (args.metrics_file or args.trace) else None
    if args.trace:
        tracer.enable(annotate_steps=args.annotate_steps)

    def go(_state=None):
        if pp > 1:
            b_loc = args.batch // mesh.shape["data"]
            mb = args.microbatches or max(1, min(2 * pp, b_loc))
            return train_composed(
                cfg, steps=args.steps, global_batch=args.batch,
                seq_len=args.seq, mesh=mesh, n_microbatches=mb,
                fsdp=args.fsdp, ckpt_dir=args.ckpt_dir or None, obs=obs)
        return train(cfg, steps=args.steps, global_batch=args.batch,
                     seq_len=args.seq, mesh=mesh,
                     ckpt_dir=args.ckpt_dir or None, obs=obs)

    if args.restartable:
        out = run_with_restarts(lambda: None, go)
    else:
        out = go()
    if args.trace:
        tracer.write(args.trace)
        tracer.disable()
        print(f"trace: {len(tracer.export()['traceEvents'])} events "
              f"-> {args.trace}")
    if args.metrics_file and obs is not None:
        obs.write(args.metrics_file)
        print(f"metrics exposition -> {args.metrics_file}")
    print(f"final loss: {np.mean(out['losses'][-10:]):.4f} "
          f"(first10 {np.mean(out['losses'][:10]):.4f}), "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
