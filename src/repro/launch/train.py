"""End-to-end training driver.

Wires every substrate layer together: config registry → data pipeline →
sharded train step (pjit) → checkpoint manager → fault tolerance
(preemption handler, straggler detector, restart supervision).

Scales from CPU smoke runs to the production mesh unchanged:

  PYTHONPATH=src python -m repro.launch.train --arch taylorshift-lra \
      --steps 200 --batch 8 --seq 256 --d-model 128
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --mesh single …
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, DataLoader
from repro.distributed import ctx
from repro.distributed import sharding as S
from repro.distributed.ft import (PreemptionHandler, StragglerDetector,
                                  run_with_restarts)
from repro.launch.mesh import (make_local_mesh, make_production_mesh,
                               make_seq_mesh)
from repro.launch.steps import (build_train_step, default_opt_config,
                                opt_state_shardings, param_shapes)
from repro.models import backend as B
from repro.models import model as M
from repro.optim import make_optimizer

log = logging.getLogger("repro.train")


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          mesh=None, ckpt_dir: str | None = None, ckpt_every: int = 50,
          log_every: int = 10, seed: int = 0, opt_cfg=None):
    mesh = mesh or make_local_mesh()
    opt_cfg = opt_cfg or default_opt_config(cfg)
    init_opt, _ = make_optimizer(opt_cfg)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    data_cfg = DataConfig(vocab=cfg.vocab, global_batch=global_batch,
                          seq_len=seq_len, seed=seed)

    with mesh, ctx.use(mesh):
        sel = B.select_backend(cfg, N=seq_len, d=cfg.dim_head, site="full",
                               causal=cfg.causal)
        log.info("attention backend: %s mode=%s seq_shards=%d (%s)",
                 sel.name, sel.mode, sel.seq_shards, sel.reason)
        pshapes = param_shapes(cfg)
        pshard = S.param_shardings(pshapes, mesh)
        oshard = opt_state_shardings(cfg, opt_cfg, pshapes, pshard, mesh)
        step_fn = jax.jit(
            build_train_step(cfg, opt_cfg),
            in_shardings=(pshard, oshard, None),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )

        start_step = 0
        if mgr is not None and mgr.latest_step() is not None:
            ostates = jax.eval_shape(init_opt, pshapes)
            start_step, (params, opt_state) = mgr.restore(
                (pshapes, ostates), shardings=(pshard, oshard))
            log.info("restored checkpoint at step %d", start_step)
        else:
            params = jax.device_put(
                M.init_params(cfg, jax.random.PRNGKey(seed)), pshard)
            opt_state = jax.device_put(init_opt(params), oshard)

        loader = DataLoader(data_cfg, start_step=start_step)
        detector = StragglerDetector()
        losses = []
        with PreemptionHandler() as pre:
            try:
                for step, batch in loader:
                    if step >= steps:
                        break
                    t0 = time.time()
                    batch = jax.device_put(batch)
                    params, opt_state, metrics = step_fn(params, opt_state,
                                                         batch)
                    loss = float(metrics["loss"])
                    detector.observe(time.time() - t0)
                    losses.append(loss)
                    if step % log_every == 0:
                        log.info("step %d loss %.4f gnorm %.3f (%.2fs)",
                                 step, loss,
                                 float(metrics["grad_norm"]),
                                 time.time() - t0)
                    if mgr is not None and step and step % ckpt_every == 0:
                        mgr.save(step + 1, (params, opt_state))
                    if pre.preempted:
                        log.warning("preempted — checkpointing at step %d",
                                    step)
                        if mgr is not None:
                            mgr.save(step + 1, (params, opt_state),
                                     blocking=True)
                        break
            finally:
                loader.close()
                if mgr is not None:
                    mgr.wait()
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "stragglers": detector.stragglers}


def main():
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="taylorshift-lra")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (CPU smoke runs)")
    ap.add_argument("--n-layers", type=int, default=0)
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--context-parallel", type=int, default=1,
                    help="size of the `seq` mesh axis: shards the causal "
                         "Taylor scan (and activations) over the sequence "
                         "(docs/sharding.md)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--restartable", action="store_true",
                    help="wrap in the fault-tolerant supervision loop")
    ap.add_argument("--no-kernels", action="store_true",
                    help="train through the pure-jnp reference attention "
                         "instead of the fused Pallas kernels (custom VJP)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.d_model:
        cfg = cfg.with_(d_model=args.d_model)
    if args.n_layers:
        cfg = cfg.with_(n_layers=args.n_layers)
    cfg = cfg.with_(max_seq_len=max(cfg.max_seq_len, args.seq))
    cfg = B.configure_for_training(cfg, use_kernels=not args.no_kernels)

    cp = args.context_parallel
    if cp > 1:
        mesh = (make_seq_mesh(cp) if args.mesh == "local"
                else make_production_mesh(multi_pod=args.mesh == "multi",
                                          seq=cp))
    else:
        mesh = (make_local_mesh() if args.mesh == "local"
                else make_production_mesh(multi_pod=args.mesh == "multi"))

    def go(_state=None):
        return train(cfg, steps=args.steps, global_batch=args.batch,
                     seq_len=args.seq, mesh=mesh,
                     ckpt_dir=args.ckpt_dir or None)

    if args.restartable:
        out = run_with_restarts(lambda: None, go)
    else:
        out = go()
    print(f"final loss: {np.mean(out['losses'][-10:]):.4f} "
          f"(first10 {np.mean(out['losses'][:10]):.4f}), "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
