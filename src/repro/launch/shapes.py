"""Assigned input-shape cells and ShapeDtypeStruct input specs.

Four cells per architecture (40 total):
  train_4k     seq_len=4096    global_batch=256   -> train_step
  prefill_32k  seq_len=32768   global_batch=32    -> prefill_step
  decode_32k   seq_len=32768   global_batch=128   -> serve_step (1 token,
                                                     cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     -> serve_step

``long_500k`` runs for ALL archs here: efficient-TaylorShift gives every
attention architecture a constant-size decode state (docs/design.md §6), and
the SSM/xLSTM archs use their native states.

Per-family interpretation (docs/design.md):
  encdec  — seq_len = encoder frames (train/prefill, mel-stub features) or
            decoder cache length (decode shapes; encoder fixed at 1500).
  vlm     — n_patches stub embeddings + (seq_len - n_patches) text tokens.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

N_MELS = 128  # whisper stub frontend feature dim


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def adapt_config(cfg: ModelConfig, cell: ShapeCell) -> ModelConfig:
    """Shape-dependent config tweaks (learned-pos table size, etc.)."""
    kw = {}
    if cfg.pos_embed == "learned":
        kw["max_seq_len"] = max(cfg.max_seq_len, cell.seq_len + 1)
    if cell.kind != "train":
        kw["remat"] = False
    return cfg.with_(**kw) if kw else cfg


def train_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    B, N = cell.global_batch, cell.seq_len
    if cfg.family == "encdec":
        return {
            "frames": sds((B, N, N_MELS), jnp.bfloat16),
            "tokens": sds((B, cfg.decoder_len), jnp.int32),
            "labels": sds((B, cfg.decoder_len), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        n_text = N - cfg.n_patches
        return {
            "patch_embeds": sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16),
            "tokens": sds((B, n_text), jnp.int32),
            "labels": sds((B, n_text), jnp.int32),
        }
    return {
        "tokens": sds((B, N), jnp.int32),
        "labels": sds((B, N), jnp.int32),
    }


def prefill_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    spec = train_input_specs(cfg, cell)
    spec.pop("labels", None)
    return spec


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    return {"tokens": sds((cell.global_batch, 1), jnp.int32)}


def input_specs(cfg: ModelConfig, cell_name: str) -> dict:
    cell = SHAPE_CELLS[cell_name]
    cfg = adapt_config(cfg, cell)
    if cell.kind == "train":
        return train_input_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_input_specs(cfg, cell)
    return decode_input_specs(cfg, cell)
