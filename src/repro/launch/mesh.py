"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
JAX import; tests and benches see the real single device.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False, seq: int = 1,
                         pipe: int = 1):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    ``seq > 1`` carves a sequence-parallel (context-parallel) axis out of
    the data axis: long-context cells trade data parallelism for
    sharding the token axis, so the causal Taylor scan (and the
    activations) split over ``seq`` (distributed/seqscan.py,
    docs/sharding.md). ``seq == 1`` keeps the historical 2-/3-axis mesh
    so existing sweeps and their result files stay comparable.

    ``pipe > 1`` carves a pipeline axis out of the data axis as well and
    switches to the composed training layout: a single
    ``(data, pipe, seq)`` mesh (no ``model`` axis — the composed path in
    distributed/composed.py shards parameters with FSDP over ``data``
    instead of tensor parallelism, so all 256/512 chips go to
    batch × stages × context).
    """
    chips = 512 if multi_pod else 256
    if pipe > 1:
        if chips % (pipe * seq):
            raise ValueError(
                f"pipe={pipe} × seq={seq} must divide the {chips}-chip pod")
        return jax.make_mesh((chips // (pipe * seq), pipe, seq),
                             ("data", "pipe", "seq"))
    if seq == 1:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return jax.make_mesh(shape, axes)
    if 16 % seq:
        raise ValueError(f"seq={seq} must divide the 16-way data axis")
    shape = (2, 16 // seq, seq, 16) if multi_pod else (16 // seq, seq, 16)
    axes = (("pod", "data", "seq", "model") if multi_pod
            else ("data", "seq", "model"))
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has — used by tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_seq_mesh(seq: int | None = None):
    """A (data, seq, model) mesh with every local device on the ``seq``
    axis — the layout the multi-device CI job
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and the
    context-parallel benchmarks exercise."""
    n = len(jax.devices())
    seq = seq or n
    if n % seq:
        raise ValueError(f"seq={seq} must divide the device count {n}")
    return jax.make_mesh((n // seq, seq, 1), ("data", "seq", "model"))


def make_composed_mesh(*, data: int | None = None, pipe: int = 1,
                       seq: int = 1):
    """A ``(data, pipe, seq)`` mesh over this host's devices — the
    composed 3D-parallel training layout (distributed/composed.py).
    ``data=None`` soaks up whatever devices remain after pipe × seq."""
    n = len(jax.devices())
    if n % (pipe * seq):
        raise ValueError(
            f"pipe={pipe} × seq={seq} must divide the device count {n}")
    data = data if data is not None else n // (pipe * seq)
    if data * pipe * seq > n:
        raise ValueError(
            f"mesh ({data}, {pipe}, {seq}) needs {data * pipe * seq} "
            f"devices, host has {n}")
    devs = jax.devices()[:data * pipe * seq]
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(data, pipe, seq), ("data", "pipe", "seq"))


def seq_size(mesh) -> int:
    """Size of the sequence-parallel axis (1 when the mesh has none)."""
    return mesh.shape["seq"] if "seq" in mesh.axis_names else 1


def pipe_size(mesh) -> int:
    """Size of the pipeline axis (1 when the mesh has none)."""
    return mesh.shape["pipe"] if "pipe" in mesh.axis_names else 1


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel (batch) axes of a mesh."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, *names) -> int:
    s = 1
    for n in names:
        if n in mesh.axis_names:
            s *= mesh.shape[n]
    return s
