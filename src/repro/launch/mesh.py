"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
JAX import; tests and benches see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has — used by tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel (batch) axes of a mesh."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, *names) -> int:
    s = 1
    for n in names:
        if n in mesh.axis_names:
            s *= mesh.shape[n]
    return s
