"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
JAX import; tests and benches see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, seq: int = 1):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    ``seq > 1`` carves a sequence-parallel (context-parallel) axis out of
    the data axis: long-context cells trade data parallelism for
    sharding the token axis, so the causal Taylor scan (and the
    activations) split over ``seq`` (distributed/seqscan.py,
    docs/sharding.md). ``seq == 1`` keeps the historical 2-/3-axis mesh
    so existing sweeps and their result files stay comparable.
    """
    if seq == 1:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return jax.make_mesh(shape, axes)
    if 16 % seq:
        raise ValueError(f"seq={seq} must divide the 16-way data axis")
    shape = (2, 16 // seq, seq, 16) if multi_pod else (16 // seq, seq, 16)
    axes = (("pod", "data", "seq", "model") if multi_pod
            else ("data", "seq", "model"))
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has — used by tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_seq_mesh(seq: int | None = None):
    """A (data, seq, model) mesh with every local device on the ``seq``
    axis — the layout the multi-device CI job
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and the
    context-parallel benchmarks exercise."""
    n = len(jax.devices())
    seq = seq or n
    if n % seq:
        raise ValueError(f"seq={seq} must divide the device count {n}")
    return jax.make_mesh((n // seq, seq, 1), ("data", "seq", "model"))


def seq_size(mesh) -> int:
    """Size of the sequence-parallel axis (1 when the mesh has none)."""
    return mesh.shape["seq"] if "seq" in mesh.axis_names else 1


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel (batch) axes of a mesh."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, *names) -> int:
    s = 1
    for n in names:
        if n in mesh.axis_names:
            s *= mesh.shape[n]
    return s
