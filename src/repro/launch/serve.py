"""Serving driver: batched autoregressive decode with TaylorShift state.

Demonstrates the paper-derived serving win: the per-layer decode cache is
a constant-size Taylor state, so context length never grows memory. The
driver prefills via the chunked-causal form (teacher-forced loop here for
simplicity at smoke scale), then decodes token-by-token.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --d-model 128 --n-layers 2 --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M


def generate(cfg, params, prompts: jnp.ndarray, *, gen_tokens: int,
             cache_kind: str = "taylor", temperature: float = 0.0,
             rng=None):
    """prompts: (B, P) int32. Returns (B, P+gen_tokens)."""
    B, P = prompts.shape
    cache = M.init_decode_state(cfg, B, cache_len=P + gen_tokens + 1,
                                cache_kind=cache_kind, dtype=jnp.float32)
    step = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c))

    # prefill (token-by-token teacher forcing; production would use the
    # chunked prefill kernel + state handoff, see core/taylor.py)
    logits = None
    for t in range(P):
        logits, cache = step({"tokens": prompts[:, t:t+1]}, cache)

    toks = [prompts]
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    cur = None
    for i in range(gen_tokens):
        if temperature > 0:
            rng, sub = jax.random.split(rng)
            cur = jax.random.categorical(sub, logits[:, -1] / temperature)
        else:
            cur = jnp.argmax(logits[:, -1], axis=-1)
        cur = cur[:, None].astype(jnp.int32)
        toks.append(cur)
        logits, cache = step({"tokens": cur}, cache)
    return jnp.concatenate(toks, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache", default="taylor", choices=["taylor", "kv"])
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_(
        d_model=args.d_model, n_layers=args.n_layers)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(cfg, params, prompts, gen_tokens=args.gen,
                   cache_kind=args.cache)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s) cache={args.cache}")
    print("sample:", out[0, -args.gen:].tolist())


if __name__ == "__main__":
    main()
