"""Serving CLI — thin front end over the continuous-batching engine.

Runs a mixed-arrival workload: requests with different prompt lengths
are submitted on a staggered schedule, share decode batches mid-flight,
and every prompt is absorbed through chunked prefill (state handoff via
``causal_taylorshift(initial_state=...)``) — no token-by-token prefill
loop remains in the serving path. With ``--check`` (default) each
request is re-run alone through the naive single-sequence baseline and
the tokens must match exactly at temperature 0 — including under
``--speculate K`` (greedy speculative decoding is exact; see
src/repro/spec/ and docs/serving.md).

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --d-model 128 --n-layers 2 --requests 4 --prompt-len 32 --gen 16 \
      --speculate 4 --drafter self

Observability (src/repro/obs/, docs/observability.md): ``--trace PATH``
writes a Chrome-trace/Perfetto JSON of every engine-step phase
(admission, prefix-cache lookup, prefill chunks, decode/draft/verify/
rollback, first dispatches tagged ``compile=true``); ``--metrics-file
PATH`` writes the Prometheus exposition (TTFT/ITL histograms,
prefix-cache and speculation counters) at exit and ``--metrics-port N``
serves it live on ``http://localhost:N/metrics``; ``--decision-log
PATH`` writes every ``select_backend`` record as JSONL — replaying
exactly how the engine's ServePlan and each trace-time attention site
were chosen. All of it observational: streams are bit-identical with
every flag on or off.

Fleet mode: ``--replica NAME`` names this process — it threads into
``EngineConfig.replica_id``, the ONE identity obs snapshots,
``ft.Membership`` and the router agree on — and ``--metrics-snapshot
PATH`` writes the mergeable ``repro.obs/v1`` snapshot at exit. Run N
replicas, then::

    python -m repro.obs --request req0 r0_trace.json r1_trace.json
    python -m repro.obs --merge-snapshots r0.snap r1.snap --prom fleet.prom
    python -m repro.obs.slo --check --snapshot r0.snap --snapshot r1.snap

Router mode (``--router``, serve/router.py): the same workload runs
against ``--replicas N`` in-process engine replicas behind the
prefix-aware router, with live migration on preemption
(``--migrate-on-preempt``, default on; ``--preempt-step K`` force-
preempts the busiest replica at fleet step K — the CI chaos check).
``--metrics-snapshot`` then writes the *merged* fleet snapshot (every
replica + the router's ``router_*``/``ft_*`` families), and ``--check``
still validates every stream against the naive baseline — migration
included, because migrated streams are bit-identical::

    python -m repro.launch.serve --router --replicas 2 --preempt-step 6 \
        --requests 4 --prefix-cache -1
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import SpecConfig, get_config
from repro.models import model as M
from repro.obs import decisions as OD
from repro.obs.trace import tracer
from repro.serve import Engine, EngineConfig, Request
from repro.tune import table as TT


def serve_metrics_http(engine: Engine, port: int):
    """Serve ``engine.render_metrics()`` on a daemon thread (Prometheus
    scrape target). Returns the server (``.shutdown()`` to stop)."""
    import threading
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            body = engine.render_metrics().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):    # no per-scrape stderr chatter
            pass

    srv = ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv


def naive_generate(cfg, params, prompts: jnp.ndarray, *, gen_tokens: int,
                   cache_kind: str = "taylor", temperature: float = 0.0,
                   rng=None):
    """Token-by-token baseline (prefill AND decode through decode_step).

    Kept as the correctness oracle and the benchmark strawman; the
    engine's chunked prefill replaces this in the serving path.
    prompts: (B, P) int32. Returns (B, P + gen_tokens).
    """
    B, P = prompts.shape
    cache = M.init_decode_state(cfg, B, cache_len=P + gen_tokens + 1,
                                cache_kind=cache_kind, dtype=jnp.float32)
    step = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c))

    logits = None
    for t in range(P):
        logits, cache = step({"tokens": prompts[:, t:t+1]}, cache)

    toks = [prompts]
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    for i in range(gen_tokens):
        if temperature > 0:
            rng, sub = jax.random.split(rng)
            cur = jax.random.categorical(sub, logits[:, -1] / temperature)
        else:
            cur = jnp.argmax(logits[:, -1], axis=-1)
        cur = cur[:, None].astype(jnp.int32)
        toks.append(cur)
        logits, cache = step({"tokens": cur}, cache)
    return jnp.concatenate(toks, axis=1)


def mixed_arrival_workload(cfg, n_requests: int, prompt_len: int, gen: int,
                           seed: int = 1, *, top_k: int = 0,
                           top_p: float = 1.0, shared_frac: float = 0.0):
    """Requests with staggered arrival steps and varied prompt lengths.

    ``shared_frac > 0`` makes every prompt open with the same
    ``shared_frac · prompt_len`` token prefix (a shared system prompt)
    followed by a per-request tail — the workload the prefix cache
    (``--prefix-cache``) exists for.
    """
    reqs, arrivals = [], []
    shared_len = int(prompt_len * shared_frac)
    shared = jax.random.randint(jax.random.PRNGKey(seed - 1),
                                (shared_len,), 0, cfg.vocab)
    for i in range(n_requests):
        plen = max(4, prompt_len - 5 * i)
        # tail of 0 is fine when a shared prefix exists (the repeated-
        # prompt limit at FRAC=1.0); prompts never exceed prompt_len
        tail_len = max(plen - shared_len, 0 if shared_len else plen)
        prompt = jax.random.randint(jax.random.PRNGKey(seed + i),
                                    (tail_len,), 0, cfg.vocab)
        toks = [*(int(t) for t in shared), *(int(t) for t in prompt)]
        reqs.append(Request(request_id=f"req{i}", prompt=toks,
                            max_new_tokens=gen, top_k=top_k, top_p=top_p))
        # ~half the requests arrive mid-flight, while earlier ones decode
        arrivals.append(0 if i < (n_requests + 1) // 2 else 2 * i)
    return reqs, arrivals


def run_workload(engine: Engine, reqs, arrivals):
    """Drive the engine with an arrival schedule keyed on step index."""
    pending = sorted(zip(arrivals, reqs), key=lambda p: p[0])
    while pending or not engine.idle:
        while pending and pending[0][0] <= engine.step_idx:
            engine.submit(pending.pop(0)[1])
        engine.step()
    return {r.request_id: engine.results[r.request_id] for r in reqs}


def run_router_workload(router, reqs, arrivals, *, preempt_step: int = 0):
    """Drive the fleet with the same arrival schedule, keyed on fleet
    steps. ``preempt_step > 0`` force-preempts the busiest replica once
    at that step — decoding streams migrate mid-flight (or replay,
    without ``migrate_on_preempt``) and, because migration is
    bit-identical, the caller's ``--check`` still holds."""
    pending = sorted(zip(arrivals, reqs), key=lambda p: p[0])
    step = 0
    while pending or not router.idle:
        while pending and pending[0][0] <= step:
            router.submit(pending.pop(0)[1])
        router.step()
        step += 1
        if step == preempt_step and len(router.replicas) > 1:
            victim = max(router.replicas,
                         key=lambda r: len(router.replicas[r].sequences))
            moved = router.preempt(victim)
            print(f"preempted {victim} at step {step}: "
                  f"{len(moved['migrated'])} migrated, "
                  f"{len(moved['resubmitted'])} resubmitted")
    return {r.request_id: router.results[r.request_id] for r in reqs}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--token-budget", type=int, default=64)
    ap.add_argument("--cache", default="taylor",
                    choices=["taylor", "kv", "auto"],
                    help="decode-cache layout; 'auto' picks via the paper's "
                         "N1 memory crossover (select_serve_plan)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k sampling cut (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="per-request nucleus sampling mass (1.0 = off)")
    ap.add_argument("--shared-prefix", type=float, default=0.0,
                    metavar="FRAC", help="give every request a common "
                    "prompt prefix of this fraction of --prompt-len "
                    "(pair with --prefix-cache)")
    ap.add_argument("--prefix-cache", type=float, default=0.0, metavar="MB",
                    help="shared-prefix state cache byte budget in MB "
                         "(0 = off, <0 = unbounded); repeated prompt "
                         "prefixes resume from cached chunked-prefill "
                         "state (serve/prefix_cache.py)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decoding with draft length <= K "
                         "(0 = one token per step)")
    ap.add_argument("--drafter", default="ngram", choices=["ngram", "self"],
                    help="draft source: prompt-lookup n-grams or the "
                         "model's own first --draft-layers blocks")
    ap.add_argument("--draft-layers", type=int, default=1,
                    help="self-drafter: number of leading blocks reused")
    ap.add_argument("--no-check", dest="check", action="store_false",
                    help="skip the per-request naive-baseline comparison")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace JSON of every engine-step "
                         "phase (open in chrome://tracing or Perfetto)")
    ap.add_argument("--annotate-steps", action="store_true",
                    help="with --trace: also enter jax.profiler "
                         "StepTraceAnnotation per engine step (correlates "
                         "a simultaneous device profile)")
    ap.add_argument("--metrics-file", default=None, metavar="PATH",
                    help="write the Prometheus text exposition at exit")
    ap.add_argument("--metrics-snapshot", default=None, metavar="PATH",
                    help="write the mergeable repro.obs/v1 metrics "
                         "snapshot at exit (fleet aggregation / SLO "
                         "input: python -m repro.obs / repro.obs.slo)")
    ap.add_argument("--replica", default=None, metavar="NAME",
                    help="name this replica (EngineConfig.replica_id): "
                         "tags the trace's process track, the snapshot's "
                         "gauges and the fleet membership")
    ap.add_argument("--router", action="store_true",
                    help="serve through the prefix-aware router over "
                         "--replicas in-process engine replicas "
                         "(serve/router.py)")
    ap.add_argument("--replicas", type=int, default=2, metavar="N",
                    help="router mode: number of engine replicas")
    ap.add_argument("--no-migrate-on-preempt", dest="migrate_on_preempt",
                    action="store_false",
                    help="router mode: replay preempted streams from "
                         "scratch instead of live-migrating them")
    ap.add_argument("--preempt-step", type=int, default=0, metavar="K",
                    help="router mode: force-preempt the busiest replica "
                         "at fleet step K (0 = never) — exercises live "
                         "migration under --check")
    ap.add_argument("--metrics-port", type=int, default=0, metavar="PORT",
                    help="serve the exposition live on "
                         "http://localhost:PORT/metrics (0 = off)")
    ap.add_argument("--decision-log", default=None, metavar="PATH",
                    help="write every select_backend decision as JSONL")
    ap.add_argument("--tuning-table", default=None, metavar="PATH",
                    help="install a repro.tune calibration table: "
                         "select_backend uses its measured N0/N1 instead "
                         "of the analytic crossovers, and the Pallas "
                         "kernels pick its swept block shapes")
    ap.add_argument("--autotune", action="store_true",
                    help="run a quick calibration sweep on this backend "
                         "before serving and install the result (pair "
                         "with --tuning-table to also persist it)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_(
        d_model=args.d_model, n_layers=args.n_layers)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # calibration comes up FIRST: kernel block shapes resolve through
    # the installed table at trace time, and the engine's ServePlan
    # consults the measured crossovers when it picks the cache layout
    if args.autotune:
        from repro.tune.calibrate import calibrate
        table = calibrate([cfg.head_dim], quick=True, verbose=True)
        if args.tuning_table:
            table.save(args.tuning_table)
            print(f"calibration table -> {args.tuning_table}")
        TT.install(table)
    elif args.tuning_table:
        TT.install(TT.TuningTable.load(args.tuning_table))
        print(f"installed tuning table {args.tuning_table} "
              f"({len(TT.active().entries)} entries)")

    # observability switches come up BEFORE the engine exists so the
    # ServePlan's select_backend calls land in the decision log and the
    # first-dispatch (compile=true) spans land in the trace
    if args.replica:
        tracer.set_process_name(args.replica)
    if args.trace:
        tracer.enable(annotate_steps=args.annotate_steps)
    if args.decision_log:
        OD.log.enable()

    def econf(replica_id):
        return EngineConfig(
            n_slots=args.slots, prefill_chunk=args.prefill_chunk,
            token_budget=args.token_budget, cache_kind=args.cache,
            max_seq_len=args.prompt_len + args.gen + 1,
            temperature=args.temperature,
            prefix_cache_mb=args.prefix_cache,
            speculate_k=args.speculate,
            spec=SpecConfig(drafter=args.drafter,
                            draft_layers=args.draft_layers),
            replica_id=replica_id)

    router = None
    if args.router:
        from repro.serve.router import Router
        engines = [Engine(cfg, params, econf(f"r{i}"))
                   for i in range(max(args.replicas, 1))]
        router = Router(engines,
                        migrate_on_preempt=args.migrate_on_preempt)
        engine, plan = engines[0], engines[0].plan
    else:
        engine = Engine(cfg, params, econf(args.replica))
        plan = engine.plan
    print(f"serve plan: cache={plan.cache_kind} "
          f"prefill={plan.prefill.name} decode={plan.decode.name}"
          + (f" verify={plan.verify.name}" if plan.verify else "")
          + f" ({plan.reason})")
    metrics_srv = (serve_metrics_http(engine, args.metrics_port)
                   if args.metrics_port else None)
    reqs, arrivals = mixed_arrival_workload(
        cfg, args.requests, args.prompt_len, args.gen,
        top_k=args.top_k, top_p=args.top_p, shared_frac=args.shared_prefix)
    if router is not None:
        results = run_router_workload(router, reqs, arrivals,
                                      preempt_step=args.preempt_step)
        routed = {rid: int(c.value) for rid, c in
                  [(r, router._requests_c.labels(replica=r))
                   for r in sorted({*router.replicas,
                                    *(o for o in router._owner.values())})]}
        print(json.dumps({
            "replicas": sorted(router.replicas),
            "routed": routed,
            "migrations": int(router._migrations_c.value),
            "resubmissions": int(router._resub_c.value),
            "wire_bytes": int(router._wire_c.value),
            "epoch": router.membership.epoch}, indent=2))
    else:
        results = run_workload(engine, reqs, arrivals)
        summary = engine.stats.summary()
        print(json.dumps(summary, indent=2))
        shared = max((m.active_decoding for m in engine.stats.steps),
                     default=0)
        print(f"max sequences sharing a decode batch: {shared}")

    if args.trace:
        tracer.write(args.trace)
        tracer.disable()
        print(f"trace: {len(tracer.export()['traceEvents'])} events "
              f"-> {args.trace}")
    if args.metrics_file:
        from repro.obs import aggregate as OA
        body = (OA.render_snapshot(router.fleet_snapshot())
                if router is not None else engine.render_metrics())
        with open(args.metrics_file, "w") as f:
            f.write(body)
        print(f"metrics exposition -> {args.metrics_file}")
    if args.metrics_snapshot:
        from repro.obs import aggregate as OA
        snap = (router.fleet_snapshot() if router is not None
                else engine.snapshot_metrics())
        OA.save_snapshot(snap, args.metrics_snapshot)
        print(f"metrics snapshot -> {args.metrics_snapshot}")
    if args.decision_log:
        OD.log.write_jsonl(args.decision_log)
        OD.log.disable()
        print(f"decision log: {len(OD.log.records)} records "
              f"-> {args.decision_log}")
    if metrics_srv is not None:
        metrics_srv.shutdown()

    if args.check and args.temperature == 0.0:
        ok = True
        for r in reqs:
            prompts = jnp.asarray([r.prompt], jnp.int32)
            ref = naive_generate(cfg, params, prompts,
                                 gen_tokens=r.max_new_tokens,
                                 cache_kind=plan.cache_kind)
            ref_toks = [int(t) for t in ref[0, len(r.prompt):]]
            got = results[r.request_id].out_tokens
            match = got == ref_toks
            ok &= match
            print(f"{r.request_id}: P={len(r.prompt)} "
                  f"{'MATCH' if match else f'MISMATCH {got} != {ref_toks}'}")
        if not ok:
            raise SystemExit("engine output differs from naive baseline")
        print("all requests match the naive per-request baseline exactly")


if __name__ == "__main__":
    main()
