import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (results/dryrun/<arch>__<shape>__<mesh>.json):
  * memory_analysis  — proves the cell fits per-device HBM
  * cost_analysis    — per-device FLOPs / bytes for §Roofline
  * collective stats — parsed from compiled HLO (wire-byte model)
  * roofline terms   — compute / memory / collective seconds + dominant

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --force
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.distributed import ctx
from repro.distributed import hlo_analysis as H
from repro.distributed import hlo_cost as HC
from repro.obs import decisions as OD
from repro.launch import shapes as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.models import backend as B
from repro.models import model as M

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def model_flops_for_cell(cfg, cell_name: str) -> float:
    """MODEL_FLOPS: 6·N_active·tokens (train) / 2·N_active·tokens (fwd)."""
    cell = SH.SHAPE_CELLS[cell_name]
    n_active = M.count_params_analytic(cfg, active_only=True)
    if cfg.family == "encdec":
        tokens = cell.global_batch * (
            cfg.decoder_len if cell.kind == "train" else 1)
        if cell.kind != "decode":
            tokens += cell.global_batch * cell.seq_len  # encoder frames
    else:
        tokens = cell.global_batch * (1 if cell.kind == "decode"
                                      else cell.seq_len)
    mult = 6 if cell.kind == "train" else 2
    return float(mult * n_active * tokens)


def run_cell(arch: str, shape: str, mesh_kind: str, *, force: bool = False,
             out_dir: str = RESULTS_DIR, cache_kind: str = "taylor",
             variant: str = "", config_edit=None,
             sp_carry: bool = True, microbatches: int = 1) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh_kind}" + (f"__{variant}" if variant else "")
    path = os.path.join(out_dir, f"{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    t0 = time.time()
    record = {"arch": arch, "shape": shape, "mesh": mesh_kind,
              "variant": variant, "status": "error"}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        cfg = get_config(arch)
        if config_edit is not None:
            cfg = config_edit(cfg)
        # capture every select_backend call the cell makes while it is
        # built and lowered (obs/decisions.py): the audit of which
        # implementation the traced program *actually* contains, vs the
        # offline B.report below
        with mesh, ctx.use(mesh, sp_carry=sp_carry), \
                OD.log.capture() as decision_records:
            jitted, args, cfg_used = build_cell(cfg, shape, mesh,
                                                cache_kind=cache_kind,
                                                microbatches=microbatches)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):    # older jax: one dict per device
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        # Loop-aware cost model (XLA's cost_analysis counts scan bodies
        # once; ours multiplies by known_trip_count — see hlo_cost.py).
        lc = HC.analyze(hlo)
        coll = H.CollectiveStats(
            counts=lc["coll_counts"], buffer_bytes=lc["coll_buffer_bytes"],
            wire_bytes_per_device=lc["coll_wire_bytes"])
        terms = H.roofline_terms(
            {"flops": lc["flops"], "bytes accessed": lc["bytes"],
             "bytes_out": lc["bytes_out"]}, coll)
        terms["xla_cost_analysis_flops_scan_once"] = float(
            cost.get("flops", 0.0))
        n_dev = mesh.size
        mf = model_flops_for_cell(cfg_used, shape)
        hlo_flops_global = terms["flops_per_device"] * n_dev
        record.update({
            "status": "ok",
            "n_devices": n_dev,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
                "peak_bytes_estimate": (
                    getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)),
            },
            "collectives": coll.as_dict(),
            "roofline": terms,
            "model_flops_global": mf,
            "hlo_flops_global": hlo_flops_global,
            "model_to_hlo_flops": (mf / hlo_flops_global
                                   if hlo_flops_global else 0.0),
            "params_total": M.count_params_analytic(cfg_used),
            "params_active": M.count_params_analytic(cfg_used,
                                                     active_only=True),
            # which attention implementation this cell actually measured
            # (select_backend per site) + the paper's analytic crossovers
            "attention": B.report(
                cfg_used, N=SH.SHAPE_CELLS[shape].seq_len,
                d=cfg_used.dim_head, mesh=mesh),
            # the trace-time selection audit (obs/decisions.py): every
            # select_backend call made while the cell was built/lowered
            "backend_decisions": decision_records,
        })
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["wall_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    choices=["all", *SH.SHAPE_CELLS.keys()])
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--cache-kind", default="taylor",
                    choices=["taylor", "kv"])
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SH.SHAPE_CELLS) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, force=args.force,
                               cache_kind=args.cache_kind)
                ok = rec["status"] == "ok"
                n_ok += ok
                n_fail += (not ok)
                msg = (f"[{'ok' if ok else 'FAIL'}] {arch} {shape} "
                       f"{mesh_kind} ({rec.get('wall_s', '?')}s)")
                if ok:
                    r = rec["roofline"]
                    msg += (f" dominant={r['dominant']}"
                            f" t_c={r['t_compute_s']:.3e}"
                            f" t_m={r['t_memory_s']:.3e}"
                            f" t_x={r['t_collective_s']:.3e}")
                    att = rec.get("attention", {})
                    if att:
                        full = att.get("full", {})
                        msg += (f" attn={full.get('backend')}"
                                f"/{full.get('mode') or '-'}"
                                f" N0={att.get('crossover_n0', 0):.0f}"
                                f" N1={att.get('crossover_n1', 0):.0f}")
                else:
                    msg += " " + rec.get("error", "")[:160]
                print(msg, flush=True)
    print(f"dryrun complete: {n_ok} ok, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
