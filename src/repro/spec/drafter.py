"""Draft-token proposers.

Two concrete drafters behind one interface:

  * ``NgramDrafter`` — prompt-lookup decoding: match the longest suffix
    of the sequence's context (prompt + generated tokens) against
    earlier context and propose the historical continuation. Pure
    host-side list work, zero model FLOPs — the right drafter for
    extractive/repetitive workloads where the continuation already
    appeared verbatim.
  * ``SelfDrafter`` — shallow self-draft: the model's own first j
    blocks plus the final norm and unembedding, run as a truncated
    model over its *own* slot pool (same ``StatePool`` machinery,
    constant-size Taylor state). Drafting k tokens costs k+1 shallow
    decode steps at j/L of a full step each; the drafter pool mirrors
    the main pool's snapshot → verify → rollback/re-absorb discipline
    so its state tracks exactly the accepted context.

The engine drives drafters through four hooks: ``on_ready`` (prompt
absorbed, slot live), ``draft`` (propose k tokens per decoding slot),
``commit`` (verification outcome — roll shallow state back to the
accepted prefix), ``release`` (slot freed). Stateless drafters ignore
everything but ``draft``.
"""

from __future__ import annotations

from typing import Sequence as Seq

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SpecConfig


class Drafter:
    """Interface. ``draft`` maps decoding sequences to k proposed tokens
    each; the other hooks let stateful drafters track slot lifecycle.

    Contract (docs/design.md §4.4, invariant 5): drafts are *hints* —
    they may be arbitrarily wrong and only cost acceptance, never
    correctness, because verification scores every draft against the
    real model. ``draft`` must return exactly k tokens per decoding
    sequence (fixed verify shapes). A stateful drafter's internal state
    must equal "drafter run over the accepted context" after each
    ``commit`` — the engine calls ``on_ready`` once per sequence (prompt
    absorbed), ``draft``/``commit`` per speculative step, and
    ``release(slot)`` on finish; any state keyed by slot index must be
    dropped there, since slots are recycled."""

    def draft(self, seqs, k: int) -> dict[int, list[int]]:
        """slot -> k draft tokens, for every sequence in ``seqs``."""
        raise NotImplementedError

    def on_ready(self, seq) -> None:
        """Called once per sequence when its prompt has been absorbed
        into the main pool (slot allocated, decode about to start)."""

    def commit(self, seq, accepted: int, block: Seq[int]) -> None:
        """Verification outcome for one sequence: of the k drafts in
        ``block[1:]`` (``block[0]`` is the previous real token), the
        first ``accepted`` were accepted. Stateful drafters roll back
        to the accepted prefix ``block[:accepted + 1]`` here."""

    def release(self, slot: int) -> None:
        """Slot freed (sequence finished)."""


# ---------------------------------------------------------------------------
# Prompt-lookup (n-gram) drafting
# ---------------------------------------------------------------------------

def ngram_propose(context: Seq[int], k: int, *, ngram_max: int = 3,
                  ngram_min: int = 1) -> list[int]:
    """Propose k tokens by suffix lookup in the sequence's own context.

    Longest-match-first: for n from ``ngram_max`` down to ``ngram_min``,
    find the most recent earlier occurrence of the length-n context
    suffix and return the k tokens that followed it (padded by repeating
    the last proposal when the match sits near the end). Falls back to
    repeating the last context token — drafting must always return
    exactly k tokens so the verify block keeps a fixed shape; a bad
    draft merely costs acceptance.
    """
    ctx = [int(t) for t in context]
    n_ctx = len(ctx)
    if n_ctx == 0:
        raise ValueError("cannot draft from empty context")
    for n in range(min(ngram_max, n_ctx - 1), ngram_min - 1, -1):
        suffix = ctx[n_ctx - n:]
        for start in range(n_ctx - n - 1, -1, -1):
            if ctx[start:start + n] == suffix:
                cont = ctx[start + n:start + n + k]
                if cont:
                    while len(cont) < k:
                        cont.append(cont[-1])
                    return cont
    return [ctx[-1]] * k


class NgramDrafter(Drafter):
    """Prompt-lookup drafter (zero model FLOPs).

    Keeps a per-slot incremental index — for each n-gram length, a map
    from gram to the position just after its most recent occurrence
    strictly before the context end — extended only over tokens emitted
    since the last draft. Each draft is then O(ngram_max) dict lookups
    instead of :func:`ngram_propose`'s O(ngram_max · context) rescan
    (which would come to dominate step latency on long contexts —
    exactly the workload this subsystem exists for). Proposals are
    identical to ``ngram_propose``; tests/test_spec.py pins the
    equivalence. Context only ever grows per slot (emission is final),
    so the index never needs invalidation — only a reset on slot reuse
    (``release``).
    """

    def __init__(self, spec: SpecConfig | None = None):
        self.spec = spec or SpecConfig()
        self._index: dict[int, dict] = {}   # slot -> {"maps", "upto"}

    def draft(self, seqs, k: int) -> dict[int, list[int]]:
        return {s.slot: self._propose(s.slot,
                                      [*s.request.prompt, *s.out_tokens], k)
                for s in seqs}

    def _propose(self, slot: int, ctx: list[int], k: int) -> list[int]:
        lengths = range(self.spec.ngram_min, self.spec.ngram_max + 1)
        st = self._index.setdefault(
            slot, {"maps": {n: {} for n in lengths}, "upto": 0})
        maps, n_ctx = st["maps"], len(ctx)
        # index grams ending strictly before the context end, so every
        # hit has a nonempty continuation (matches ngram_propose's
        # "most recent *earlier* occurrence" search)
        for end in range(st["upto"] + 1, n_ctx):
            for n in maps:
                if end >= n:
                    maps[n][tuple(ctx[end - n:end])] = end
        st["upto"] = max(st["upto"], n_ctx - 1)
        for n in range(self.spec.ngram_max, self.spec.ngram_min - 1, -1):
            if n >= n_ctx:
                continue
            end = maps[n].get(tuple(ctx[n_ctx - n:]))
            if end is not None:
                cont = ctx[end:end + k]
                while len(cont) < k:
                    cont.append(cont[-1])
                return cont
        return [ctx[-1]] * k

    def release(self, slot: int) -> None:
        self._index.pop(slot, None)


# ---------------------------------------------------------------------------
# Shallow-layer self-draft
# ---------------------------------------------------------------------------

def truncate_params(params, cfg: ModelConfig, j: int):
    """Parameter view of the model's first ``j`` blocks.

    The layer stack is stored as per-pattern-position group stacks
    (leaves (n_groups, ...)) plus an unrolled remainder; the first j
    layers are ``j // P`` full pattern groups and the first ``j % P``
    kinds of the next group. Embedding, final norm, unembedding (and any
    shared-attention block) are shared with the full model — slices are
    views, so no weight is copied. Pair with ``cfg.with_(n_layers=j)``.
    """
    pattern, n_groups, _ = _pattern_layout(cfg)
    P = len(pattern)
    if not 1 <= j <= cfg.n_layers:
        raise ValueError(f"draft_layers={j} outside [1, {cfg.n_layers}]")
    jg, jr = j // P, j % P
    out = {key: val for key, val in params.items()
           if key not in ("groups", "rem")}
    out["groups"] = ([jax.tree.map(lambda a: a[:jg], g)
                      for g in params["groups"]] if jg else [])
    rem_p = []
    for i in range(jr):
        if jg < n_groups:
            rem_p.append(jax.tree.map(lambda a: a[jg], params["groups"][i]))
        else:
            rem_p.append(params["rem"][i])
    out["rem"] = rem_p
    return out


def _pattern_layout(cfg, n_layers=None):
    from repro.models.model import _pattern_layout as pl
    return pl(cfg, n_layers)


class SelfDrafter(Drafter):
    """Draft with the model's own first ``spec.draft_layers`` blocks.

    Keeps a second ``StatePool`` (truncated model, same slot indices as
    the main pool) whose state always equals "shallow model run over the
    accepted context". One draft phase runs k+1 shallow decode steps:
    feed the last real token, chain k argmax drafts, and absorb the
    final draft too — so on full acceptance the shallow state needs no
    fix-up at all, and on rejection it restores its pre-draft snapshot
    and re-absorbs the accepted prefix through the truncated model's
    ``verify_chunk``, exactly mirroring the main pool's rollback.
    """

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int,
                 cache_len: int, cache_kind: str = "taylor",
                 spec: SpecConfig | None = None, prefill_chunk: int = 128):
        from repro.models import model as M
        from repro.serve.pool import StatePool

        self.spec = spec or SpecConfig()
        j = self.spec.draft_layers
        self.cfg = cfg.with_(n_layers=j)
        self.params = truncate_params(params, cfg, j)
        self.pool = StatePool(self.cfg, n_slots, cache_len=cache_len,
                              cache_kind=cache_kind)
        self.prefill_chunk = prefill_chunk
        self._snap = None       # whole-pool reference from draft() time
        dcfg = self.cfg

        def draft_loop(p, tokens0, cache, k):
            """k argmax draft steps + one absorb-only step, fused into a
            single dispatch (k+1 sequential shallow decode_steps would
            otherwise dominate the drafter's cost at small scale)."""
            def body(carry, _):
                toks, cache = carry
                logits, cache = M.decode_step(p, dcfg, {"tokens": toks},
                                              cache)
                nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
                return (nxt, cache), nxt[:, 0]

            (last, cache), drafts = jax.lax.scan(body, (tokens0, cache),
                                                 None, length=k)
            _, cache = M.decode_step(p, dcfg, {"tokens": last}, cache)
            return drafts.T, cache          # (B, k)

        pf = jax.jit(lambda p, t, c: M.prefill_chunk(p, dcfg,
                                                     {"tokens": t}, c))
        dl = jax.jit(draft_loop, static_argnums=3)
        rb = jax.jit(lambda p, cache, snap, slot, toks: M.verify_rollback(
            p, dcfg, cache, snap, slot, {"tokens": toks}))
        self._prefill_fn = lambda t, c: pf(self.params, t, c)
        self._draft_fn = lambda t, c, k: dl(self.params, t, c, k)
        self._rollback_fn = lambda c, snap, slot, t: rb(self.params, c,
                                                        snap, slot, t)

    # -- lifecycle ----------------------------------------------------------

    def on_ready(self, seq) -> None:
        """Absorb the sequence's accepted context through the shallow
        model into this slot — chunked exactly like the main prefill
        (same power-of-two chunk plan, so the shallow prefill shapes are
        a subset of shapes the engine already compiles for the full
        model). The context is prompt + all-but-the-last emitted token:
        normally ``out_tokens`` is empty here (the engine calls on_ready
        before the first emit), but a migrated stream (engine.
        import_request) arrives mid-generation, and the drafter contract
        — state equals "shallow model over the accepted context", where
        the last emitted token is the *next* decode feed — must hold for
        it too."""
        from repro.serve.prefill import plan_chunks

        cache = self.pool.new_sequence_cache()
        ctx = [*seq.request.prompt, *seq.out_tokens[:-1]]
        lo = 0
        for c in plan_chunks(len(ctx), self.prefill_chunk):
            toks = jnp.asarray([ctx[lo:lo + c]], jnp.int32)
            _, cache = self._prefill_fn(toks, cache)
            lo += c
        self.pool.scatter(cache, seq.slot)

    def draft(self, seqs, k: int) -> dict[int, list[int]]:
        """One fused shallow decode loop for every decoding slot.

        k+1 steps in a single dispatch: feed the last real token, chain
        k argmax drafts, absorb the final draft. The pre-draft pool
        pytree is kept as the zero-copy snapshot ``commit`` rolls back
        to after verification.
        """
        self._snap = self.pool.cache    # O(1): arrays are immutable
        tokens = np.zeros((self.pool.n_slots, 1), np.int32)
        for s in seqs:
            tokens[s.slot, 0] = s.next_token
        drafts, self.pool.cache = self._draft_fn(jnp.asarray(tokens),
                                                 self.pool.cache, k)
        drafts = np.asarray(drafts)
        return {s.slot: [int(t) for t in drafts[s.slot]] for s in seqs}

    def commit(self, seq, accepted: int, block: Seq[int]) -> None:
        k = len(block) - 1
        if accepted >= k:       # shallow state already == accepted context
            return
        if self._snap is None:  # draft() was never called this step
            return
        toks = jnp.asarray([list(block[:accepted + 1])], jnp.int32)
        self.pool.cache = self._rollback_fn(self.pool.cache, self._snap,
                                            seq.slot, toks)

    def release(self, slot: int) -> None:
        self.pool.reset(slot)


def make_drafter(cfg: ModelConfig, params, *, n_slots: int, cache_len: int,
                 cache_kind: str, spec: SpecConfig,
                 prefill_chunk: int = 128) -> Drafter:
    """Build the drafter named by ``spec.drafter``."""
    if spec.drafter == "ngram":
        return NgramDrafter(spec)
    if spec.drafter == "self":
        return SelfDrafter(cfg, params, n_slots=n_slots, cache_len=cache_len,
                           cache_kind=cache_kind, spec=spec,
                           prefill_chunk=prefill_chunk)
    raise ValueError(f"unknown drafter {spec.drafter!r} "
                     "(expected 'ngram' or 'self')")
