"""Greedy draft verification.

The model side — scoring k+1 tokens per slot in one batched call from
each slot's current Taylor state — is ``models.model.verify_chunk``;
this module holds the pure acceptance logic the engine applies to its
output. Greedy verification is exact: the emitted stream is, token for
token, what one-token-per-step greedy decoding would have produced,
because each position's argmax is conditioned only on the (verified)
prefix before it.
"""

from __future__ import annotations

from typing import Sequence


def accepted_prefix(draft: Sequence[int], greedy: Sequence[int]
                    ) -> tuple[int, list[int]]:
    """Longest accepted draft prefix + the bonus token.

    ``draft``: the k drafted tokens fed at positions 1..k of the verify
    block. ``greedy``: the k+1 argmax tokens of the verify logits —
    ``greedy[i]`` is the model's next token after absorbing block
    positions 0..i.

    Position i's draft is accepted iff ``draft[i] == greedy[i]`` (the
    model would have produced exactly that token), and acceptance stops
    at the first mismatch — later positions were conditioned on a
    rejected token, so their logits are void. The model's own token at
    the first mismatch (or ``greedy[k]`` on full acceptance) is free —
    the "bonus" token every speculative step emits even at zero
    acceptance.

    Returns ``(a, emitted)``: a ∈ [0, k] accepted drafts, and the
    a + 1 tokens to emit (accepted drafts + bonus).
    """
    k = len(draft)
    assert len(greedy) == k + 1, (len(greedy), k)
    a = 0
    while a < k and int(draft[a]) == int(greedy[a]):
        a += 1
    return a, [*(int(t) for t in draft[:a]), int(greedy[a])]
