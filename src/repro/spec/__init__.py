"""Speculative generation on snapshot/rollback Taylor state.

TaylorShift's "and Back" reformulation gives every decoding sequence a
*constant-size* recurrent state (per-head O(d²) tensors, not a growing
KV cache) — the linear-attention-as-RNN view of Katharopoulos et al.
(2020). That makes state snapshot/rollback nearly free: a slot's entire
decode state copies in O(layers · d²) regardless of context length, so
speculative decoding needs no paged-cache surgery. The subsystem is

  * ``drafter``    — the ``Drafter`` interface plus two concrete
    drafters: ``NgramDrafter`` (prompt-lookup: match the context suffix
    against earlier context, propose the historical continuation) and
    ``SelfDrafter`` (shallow self-draft: the model's own first j blocks
    + final norm + unembed run as a truncated model with its own slot
    pool, mirroring the main pool's snapshot/rollback discipline);
  * ``verify``     — greedy acceptance: score the k drafted tokens in
    ONE ``models.model.verify_chunk`` call from each slot's current
    state (`select_backend(site="verify")` routes it onto one
    sequential ``causal_taylorshift`` chunk), then accept the longest
    prefix whose argmax chain matches the draft, plus one bonus token;
  * ``controller`` — acceptance-rate-adaptive draft length (EWMA over
    observed acceptance, doubling/halving within [1, speculate_k]).

Engine integration lives in ``serve/engine.py`` (``EngineConfig.
speculate_k``); rollback primitives in ``serve/pool.py``
(``StatePool.snapshot/restore``). See docs/serving.md.
"""

from repro.spec.controller import DraftController
from repro.spec.drafter import (Drafter, NgramDrafter, SelfDrafter,
                                make_drafter, truncate_params)
from repro.spec.verify import accepted_prefix

__all__ = [
    "Drafter", "NgramDrafter", "SelfDrafter", "make_drafter",
    "truncate_params", "accepted_prefix", "DraftController",
]
