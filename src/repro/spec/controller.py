"""Acceptance-rate-adaptive draft length.

Speculation's cost model: a verify step always pays for k+1 scored
tokens (plus the drafter's own work) but only emits a+1, so the win
lives or dies on the acceptance rate a/k. The controller tracks an EWMA
of observed acceptance and moves the draft length by doubling/halving
within [1, cap] — powers of two keep the set of verify-call shapes (and
therefore jit retraces) logarithmic in the cap rather than linear.

One controller per engine (not per sequence): the verify call batches
every decoding slot at one shared k, so a per-sequence length would
force ragged blocks. Greedy output is k-invariant (verification only
ever accepts tokens greedy decoding would emit), so adaptation changes
throughput, never the stream.
"""

from __future__ import annotations

from repro.configs.base import SpecConfig
from repro.obs.metrics import MetricsRegistry


class DraftController:
    """Tracks acceptance and serves the current draft length ``k``.

    With a ``registry`` (the engine passes its ``EngineStats``
    registry), observations publish into ``spec_*`` metrics —
    ``spec_drafted_tokens_total`` / ``spec_accepted_tokens_total``
    counters and ``spec_draft_k`` / ``spec_acceptance_ewma`` gauges —
    instead of living only in controller attributes; the attributes
    remain as views for existing callers. Observational only: the
    resize policy reads its own EWMA, never the registry.
    """

    def __init__(self, cap: int, spec: SpecConfig | None = None,
                 registry: MetricsRegistry | None = None):
        if cap < 1:
            raise ValueError("draft-length cap must be >= 1")
        self.cap = cap
        self.spec = spec or SpecConfig()
        self.k = cap
        # neutral prior between the two thresholds: no resize until
        # real observations push the EWMA out of the dead band
        self.rate = 0.5 * (self.spec.grow_above + self.spec.shrink_below)
        self._drafted_c = self._accepted_c = None
        self._k_g = self._rate_g = None
        if registry is not None:
            self._drafted_c = registry.counter(
                "spec_drafted_tokens_total",
                "drafted tokens observed by the controller")
            self._accepted_c = registry.counter(
                "spec_accepted_tokens_total",
                "drafted tokens accepted by greedy verification")
            self._k_g = registry.gauge(
                "spec_draft_k", "current adaptive draft length")
            self._k_g.set(self.k)
            self._rate_g = registry.gauge(
                "spec_acceptance_ewma",
                "acceptance EWMA driving draft-length resizing")
            self._rate_g.set(self.rate)
        self.observed_drafted = 0
        self.observed_accepted = 0

    def update(self, accepted: int, drafted: int) -> None:
        """Fold one sequence's verify outcome (a of k accepted) in."""
        if drafted <= 0:
            return
        if not 0 <= accepted <= drafted:
            raise ValueError(f"accepted={accepted} of drafted={drafted}")
        self.observed_drafted += drafted
        self.observed_accepted += accepted
        w = self.spec.ewma
        self.rate = (1.0 - w) * self.rate + w * (accepted / drafted)
        if self._drafted_c is not None:
            self._drafted_c.inc(drafted)
            self._accepted_c.inc(accepted)
            self._rate_g.set(self.rate)
        if not self.spec.adaptive:
            return
        if self.rate > self.spec.grow_above:
            self.k = min(self.k * 2, self.cap)
        elif self.rate < self.spec.shrink_below:
            self.k = max(self.k // 2, 1)
        if self._k_g is not None:
            self._k_g.set(self.k)

    @property
    def acceptance_rate(self) -> float:
        """Lifetime mean acceptance (not the EWMA the resizing uses)."""
        if not self.observed_drafted:
            return 0.0
        return self.observed_accepted / self.observed_drafted
