"""Mergeable metrics snapshots: N replica registries -> one fleet view.

The wire format the future prefix-aware router consumes for per-replica
load (ROADMAP "fleet-scale serving"), and the offline half of the
observability contract: the hot path only ever *writes* plain
counters/gauges/histograms (obs/metrics.py); everything here — JSON
serialization, cross-process merge, fleet Prometheus rendering — reads
a frozen snapshot after the fact (docs/design.md §4.6).

Schema (versioned like ``repro.tune/v1`` — foreign versions are
refused, never coerced)::

    {"schema": "repro.obs/v1", "replica": "r0" | null,
     "created_unix": 1e9, "metrics": {
        name: {"kind": "counter"|"gauge"|"histogram", "help": str,
               "children": [{"labels": {...}, ...payload}]}}}

counter payload   ``value``
gauge payload     ``value``, ``ts`` (unix seconds of last write | null)
histogram payload ``buckets``, ``bucket_counts`` (len+1, +Inf last),
                  ``sum``, ``count``, ``min``/``max`` (null when empty),
                  ``samples`` (raw observations while exact, else [])

Merge semantics (:func:`merge_snapshots` — associative by
construction, so folding replica snapshots in any grouping yields the
same fleet document):

  * counters with equal (name, labels) **sum** — the fleet total equals
    the sum of the per-replica totals;
  * histograms with equal (name, labels) merge via
    ``Histogram.merge``: bucket counts/sum/count/min/max exactly,
    samples kept only while every input is exact and the union fits
    under ``MAX_SAMPLES``;
  * gauges are **tagged, not summed**: each leaf snapshot's gauge
    children gain a ``replica`` label (exactly once — merged snapshots
    carry ``replica: null`` and never re-tag), so per-replica load
    survives aggregation; two gauges that still collide take the
    freshest ``ts`` (ties: larger value).
"""

from __future__ import annotations

import json
import math
import time

from repro.obs.metrics import Histogram, MetricsRegistry, _Family

SCHEMA = "repro.obs/v1"

_KINDS = ("counter", "gauge", "histogram")


def _none_if_inf(v: float):
    return None if not math.isfinite(v) else v


def snapshot(*registries: MetricsRegistry, replica: str | None = None
             ) -> dict:
    """Serialize registries into one ``repro.obs/v1`` document.

    Metric names must be disjoint across ``registries`` (same contract
    as ``render_all`` — the engine's stats + prefix-cache pair).
    ``replica`` names this process; the merge step turns it into the
    ``replica`` gauge label.
    """
    metrics: dict = {}
    for reg in registries:
        for name, kind, help, children in reg.families():
            if name in metrics:
                raise ValueError(
                    f"duplicate metric {name!r} across registries")
            out_children = []
            for c in children:
                child: dict = {"labels": dict(c.labels)}
                if kind == "histogram":
                    child.update(
                        buckets=list(c.buckets),
                        bucket_counts=list(c.bucket_counts),
                        sum=c.sum, count=c.count,
                        min=_none_if_inf(c._min),
                        max=_none_if_inf(c._max),
                        samples=(list(c.samples) if c.exact else []))
                elif kind == "gauge":
                    child.update(value=c.value, ts=c.ts)
                else:
                    child.update(value=c.value)
                out_children.append(child)
            metrics[name] = {"kind": kind, "help": help,
                             "children": out_children}
    return {"schema": SCHEMA, "replica": replica,
            "created_unix": time.time(), "metrics": metrics}


def validate_snapshot(doc) -> list[str]:
    """Problems in a snapshot document ([] = valid); foreign schema
    versions are a single fatal problem, mirroring ``repro.tune``."""
    if not isinstance(doc, dict):
        return ["snapshot is not an object"]
    if doc.get("schema") != SCHEMA:
        return [f"schema {doc.get('schema')!r} is not {SCHEMA!r} — refusing"]
    problems: list[str] = []
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict):
        return problems + ["metrics missing or not an object"]
    for name, fam in metrics.items():
        kind = fam.get("kind")
        if kind not in _KINDS:
            problems.append(f"{name}: unknown kind {kind!r}")
            continue
        children = fam.get("children")
        if not isinstance(children, list):
            problems.append(f"{name}: children missing")
            continue
        for i, c in enumerate(children):
            where = f"{name}.children[{i}]"
            if not isinstance(c.get("labels"), dict):
                problems.append(f"{where}: labels missing")
            if kind == "histogram":
                bc, bk = c.get("bucket_counts"), c.get("buckets")
                if not isinstance(bk, list) or not isinstance(bc, list) \
                        or len(bc) != len(bk) + 1:
                    problems.append(f"{where}: bucket_counts/buckets "
                                    "length mismatch")
                    continue
                if sum(bc) != c.get("count"):
                    problems.append(f"{where}: bucket_counts sum "
                                    f"{sum(bc)} != count {c.get('count')}")
                samples = c.get("samples", [])
                if samples and len(samples) != c.get("count"):
                    problems.append(f"{where}: partial samples "
                                    f"({len(samples)} of {c.get('count')})"
                                    " — snapshots are exact or empty")
                if not isinstance(c.get("sum"), (int, float)) \
                        or not math.isfinite(c["sum"]):
                    problems.append(f"{where}: non-finite sum")
            else:
                v = c.get("value")
                if not isinstance(v, (int, float)) or (
                        isinstance(v, float) and not math.isfinite(v)):
                    problems.append(f"{where}: bad value {v!r}")
    return problems


def check_snapshot(doc) -> None:
    problems = validate_snapshot(doc)
    if problems:
        raise ValueError("invalid metrics snapshot:\n  "
                         + "\n  ".join(problems))


def _child_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _hist_from_child(c: dict) -> Histogram:
    h = Histogram(labels=dict(c["labels"]), buckets=tuple(c["buckets"]))
    h.bucket_counts = list(c["bucket_counts"])
    h.sum = float(c["sum"])
    h.count = int(c["count"])
    h._min = c["min"] if c.get("min") is not None else math.inf
    h._max = c["max"] if c.get("max") is not None else -math.inf
    h.samples = list(c.get("samples") or [])
    return h


def _hist_to_child(h: Histogram) -> dict:
    return {"labels": dict(h.labels), "buckets": list(h.buckets),
            "bucket_counts": list(h.bucket_counts), "sum": h.sum,
            "count": h.count, "min": _none_if_inf(h._min),
            "max": _none_if_inf(h._max),
            "samples": list(h.samples) if h.exact else []}


def merge_snapshots(*docs: dict) -> dict:
    """Fold N snapshots into one fleet snapshot (see module docstring
    for the per-kind rules). Refuses foreign schema versions."""
    for doc in docs:
        check_snapshot(doc)
    metrics: dict = {}
    for doc in docs:
        replica = doc.get("replica")
        for name, fam in doc["metrics"].items():
            out = metrics.setdefault(
                name, {"kind": fam["kind"], "help": fam.get("help", ""),
                       "children": {}})
            if out["kind"] != fam["kind"]:
                raise ValueError(
                    f"metric {name!r}: kind {fam['kind']!r} from replica "
                    f"{replica!r} conflicts with {out['kind']!r}")
            out["help"] = out["help"] or fam.get("help", "")
            for c in fam["children"]:
                labels = dict(c["labels"])
                # leaf snapshots (replica set) tag their gauges exactly
                # once; merged snapshots carry replica=None and pass
                # children through untouched — that single-tagging rule
                # is what makes the fold associative
                if (fam["kind"] == "gauge" and replica is not None
                        and "replica" not in labels):
                    labels["replica"] = replica
                key = _child_key(labels)
                prev = out["children"].get(key)
                if prev is None:
                    merged = dict(c, labels=labels)
                elif fam["kind"] == "counter":
                    merged = {"labels": labels,
                              "value": prev["value"] + c["value"]}
                elif fam["kind"] == "gauge":
                    # freshest write wins; ties break on value so the
                    # choice is order-independent
                    a = (prev.get("ts") or 0.0, prev["value"])
                    b = (c.get("ts") or 0.0, c["value"])
                    merged = dict((c if b >= a else prev), labels=labels)
                else:
                    merged = _hist_to_child(
                        _hist_from_child(prev).merge(_hist_from_child(c)))
                out["children"][key] = merged
    return {"schema": SCHEMA, "replica": None, "created_unix": time.time(),
            "metrics": {
                name: {"kind": fam["kind"], "help": fam["help"],
                       "children": [fam["children"][k]
                                    for k in sorted(fam["children"])]}
                for name, fam in metrics.items()}}


def registry_from_snapshot(doc: dict) -> MetricsRegistry:
    """Rebuild a live ``MetricsRegistry`` from a snapshot — the uniform
    object the SLO evaluator and ``render_snapshot`` both consume, so a
    fleet snapshot answers quantile/value queries exactly like the
    registry it came from."""
    check_snapshot(doc)
    reg = MetricsRegistry()
    for name, fam in doc["metrics"].items():
        kind, help, children = fam["kind"], fam.get("help", ""), \
            fam["children"]
        labelnames = tuple(sorted(
            {k for c in children for k in c["labels"]}))
        if kind == "histogram":
            buckets = tuple(children[0]["buckets"]) if children \
                else None
            m = reg.histogram(name, help, labelnames=labelnames,
                              **({"buckets": buckets} if buckets else {}))
        elif kind == "gauge":
            m = reg.gauge(name, help, labelnames=labelnames)
        else:
            m = reg.counter(name, help, labelnames=labelnames)
        for c in children:
            child = m.labels(**c["labels"]) if isinstance(m, _Family) \
                else m
            if kind == "histogram":
                h = _hist_from_child(c)
                child.bucket_counts = h.bucket_counts
                child.sum, child.count = h.sum, h.count
                child._min, child._max = h._min, h._max
                child.samples = h.samples
            elif kind == "gauge":
                child.value = c["value"]
                child.ts = c.get("ts")
            else:
                child.value = c["value"]
    return reg


def render_snapshot(doc: dict) -> str:
    """One Prometheus text exposition for a (possibly fleet-merged)
    snapshot."""
    return registry_from_snapshot(doc).render()


def save_snapshot(doc: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(doc, f)


def load_snapshot(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    check_snapshot(doc)
    return doc
