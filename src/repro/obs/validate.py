"""Validators for the observability outputs CI gates on.

Pure functions over already-loaded data — each returns a list of
problem strings (empty = valid) so callers can aggregate; the
``check_*`` wrappers raise ``ValueError`` with every problem listed.
``scripts/validate_obs.py`` is the CLI front end (the CI ``obs`` job);
``tests/test_obs.py`` exercises them directly.
"""

from __future__ import annotations

import math
import re

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?P<labels>\{[^}]*\})?\s+"
    r"(?P<value>[-+]?(?:\d+\.?\d*(?:[eE][-+]?\d+)?|\d*\.\d+(?:[eE][-+]?\d+)?"
    r"|Inf|NaN))$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


# ---------------------------------------------------------------------------
# Chrome trace
# ---------------------------------------------------------------------------

def validate_chrome_trace(doc, *, require_spans: tuple[str, ...] = ()
                          ) -> list[str]:
    """Well-formedness of a Chrome trace event document.

    Checks: ``traceEvents`` is a non-empty list; every event has name /
    ph / ts / pid / tid; per-thread timestamps are monotone
    non-decreasing; B/E events match up as a proper stack per thread
    (same names, nothing left open); ``require_spans`` all appear.
    """
    problems: list[str] = []
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    stacks: dict[tuple, list[str]] = {}
    last_ts: dict[tuple, float] = {}
    names: set[str] = set()
    for i, ev in enumerate(events):
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in ev:
                problems.append(f"event {i}: missing {k!r}")
        if problems:
            continue
        tid = (ev["pid"], ev["tid"])
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or math.isnan(ts):
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        if ts < last_ts.get(tid, float("-inf")):
            problems.append(
                f"event {i} ({ev['name']}): ts {ts} goes backwards on "
                f"thread {tid}")
        last_ts[tid] = ts
        ph = ev["ph"]
        stack = stacks.setdefault(tid, [])
        if ph == "B":
            stack.append(ev["name"])
            names.add(ev["name"])
        elif ph == "E":
            if not stack:
                problems.append(
                    f"event {i}: E {ev['name']!r} with no open span")
            elif stack[-1] != ev["name"]:
                problems.append(
                    f"event {i}: E {ev['name']!r} closes open span "
                    f"{stack[-1]!r} (bad nesting)")
                stack.pop()
            else:
                stack.pop()
        elif ph in ("i", "I", "X", "M", "C"):
            names.add(ev["name"])
        else:
            problems.append(f"event {i}: unknown ph {ph!r}")
    for tid, stack in stacks.items():
        if stack:
            problems.append(f"thread {tid}: unclosed spans {stack}")
    for want in require_spans:
        if want not in names:
            problems.append(f"required span {want!r} never appears")
    return problems


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

def validate_prometheus_text(text: str, *,
                             require_metrics: tuple[str, ...] = ()
                             ) -> list[str]:
    """Parse the text exposition format.

    Checks: every non-comment line is a valid sample; every sampled
    family has a ``# TYPE``; histogram ``_bucket`` series are cumulative
    (monotone in ``le``) and agree with ``_count``; no NaNs;
    ``require_metrics`` families all present.
    """
    problems: list[str] = []
    types: dict[str, str] = {}
    samples: list[tuple[str, dict, float]] = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                problems.append(f"line {lineno}: bad TYPE line")
            else:
                types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line.strip())
        if not m:
            problems.append(f"line {lineno}: unparseable sample {line!r}")
            continue
        labels = dict(_LABEL_RE.findall(m.group("labels") or ""))
        value = float(m.group("value"))
        if math.isnan(value):
            problems.append(f"line {lineno}: NaN value")
        samples.append((m.group("name"), labels, value))

    def family(name: str) -> str:
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                return name[:-len(suffix)]
        return name

    seen_families = {family(n) for n, _, _ in samples}
    for fam in seen_families:
        if fam not in types:
            problems.append(f"family {fam!r} sampled without a # TYPE line")
    for want in require_metrics:
        if want not in seen_families:
            problems.append(f"required metric {want!r} missing")

    # histogram bucket monotonicity + count agreement, per label set
    hists: dict[tuple, list[tuple[float, float]]] = {}
    counts: dict[tuple, float] = {}
    for name, labels, value in samples:
        fam = family(name)
        if types.get(fam) != "histogram":
            continue
        base = tuple(sorted((k, v) for k, v in labels.items()
                            if k != "le"))
        if name == fam + "_bucket":
            le = labels.get("le")
            edge = float("inf") if le == "+Inf" else float(le)
            hists.setdefault((fam, base), []).append((edge, value))
        elif name == fam + "_count":
            counts[(fam, base)] = value
    for key, series in hists.items():
        series.sort(key=lambda p: p[0])
        vals = [v for _, v in series]
        if any(b > a for a, b in zip(vals[1:], vals)):
            problems.append(f"histogram {key[0]}: non-cumulative buckets")
        if series and series[-1][0] != float("inf"):
            problems.append(f"histogram {key[0]}: missing +Inf bucket")
        if key in counts and series and series[-1][1] != counts[key]:
            problems.append(
                f"histogram {key[0]}: +Inf bucket {series[-1][1]} != "
                f"_count {counts[key]}")
    return problems


# ---------------------------------------------------------------------------
# Decision log
# ---------------------------------------------------------------------------

DECISION_KEYS = ("seq", "site", "N", "d", "H", "cache_kind", "backend",
                 "mode", "n0", "n1", "reason", "provenance")


def validate_decision_log(records: list[dict]) -> list[str]:
    """Every record carries the audit schema; seq is dense from 0."""
    problems = []
    if not records:
        return ["decision log is empty"]
    for i, r in enumerate(records):
        missing = [k for k in DECISION_KEYS if k not in r]
        if missing:
            problems.append(f"record {i}: missing keys {missing}")
        if r.get("seq") != i:
            problems.append(f"record {i}: seq {r.get('seq')} not dense")
    return problems


def _raise(problems: list[str], what: str) -> None:
    if problems:
        raise ValueError(f"invalid {what}:\n  " + "\n  ".join(problems))


def check_chrome_trace(doc, **kw) -> None:
    _raise(validate_chrome_trace(doc, **kw), "Chrome trace")


def check_prometheus_text(text: str, **kw) -> None:
    _raise(validate_prometheus_text(text, **kw), "Prometheus exposition")


def check_decision_log(records: list[dict]) -> None:
    _raise(validate_decision_log(records), "decision log")
