"""Span tracer: nested spans -> Chrome-trace/Perfetto JSON.

One global ``tracer`` (module-level, like ``decisions.log``) that the
serving engine wraps around every step phase — admission, prefix-cache
lookup, prefill chunk, decode batch, draft, verify, rollback — plus
whatever benchmarks and launchers want to mark. Spans nest naturally
(``with tracer.span("decode_batch"):``) and export as Chrome trace
event JSON (``ph: "B"/"E"`` pairs) that chrome://tracing and
https://ui.perfetto.dev open directly.

Zero overhead when off: ``span()`` checks one flag and returns a shared
no-op context manager — no event append, no timestamp read, no
allocation. The engine is instrumented unconditionally; only an enabled
tracer pays.

Two serving-specific extras:

* **jit-compile detection** — pass ``compile_key=<hashable>`` and the
  first span with that key is tagged ``args["compile"] = true``: the
  engine keys on dispatch shapes (chunk length, draft k, slot count),
  so warmup spans that trigger XLA compilation are visually separable
  from steady-state dispatches of the same phase.
* **``jax.profiler`` correlation** — with ``annotate_steps=True`` any
  span carrying ``step_num`` also enters a
  ``jax.profiler.StepTraceAnnotation``, so a simultaneously captured
  device profile aligns its steps with this tracer's engine steps.

Thread-safe: events append under a lock (cross-session replicas or a
metrics HTTP thread may export mid-run), and ``tid`` records the
emitting thread so nesting is judged per thread.

Fleet extensions (PR 9): ``pid`` is stamped at *emit* time with the
real ``os.getpid()`` (a fork after import can no longer alias two
replicas onto one track), ``set_process_name()`` names the replica, and
``export()`` prepends Chrome ``ph:"M"`` ``process_name``/``thread_name``
metadata events so N merged traces render as per-replica tracks.
``perf_counter`` timestamps are per-process, so ``export()`` also
records ``otherData.epoch_offset_us`` — the offset that maps this
process's span timestamps onto the shared unix epoch — and
:func:`merge_traces` applies it, making cross-process timelines
comparable. :func:`iter_spans` / :func:`request_spans` reconstruct
completed spans (B/E pairs + instants) and filter them by the
``request``/``requests`` span args the engine attaches; the
``python -m repro.obs --request <id>`` CLI builds on them.
"""

from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared do-nothing span for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key, value):
        return self


_NULL = _NullSpan()


class Span:
    """One live span; ``set(k, v)`` attaches args visible in the trace."""

    __slots__ = ("_tracer", "name", "_args", "_ann")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None,
                 annotation=None):
        self._tracer = tracer
        self.name = name
        self._args = args
        self._ann = annotation

    def set(self, key, value) -> "Span":
        if self._args is None:
            self._args = {}
        self._args[key] = value
        return self

    def __enter__(self):
        if self._ann is not None:
            self._ann.__enter__()
        self._tracer._emit("B", self.name, self._args)
        # args dict is shared with the B event: set() after enter still
        # lands in the exported trace
        return self

    def __exit__(self, *exc):
        # error goes on the E event: the B event's args dict was already
        # emitted (and may be None when the span opened bare)
        self._tracer._emit(
            "E", self.name,
            {"error": exc[0].__name__} if exc[0] is not None else None)
        if self._ann is not None:
            self._ann.__exit__(*exc)
        return False


class Tracer:
    """Thread-safe span/event buffer with Chrome-trace export."""

    def __init__(self, *, annotate_steps: bool = False):
        self.enabled = False
        self.annotate_steps = annotate_steps
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._seen_keys: set = set()
        self.process_name: str | None = None

    # -- control ------------------------------------------------------------

    def set_process_name(self, name: str) -> None:
        """Name this process's track in merged traces (replica/host id);
        lands in the ``process_name`` metadata event and ``otherData``."""
        self.process_name = name

    def enable(self, *, annotate_steps: bool | None = None) -> None:
        if annotate_steps is not None:
            self.annotate_steps = annotate_steps
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self.events = []
            self._seen_keys = set()

    # -- spans --------------------------------------------------------------

    def span(self, name: str, *, compile_key=None, step_num=None,
             **args) -> Span | _NullSpan:
        """Context manager for one nested span.

        ``compile_key``: hashable dispatch-shape key; the first span per
        key is tagged ``compile=true`` (jit warmup detection).
        ``step_num``: with ``annotate_steps``, correlates this span with
        a ``jax.profiler`` step annotation of the same number.
        """
        if not self.enabled:
            return _NULL
        a = dict(args) if args else None
        if compile_key is not None:
            first = compile_key not in self._seen_keys
            if first:
                self._seen_keys.add(compile_key)
                a = a or {}
                a["compile"] = True
        ann = None
        if step_num is not None:
            if a is None:
                a = {}
            a["step_num"] = step_num
            if self.annotate_steps:
                try:
                    from jax.profiler import StepTraceAnnotation
                    ann = StepTraceAnnotation(name, step_num=step_num)
                except Exception:  # profiler unavailable: spans still work
                    ann = None
        return Span(self, name, a, ann)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event."""
        if self.enabled:
            self._emit("i", name, dict(args) if args else None)

    def _emit(self, ph: str, name: str, args: dict | None) -> None:
        # pid is read at emit time, not cached at construction: the
        # module-level tracer predates any fork, and a cached pid would
        # alias every worker of a forked replica onto one trace track
        ev = {"name": name, "ph": ph, "ts": time.perf_counter() * 1e6,
              "pid": os.getpid(), "tid": threading.get_ident()}
        if args is not None:
            ev["args"] = args
        if ph == "i":
            ev["s"] = "t"     # instant scope: thread
        # lock-free append: CPython list.append is atomic, and clear()
        # swaps the whole list rather than mutating it — the lock only
        # serializes clear()/export() against each other
        self.events.append(ev)

    # -- export -------------------------------------------------------------

    def export(self) -> dict:
        """Chrome trace event format object (deep-copied args).

        Prepends ``process_name``/``thread_name`` metadata events for
        every (pid, tid) that emitted, and records
        ``otherData.epoch_offset_us`` — ``time.time() -
        time.perf_counter()`` in µs — so :func:`merge_traces` can place
        this process's per-process timestamps on the shared unix epoch.
        """
        with self._lock:
            events = [dict(e, args=dict(e["args"])) if "args" in e
                      else dict(e) for e in self.events]
        pname = self.process_name or f"pid {os.getpid()}"
        main_tid = threading.main_thread().ident
        meta: list[dict] = []
        seen_pids: set = set()
        seen_tids: set = set()
        for e in events:
            pid, tid = e["pid"], e["tid"]
            if pid not in seen_pids:
                seen_pids.add(pid)
                meta.append({"name": "process_name", "ph": "M", "ts": 0.0,
                             "pid": pid, "tid": 0,
                             "args": {"name": pname}})
            if (pid, tid) not in seen_tids:
                seen_tids.add((pid, tid))
                meta.append({"name": "thread_name", "ph": "M", "ts": 0.0,
                             "pid": pid, "tid": tid,
                             "args": {"name": ("MainThread"
                                               if tid == main_tid
                                               else f"thread-{tid}")}})
        offset_us = (time.time() - time.perf_counter()) * 1e6
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"process_name": pname,
                              "epoch_offset_us": offset_us}}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)


#: The process-global tracer every instrumented module shares.
tracer = Tracer()


# ---------------------------------------------------------------------------
# Cross-process merge + span reconstruction (python -m repro.obs)
# ---------------------------------------------------------------------------

def merge_traces(*docs: dict) -> dict:
    """Merge N exported trace documents into one epoch-aligned trace.

    Each document's ``otherData.epoch_offset_us`` shifts its event
    timestamps onto the unix epoch; metadata events come first, the rest
    sort by shifted ts (a constant shift per document, so per-thread
    list order — what the validator checks — is preserved). The merged
    document carries ``epoch_offset_us: 0`` so merging is idempotent
    and associative: merge(merge(a, b), c) == merge(a, b, c).
    """
    meta: list[dict] = []
    seen_meta: set = set()
    events: list[dict] = []
    for doc in docs:
        off = float((doc.get("otherData") or {}).get("epoch_offset_us", 0.0))
        for ev in doc.get("traceEvents", ()):
            if ev.get("ph") == "M":
                key = (ev.get("name"), ev.get("pid"), ev.get("tid"))
                if key not in seen_meta:
                    seen_meta.add(key)
                    meta.append(dict(ev))
            else:
                ev = dict(ev)
                ev["ts"] = ev["ts"] + off
                events.append(ev)
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"epoch_offset_us": 0.0, "merged": len(docs)}}


def process_names(doc: dict) -> dict:
    """pid -> process/replica name from the metadata events."""
    names: dict = {}
    for ev in doc.get("traceEvents", ()):
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            names[ev["pid"]] = (ev.get("args") or {}).get("name")
    return names


def iter_spans(doc: dict):
    """Yield completed spans and instants from a trace document.

    Spans come from matched B/E pairs per (pid, tid) stack — args from
    both ends merged — as ``{"name", "ts", "dur", "pid", "tid",
    "args"}``; instants carry ``dur == 0.0``. Unclosed spans are
    dropped (the validator flags those separately).
    """
    stacks: dict[tuple, list[dict]] = {}
    for ev in doc.get("traceEvents", ()):
        ph = ev.get("ph")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks.setdefault(key, []).append(ev)
        elif ph == "E":
            stack = stacks.get(key)
            if stack and stack[-1]["name"] == ev["name"]:
                b = stack.pop()
                args = dict(b.get("args") or {})
                args.update(ev.get("args") or {})
                yield {"name": b["name"], "ts": b["ts"],
                       "dur": ev["ts"] - b["ts"], "pid": b["pid"],
                       "tid": b["tid"], "args": args}
        elif ph in ("i", "I"):
            yield {"name": ev["name"], "ts": ev["ts"], "dur": 0.0,
                   "pid": ev["pid"], "tid": ev["tid"],
                   "args": dict(ev.get("args") or {})}


def request_spans(doc: dict, request_id: str) -> list[dict]:
    """Spans/instants belonging to one request, chronological.

    A span belongs when its args carry ``request == request_id`` or
    list ``request_id`` in ``requests`` — the two conventions the
    engine uses for per-sequence and batched phases respectively.
    """
    out = []
    for span in iter_spans(doc):
        a = span["args"]
        if (a.get("request") == request_id
                or request_id in (a.get("requests") or ())):
            out.append(span)
    out.sort(key=lambda s: s["ts"])
    return out
