"""Span tracer: nested spans -> Chrome-trace/Perfetto JSON.

One global ``tracer`` (module-level, like ``decisions.log``) that the
serving engine wraps around every step phase — admission, prefix-cache
lookup, prefill chunk, decode batch, draft, verify, rollback — plus
whatever benchmarks and launchers want to mark. Spans nest naturally
(``with tracer.span("decode_batch"):``) and export as Chrome trace
event JSON (``ph: "B"/"E"`` pairs) that chrome://tracing and
https://ui.perfetto.dev open directly.

Zero overhead when off: ``span()`` checks one flag and returns a shared
no-op context manager — no event append, no timestamp read, no
allocation. The engine is instrumented unconditionally; only an enabled
tracer pays.

Two serving-specific extras:

* **jit-compile detection** — pass ``compile_key=<hashable>`` and the
  first span with that key is tagged ``args["compile"] = true``: the
  engine keys on dispatch shapes (chunk length, draft k, slot count),
  so warmup spans that trigger XLA compilation are visually separable
  from steady-state dispatches of the same phase.
* **``jax.profiler`` correlation** — with ``annotate_steps=True`` any
  span carrying ``step_num`` also enters a
  ``jax.profiler.StepTraceAnnotation``, so a simultaneously captured
  device profile aligns its steps with this tracer's engine steps.

Thread-safe: events append under a lock (cross-session replicas or a
metrics HTTP thread may export mid-run), and ``tid`` records the
emitting thread so nesting is judged per thread.
"""

from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared do-nothing span for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key, value):
        return self


_NULL = _NullSpan()


class Span:
    """One live span; ``set(k, v)`` attaches args visible in the trace."""

    __slots__ = ("_tracer", "name", "_args", "_ann")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None,
                 annotation=None):
        self._tracer = tracer
        self.name = name
        self._args = args
        self._ann = annotation

    def set(self, key, value) -> "Span":
        if self._args is None:
            self._args = {}
        self._args[key] = value
        return self

    def __enter__(self):
        if self._ann is not None:
            self._ann.__enter__()
        self._tracer._emit("B", self.name, self._args)
        # args dict is shared with the B event: set() after enter still
        # lands in the exported trace
        return self

    def __exit__(self, *exc):
        # error goes on the E event: the B event's args dict was already
        # emitted (and may be None when the span opened bare)
        self._tracer._emit(
            "E", self.name,
            {"error": exc[0].__name__} if exc[0] is not None else None)
        if self._ann is not None:
            self._ann.__exit__(*exc)
        return False


class Tracer:
    """Thread-safe span/event buffer with Chrome-trace export."""

    def __init__(self, *, annotate_steps: bool = False):
        self.enabled = False
        self.annotate_steps = annotate_steps
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._seen_keys: set = set()
        self._pid = os.getpid()

    # -- control ------------------------------------------------------------

    def enable(self, *, annotate_steps: bool | None = None) -> None:
        if annotate_steps is not None:
            self.annotate_steps = annotate_steps
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self.events = []
            self._seen_keys = set()

    # -- spans --------------------------------------------------------------

    def span(self, name: str, *, compile_key=None, step_num=None,
             **args) -> Span | _NullSpan:
        """Context manager for one nested span.

        ``compile_key``: hashable dispatch-shape key; the first span per
        key is tagged ``compile=true`` (jit warmup detection).
        ``step_num``: with ``annotate_steps``, correlates this span with
        a ``jax.profiler`` step annotation of the same number.
        """
        if not self.enabled:
            return _NULL
        a = dict(args) if args else None
        if compile_key is not None:
            first = compile_key not in self._seen_keys
            if first:
                self._seen_keys.add(compile_key)
                a = a or {}
                a["compile"] = True
        ann = None
        if step_num is not None:
            if a is None:
                a = {}
            a["step_num"] = step_num
            if self.annotate_steps:
                try:
                    from jax.profiler import StepTraceAnnotation
                    ann = StepTraceAnnotation(name, step_num=step_num)
                except Exception:  # profiler unavailable: spans still work
                    ann = None
        return Span(self, name, a, ann)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event."""
        if self.enabled:
            self._emit("i", name, dict(args) if args else None)

    def _emit(self, ph: str, name: str, args: dict | None) -> None:
        ev = {"name": name, "ph": ph, "ts": time.perf_counter() * 1e6,
              "pid": self._pid, "tid": threading.get_ident()}
        if args is not None:
            ev["args"] = args
        if ph == "i":
            ev["s"] = "t"     # instant scope: thread
        # lock-free append: CPython list.append is atomic, and clear()
        # swaps the whole list rather than mutating it — the lock only
        # serializes clear()/export() against each other
        self.events.append(ev)

    # -- export -------------------------------------------------------------

    def export(self) -> dict:
        """Chrome trace event format object (deep-copied args)."""
        with self._lock:
            events = [dict(e, args=dict(e["args"])) if "args" in e
                      else dict(e) for e in self.events]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)


#: The process-global tracer every instrumented module shares.
tracer = Tracer()
