"""Declarative SLO targets evaluated against metrics — never the hot path.

A target is a plain dict (JSON-friendly so ``--config`` files work)::

    {"name": "ttft_p95",  "metric": "engine_ttft_seconds",
     "quantile": 0.95, "max": 2.0}                    # histogram tail
    {"name": "decode_rate", "metric": "engine_decode_tokens_total",
     "per": "engine_step_wall_seconds_sum", "min": 50.0}   # tokens/s
    {"name": "hit_rate", "ratio": ["prefix_cache_hits_total",
     ["prefix_cache_hits_total", "prefix_cache_misses_total"]],
     "min": 0.5}                                      # cache hit rate
    {"name": "bubble", "metric": "train_pipeline_bubble_fraction",
     "max": 0.5}                                      # gauge ceiling

Value resolution, uniformly over a live ``MetricsRegistry`` or a
``repro.obs/v1`` snapshot (rebuilt via
``aggregate.registry_from_snapshot`` — evaluation always reads a frozen
registry, which is what keeps SLO checking off the serving hot path,
docs/design.md §4.6):

  * ``metric`` + ``quantile`` — the histogram quantile (labeled
    children merged first, so fleet snapshots evaluate over the union
    of replicas);
  * ``metric`` alone — counter/gauge value (children summed);
  * ``metric`` + ``per`` — ``metric / per`` (each side a summed
    counter/gauge; histogram ``_sum``/``_count`` suffixes resolve);
  * ``ratio: [num, den]`` — each side a name or list of names, summed.

Bounds: ``min`` (floor) and/or ``max`` (ceiling). A target whose
metrics are absent from the registry is *skipped*, not failed — one
default config covers serving and training artifacts.

Error budgets: for a quantile target with a ``max`` bound, the budget
is the tolerated violating fraction ``1 - quantile``; the report's
``budget_used`` is ``P(obs > max) / (1 - quantile)`` — 1.0 exactly at
the SLO boundary, >1 when blown. Computed from the histogram CDF
(exact below the sample cap, bucket-interpolated past it).

CLI (CI's nonzero-exit gate)::

    python -m repro.obs.slo --check --snapshot serve.snap.json \
        [--config targets.json] [--set ttft_p95.max=0.001]
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from functools import reduce

from repro.obs import aggregate as A
from repro.obs.metrics import Histogram, MetricsRegistry, _Family


def default_targets() -> list[dict]:
    """One config for both artifact families: serving targets (engine_*
    / prefix_cache_*) and training targets (train_*) — whichever family
    a registry lacks is skipped at evaluation time."""
    return [
        {"name": "ttft_p95", "metric": "engine_ttft_seconds",
         "quantile": 0.95, "max": 30.0},
        {"name": "itl_p99", "metric": "engine_itl_seconds",
         "quantile": 0.99, "max": 10.0},
        {"name": "decode_tokens_per_step_wall",
         "metric": "engine_decode_tokens_total",
         "per": "engine_step_wall_seconds_sum", "min": 0.5},
        {"name": "prefix_cache_hit_rate",
         "ratio": ["prefix_cache_hits_total",
                   ["prefix_cache_hits_total",
                    "prefix_cache_misses_total"]],
         "min": 0.0},
        {"name": "pipeline_bubble_fraction",
         "metric": "train_pipeline_bubble_fraction", "max": 0.9},
        {"name": "train_step_p95", "metric": "train_step_seconds",
         "quantile": 0.95, "max": 600.0},
    ]


# ---------------------------------------------------------------------------
# value resolution over a registry
# ---------------------------------------------------------------------------

def _merged_children(reg: MetricsRegistry, name: str):
    m = reg.get(name)
    if m is None:
        return None, None
    kind = m.kind if isinstance(m, _Family) else m._kind
    children = m.children if isinstance(m, _Family) else [m]
    return kind, children


def _scalar(reg: MetricsRegistry, name: str) -> float | None:
    """Summed value of a counter/gauge family; histogram ``_sum`` /
    ``_count`` suffixes resolve to the merged histogram's fields."""
    for suffix, attr in (("_sum", "sum"), ("_count", "count")):
        if name.endswith(suffix):
            kind, children = _merged_children(reg, name[:-len(suffix)])
            if kind == "histogram":
                return float(sum(getattr(c, attr) for c in children))
    kind, children = _merged_children(reg, name)
    if kind is None or kind == "histogram":
        return None
    return float(sum(c.value for c in children))


def _histogram(reg: MetricsRegistry, name: str) -> Histogram | None:
    kind, children = _merged_children(reg, name)
    if kind != "histogram" or not children:
        return None
    return reduce(lambda a, b: a.merge(b), children)


def _sum_names(reg: MetricsRegistry, names) -> float | None:
    names = [names] if isinstance(names, str) else list(names)
    vals = [_scalar(reg, n) for n in names]
    if any(v is None for v in vals):
        return None
    return sum(vals)


def evaluate_target(target: dict, reg: MetricsRegistry) -> dict:
    """One result row: ``{name, value, min, max, ok, skipped,
    budget_used}`` (``value`` None when skipped)."""
    name = target.get("name", "?")
    lo, hi = target.get("min"), target.get("max")
    value = budget_used = None
    if "ratio" in target:
        num, den = target["ratio"]
        n, d = _sum_names(reg, num), _sum_names(reg, den)
        if n is not None and d is not None:
            value = n / d if d else math.nan
    elif "quantile" in target:
        h = _histogram(reg, target["metric"])
        if h is not None and h.count:
            q = float(target["quantile"])
            value = h.quantile(q)
            if hi is not None and 0.0 < q < 1.0:
                violating = 1.0 - h.cdf(hi)
                budget_used = violating / (1.0 - q)
    elif "per" in target:
        n = _sum_names(reg, target["metric"])
        d = _sum_names(reg, target["per"])
        if n is not None and d is not None:
            value = n / d if d else math.nan
    else:
        value = _sum_names(reg, target["metric"])
    if value is None:
        return {"name": name, "value": None, "min": lo, "max": hi,
                "ok": True, "skipped": True, "budget_used": None}
    ok = not math.isnan(value) \
        and (lo is None or value >= lo) \
        and (hi is None or value <= hi)
    return {"name": name, "value": value, "min": lo, "max": hi,
            "ok": ok, "skipped": False, "budget_used": budget_used}


def evaluate(targets: list[dict], source) -> list[dict]:
    """Evaluate targets against a ``MetricsRegistry`` or a
    ``repro.obs/v1`` snapshot dict (the offline surfaces — callers with
    a live engine snapshot it first)."""
    reg = source if isinstance(source, MetricsRegistry) \
        else A.registry_from_snapshot(source)
    return [evaluate_target(t, reg) for t in targets]


def format_report(results: list[dict]) -> str:
    lines = []
    for r in results:
        if r["skipped"]:
            lines.append(f"SKIP {r['name']}: metric absent")
            continue
        bound = " ".join(
            f"{side}={v:g}" for side, v in
            (("min", r["min"]), ("max", r["max"])) if v is not None)
        budget = (f" budget_used={r['budget_used']:.3f}"
                  if r["budget_used"] is not None else "")
        lines.append(f"{'OK  ' if r['ok'] else 'FAIL'} {r['name']}: "
                     f"value={r['value']:.6g} {bound}{budget}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _apply_overrides(targets: list[dict], sets: list[str]) -> None:
    """``--set name.min|max=VALUE`` — how CI deliberately tightens a
    target past the measured value to prove the nonzero exit."""
    by_name = {t.get("name"): t for t in targets}
    for s in sets:
        try:
            key, value = s.split("=", 1)
            tname, field = key.rsplit(".", 1)
        except ValueError:
            raise SystemExit(f"--set wants name.min|max=VALUE, got {s!r}")
        if field not in ("min", "max") or tname not in by_name:
            raise SystemExit(f"--set: unknown target/field {key!r}")
        by_name[tname][field] = float(value)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.slo",
        description="evaluate SLO targets against metrics snapshots")
    ap.add_argument("--snapshot", action="append", default=[],
                    metavar="PATH", required=True,
                    help="repro.obs/v1 snapshot (repeat to merge a fleet)")
    ap.add_argument("--config", metavar="PATH",
                    help="JSON list of targets (default: built-ins)")
    ap.add_argument("--set", action="append", default=[], dest="sets",
                    metavar="NAME.min|max=V", help="override one bound")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any evaluated target fails")
    args = ap.parse_args(argv)

    if args.config:
        with open(args.config) as f:
            targets = json.load(f)
    else:
        targets = default_targets()
    _apply_overrides(targets, args.sets)

    snaps = [A.load_snapshot(p) for p in args.snapshot]
    doc = snaps[0] if len(snaps) == 1 else A.merge_snapshots(*snaps)
    results = evaluate(targets, doc)
    print(format_report(results))
    failed = [r for r in results if not r["ok"]]
    evaluated = [r for r in results if not r["skipped"]]
    print(f"slo: {len(evaluated) - len(failed)}/{len(evaluated)} "
          f"evaluated targets ok, {len(results) - len(evaluated)} skipped")
    if args.check and failed:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
