"""Backend decision/audit log.

Every ``models/backend.py:select_backend`` call resolves an attention
implementation from the paper's N0/N1 cost model — and until now the
evidence (site, shape, crossovers, reason) vanished after the call.
With the log enabled, each selection appends one structured record:

    {"seq": 3, "site": "prefill", "N": 128, "d": 32, "H": 4,
     "causal": true, "cache_kind": "taylor", "backend": "causal-scan",
     "mode": "", "repeat_kv": false, "seq_shards": 1,
     "scan": "sequential", "chunk": 128, "n0": 1187.0, "n1": 542.0,
     "reason": "TaylorState handoff (...)"}

Consumers:

* ``launch/dryrun.py`` captures the selections made while a cell is
  built/lowered and stores them in the cell JSON next to the roofline
  (``backend_decisions``), so a sweep records which implementation it
  *actually* traced, not just the offline ``B.report``;
* ``launch/serve.py --decision-log`` writes the serving engine's
  records as JSONL — replaying exactly how the ``ServePlan`` and every
  trace-time attention site were chosen;
* ``benchmarks/crossover.py --decision-log`` diffs recorded choices
  against the analytic crossovers — the hook the ROADMAP's empirical
  calibration pass consumes (measured N0/N1 overrides will be judged
  against these records).

Off by default and one attribute check when off — ``select_backend``
stays hot-path cheap. ``capture()`` is the scoped way to collect
records without leaking global state.
"""

from __future__ import annotations

import contextlib
import json
import threading


class DecisionLog:
    """Append-only structured log of backend selections."""

    def __init__(self):
        self.enabled = False
        self.records: list[dict] = []
        self._lock = threading.Lock()

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self.records = []

    def record(self, **fields) -> None:
        """Append one record (no-op when disabled — callers may guard on
        ``log.enabled`` themselves to skip building the fields)."""
        if not self.enabled:
            return
        with self._lock:
            self.records.append({"seq": len(self.records), **fields})

    @contextlib.contextmanager
    def capture(self):
        """Collect the records made inside the block.

        Yields the live list; prior enabled-state and records are
        restored on exit, so nested/global logging is unaffected.
        """
        prev_enabled, prev_records = self.enabled, self.records
        self.records = []
        self.enabled = True
        try:
            yield self.records
        finally:
            self.enabled, self.records = prev_enabled, prev_records

    def write_jsonl(self, path: str) -> None:
        with self._lock:
            records = list(self.records)
        with open(path, "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")


def read_jsonl(path: str) -> list[dict]:
    """Load a decision log written by ``write_jsonl``."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


#: The process-global decision log ``select_backend`` publishes into.
log = DecisionLog()
