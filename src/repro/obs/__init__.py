"""Unified observability: tracing, metrics, and decision auditing.

Three pillars (docs/observability.md):

* ``obs.trace``     — nested span tracer, Chrome-trace/Perfetto export,
  jit-compile tagging, optional ``jax.profiler`` step correlation;
  global instance ``obs.tracer``.
* ``obs.metrics``   — counters/gauges/histograms with labels +
  Prometheus text exposition; the engine's ``EngineStats`` is a view
  over a ``MetricsRegistry``.
* ``obs.decisions`` — structured audit log of every
  ``models/backend.py:select_backend`` call; global ``obs.decisions.log``.

Invariant (design.md §4.6): purely observational. All three pillars are
write-only from the serving/dispatch hot paths — nothing reads them
back into scheduling, selection, or sampling — and everything except
the always-on metrics counters is off by default with one-flag-check
overhead.
"""

from repro.obs import decisions, metrics, trace, validate  # noqa: F401
from repro.obs.decisions import DecisionLog
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               render_all)
from repro.obs.trace import Tracer, tracer

__all__ = [
    "decisions", "metrics", "trace", "validate",
    "DecisionLog", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "render_all", "Tracer", "tracer",
]
