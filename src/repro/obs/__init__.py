"""Unified observability: tracing, metrics, decision auditing, and the
fleet layer on top (docs/observability.md).

Process-local pillars:

* ``obs.trace``     — nested span tracer, Chrome-trace/Perfetto export,
  jit-compile tagging, optional ``jax.profiler`` step correlation;
  global instance ``obs.tracer``. Fleet-aware: real ``os.getpid()``
  stamps, process-name metadata events, epoch offsets for
  cross-process merge.
* ``obs.metrics``   — counters/gauges/histograms with labels +
  Prometheus text exposition; the engine's ``EngineStats`` is a view
  over a ``MetricsRegistry``; ``obs.metrics.default_registry`` hosts
  process-lifetime infrastructure metrics (ft heartbeats).
* ``obs.decisions`` — structured audit log of every
  ``models/backend.py:select_backend`` call; global ``obs.decisions.log``.

Fleet layers (offline — they read exported artifacts, never the hot
path):

* ``obs.aggregate`` — versioned ``repro.obs/v1`` metrics snapshots,
  associative cross-replica merge, fleet Prometheus rendering.
* ``obs.slo``       — declarative SLO targets + error budgets over a
  registry or snapshot; ``python -m repro.obs.slo --check`` for CI.
* ``python -m repro.obs`` — trace merge + per-request cross-process
  timelines + snapshot aggregation CLI.

Invariant (design.md §4.6): purely observational. The pillars are
write-only from the serving/dispatch hot paths — nothing reads them
back into scheduling, selection, or sampling — and everything except
the always-on metrics counters is off by default with one-flag-check
overhead. Aggregation and SLO evaluation read metrics *offline* (a
snapshot or exported file), never from the hot path.
"""

from repro.obs import (aggregate, decisions, metrics,  # noqa: F401
                       slo, trace, validate)
from repro.obs.decisions import DecisionLog
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry, render_all)
from repro.obs.trace import Tracer, merge_traces, request_spans, tracer

__all__ = [
    "aggregate", "decisions", "metrics", "slo", "trace", "validate",
    "DecisionLog", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "render_all", "Tracer", "merge_traces",
    "request_spans", "tracer",
]
