"""Fleet CLI: cross-process trace merge, request timelines, snapshot
aggregation.

  # one request's timeline reconstructed across N replica traces
  python -m repro.obs --request req0 r0_trace.json r1_trace.json

  # merge traces into one epoch-aligned Chrome trace (open in Perfetto)
  python -m repro.obs --merge fleet_trace.json r0.json r1.json

  # fold replica metrics snapshots into one fleet view
  python -m repro.obs --merge-snapshots r0.snap r1.snap \
      --out fleet.snap [--prom fleet.prom]

SLO evaluation lives one module down: ``python -m repro.obs.slo``.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import aggregate as A
from repro.obs import trace as T


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _print_timeline(request_id: str, paths: list[str]) -> int:
    merged = T.merge_traces(*(_load(p) for p in paths))
    names = T.process_names(merged)
    spans = T.request_spans(merged, request_id)
    if not spans:
        print(f"request {request_id!r}: no spans in {len(paths)} trace(s)")
        return 1
    t0 = spans[0]["ts"]
    print(f"request {request_id} — {len(spans)} spans across "
          f"{len({s['pid'] for s in spans})} process(es), "
          f"t0 = {t0 / 1e6:.6f} unix")
    print(f"{'t+ms':>10} {'dur ms':>9}  {'replica':<14} event")
    for s in spans:
        where = names.get(s["pid"], f"pid {s['pid']}")
        args = {k: v for k, v in s["args"].items()
                if k not in ("request", "requests")}
        extra = (" " + " ".join(f"{k}={v}" for k, v in args.items())
                 if args else "")
        print(f"{(s['ts'] - t0) / 1e3:>10.3f} {s['dur'] / 1e3:>9.3f}  "
              f"{where:<14} {s['name']}{extra}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="merge traces/snapshots across engine replicas")
    ap.add_argument("paths", nargs="*", metavar="PATH",
                    help="trace or snapshot files, mode-dependent")
    ap.add_argument("--request", metavar="ID",
                    help="print this request's cross-process timeline "
                         "from the given trace files")
    ap.add_argument("--merge", metavar="OUT",
                    help="write the epoch-aligned merge of the given "
                         "trace files")
    ap.add_argument("--merge-snapshots", action="store_true",
                    help="treat PATHs as repro.obs/v1 snapshots and "
                         "merge them (--out / --prom)")
    ap.add_argument("--out", metavar="PATH",
                    help="with --merge-snapshots: write the fleet "
                         "snapshot JSON here")
    ap.add_argument("--prom", metavar="PATH",
                    help="with --merge-snapshots: write the fleet "
                         "Prometheus exposition here")
    args = ap.parse_args(argv)

    if not args.paths:
        ap.error("no input files")
    if args.request:
        return _print_timeline(args.request, args.paths)
    if args.merge:
        merged = T.merge_traces(*(_load(p) for p in args.paths))
        with open(args.merge, "w") as f:
            json.dump(merged, f)
        print(f"merged {len(args.paths)} traces "
              f"({len(merged['traceEvents'])} events) -> {args.merge}")
        return 0
    if args.merge_snapshots:
        merged = A.merge_snapshots(
            *(A.load_snapshot(p) for p in args.paths))
        if args.out:
            A.save_snapshot(merged, args.out)
            print(f"fleet snapshot ({len(merged['metrics'])} metrics) "
                  f"-> {args.out}")
        if args.prom:
            with open(args.prom, "w") as f:
                f.write(A.render_snapshot(merged))
            print(f"fleet exposition -> {args.prom}")
        if not args.out and not args.prom:
            print(A.render_snapshot(merged), end="")
        return 0
    ap.error("pick one of --request / --merge / --merge-snapshots")


if __name__ == "__main__":
    sys.exit(main())
