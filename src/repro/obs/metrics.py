"""Metrics registry: counters, gauges, histograms with labels.

The single metrics surface the serving stack publishes into —
``EngineStats`` (serve/scheduler.py), ``PrefixCache``
(serve/prefix_cache.py), ``Scheduler`` and the speculative
``DraftController`` (spec/controller.py) all register their counters
here instead of keeping private dicts, so one Prometheus text
exposition (``MetricsRegistry.render()``) covers the whole engine and
``EngineStats.summary()`` is a *view* over the registry rather than a
second bookkeeping system.

Design constraints, in order:

* **cheap on the hot path** — ``Counter.inc`` / ``Gauge.set`` /
  ``Histogram.observe`` are a couple of attribute writes, no locks on
  the unlabeled fast path (the engine is single-threaded per step; the
  registry dict itself is guarded for concurrent *registration* only);
* **percentile-honest** — histograms keep the raw observations (capped
  at ``Histogram.MAX_SAMPLES``, after which percentiles fall back to
  bucket interpolation) so ``quantile(0.5/0.95/0.99)`` reports real
  p50/p95/p99 rather than bucket-boundary estimates; the bucket counts
  still drive the Prometheus ``_bucket`` exposition;
* **exposition-compatible** — ``render()`` emits the Prometheus text
  format (``# HELP`` / ``# TYPE`` / ``name{labels} value``) that any
  scraper parses; ``obs.validate.validate_prometheus_text`` checks it
  in CI.
"""

from __future__ import annotations

import bisect
import math
import re
import threading
import time

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

# latency buckets (seconds) tuned to serving TTFT/ITL scales
LATENCY_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _fmt(v: float) -> str:
    """Prometheus sample value: integers render bare, floats repr()."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int) or (isinstance(v, float) and v.is_integer()
                              and abs(v) < 1e15):
        return str(int(v))
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
    return "{" + inner + "}"


class _Child:
    """Base for one (metric, label-values) time series."""

    def __init__(self, labels: dict):
        self.labels = dict(labels)


class Counter(_Child):
    """Monotonically increasing count."""

    def __init__(self, labels: dict | None = None):
        super().__init__(labels or {})
        self.value = 0

    def inc(self, amount: int | float = 1) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount


class Gauge(_Child):
    """A value that can go up and down.

    ``ts`` is the unix time of the last write (None until one happens):
    the aggregation layer (obs/aggregate.py) serializes it so merged
    fleet snapshots can pick the freshest of two writes to the *same*
    series and the future router can judge per-replica staleness.
    """

    def __init__(self, labels: dict | None = None):
        super().__init__(labels or {})
        self.value = 0.0
        self.ts: float | None = None

    def set(self, value: float) -> None:
        self.value = value
        self.ts = time.time()

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount
        self.ts = time.time()

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount
        self.ts = time.time()


class Histogram(_Child):
    """Cumulative-bucket histogram that also keeps raw samples.

    ``quantile(q)`` interpolates the sorted raw samples while they fit
    under ``MAX_SAMPLES`` (exact percentiles for every serving run this
    repo times); past the cap it degrades to linear interpolation
    inside the cumulative buckets — still monotone, never silently
    wrong by more than a bucket width.
    """

    MAX_SAMPLES = 1 << 17

    def __init__(self, labels: dict | None = None,
                 buckets: tuple[float, ...] = LATENCY_BUCKETS):
        super().__init__(labels or {})
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +Inf last
        self.sum = 0.0
        self.count = 0
        self.samples: list[float] = []
        self._min = math.inf
        self._max = -math.inf

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        self.bucket_counts[bisect.bisect_left(self.buckets, value)] += 1
        if value < self._min:
            self._min = value
        if value > self._max:
            self._max = value
        if len(self.samples) < self.MAX_SAMPLES:
            self.samples.append(value)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def min(self) -> float:
        return self._min if self.count else 0.0

    @property
    def max(self) -> float:
        return self._max if self.count else 0.0

    def quantile(self, q: float) -> float:
        """q in [0, 1]; nan with no observations."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if not self.count:
            return math.nan
        if self.samples and len(self.samples) == self.count:
            s = sorted(self.samples)
            pos = q * (len(s) - 1)
            lo = int(pos)
            hi = min(lo + 1, len(s) - 1)
            return s[lo] + (s[hi] - s[lo]) * (pos - lo)
        # bucket interpolation on the cumulative counts; the extreme
        # quantiles and the interpolated value are pinned to the
        # *observed* min/max (tracked in observe) so the fallback never
        # extrapolates past values that actually occurred — quantile(1.0)
        # of a capped histogram is the real max, not a bucket edge
        if q == 0.0:
            return self._min
        if q == 1.0:
            return self._max
        target = q * self.count
        cum = 0
        prev_edge = 0.0
        est = self.buckets[-1]
        for i, n in enumerate(self.bucket_counts):
            if cum + n >= target and n:
                edge = (self.buckets[i] if i < len(self.buckets)
                        else self.buckets[-1])
                frac = (target - cum) / n
                est = prev_edge + (edge - prev_edge) * frac
                break
            cum += n
            if i < len(self.buckets):
                prev_edge = self.buckets[i]
        return min(max(est, self._min), self._max)

    @property
    def exact(self) -> bool:
        """True while the raw samples cover every observation, i.e.
        quantiles are exact rather than bucket-interpolated."""
        return len(self.samples) == self.count

    def cdf(self, value: float) -> float:
        """Fraction of observations ≤ ``value`` (SLO error budgets).
        Exact from samples when available, else cumulative-bucket
        interpolation — same degradation contract as :meth:`quantile`.
        """
        if not self.count:
            return math.nan
        if self.exact:
            return bisect.bisect_right(sorted(self.samples),
                                       value) / self.count
        if value >= self._max:
            return 1.0
        if value < self._min:
            return 0.0
        cum = 0
        prev_edge = 0.0
        for i, n in enumerate(self.bucket_counts):
            edge = (self.buckets[i] if i < len(self.buckets)
                    else math.inf)
            if value < edge:
                if n and math.isfinite(edge):
                    frac = (value - prev_edge) / max(edge - prev_edge,
                                                     1e-300)
                    cum += n * min(max(frac, 0.0), 1.0)
                elif n:
                    cum += n
                return min(cum / self.count, 1.0)
            cum += n
            prev_edge = edge
        return 1.0

    def merge(self, other: "Histogram") -> "Histogram":
        """A new histogram equal to observing both inputs' streams.

        Bucket counts, sum, count, min and max merge *exactly* always.
        Raw samples survive only when both inputs are exact and the
        union fits under ``MAX_SAMPLES``; otherwise the result keeps no
        samples and quantiles degrade to bucket interpolation — the
        same contract as a single capped histogram. Under that rule the
        merge is associative: exactness of a fold equals "every leaf
        exact and the total count ≤ MAX_SAMPLES", independent of
        grouping, and the kept samples are the sorted union.
        """
        if self.buckets != other.buckets:
            raise ValueError(
                f"cannot merge histograms with different buckets: "
                f"{self.buckets} vs {other.buckets}")
        out = Histogram(labels=dict(self.labels), buckets=self.buckets)
        out.bucket_counts = [a + b for a, b in
                             zip(self.bucket_counts, other.bucket_counts)]
        out.sum = self.sum + other.sum
        out.count = self.count + other.count
        out._min = min(self._min, other._min)
        out._max = max(self._max, other._max)
        if (self.exact and other.exact
                and out.count <= self.MAX_SAMPLES):
            out.samples = sorted(self.samples + other.samples)
        return out


class _Family:
    """One named metric and its labeled children."""

    def __init__(self, name: str, kind: str, help: str,
                 factory, **kwargs):
        self.name = name
        self.kind = kind
        self.help = help
        self._factory = factory
        self._kwargs = kwargs
        self._children: dict[tuple, _Child] = {}

    def labels(self, **labelvals) -> _Child:
        key = tuple(sorted(labelvals.items()))
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = self._factory(
                labels=dict(key), **self._kwargs)
        return child

    @property
    def children(self) -> list[_Child]:
        return list(self._children.values())


class MetricsRegistry:
    """Named metrics with one-line registration.

    ``counter`` / ``gauge`` / ``histogram`` create-or-return: calling
    twice with one name returns the same object (so views like
    ``EngineStats`` and publishers like ``Scheduler`` can resolve
    independently), but a name can never change kind.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _register(self, name: str, kind: str, help: str, build):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                ekind = (existing.kind if isinstance(existing, _Family)
                         else existing._kind)
                if ekind != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {ekind}")
                return existing
            m = build()
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple[str, ...] = ()) -> Counter | _Family:
        return self._one(name, "counter", help, labelnames, Counter)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple[str, ...] = ()) -> Gauge | _Family:
        return self._one(name, "gauge", help, labelnames, Gauge)

    def histogram(self, name: str, help: str = "",
                  labelnames: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = LATENCY_BUCKETS
                  ) -> Histogram | _Family:
        return self._one(name, "histogram", help, labelnames, Histogram,
                         buckets=buckets)

    def _one(self, name, kind, help, labelnames, cls, **kwargs):
        if labelnames:
            return self._register(
                name, kind, help,
                lambda: _Family(name, kind, help, cls, **kwargs))

        def build():
            m = cls(**kwargs)
            m._kind = kind
            m._help = help
            return m
        return self._register(name, kind, help, build)

    def get(self, name: str):
        return self._metrics.get(name)

    def value(self, name: str, default=0):
        """Scalar value of an unlabeled counter/gauge (views use this)."""
        m = self._metrics.get(name)
        return default if m is None else m.value

    def families(self):
        """Yield ``(name, kind, help, children)`` per registered metric,
        name-sorted — the uniform iteration surface ``render()`` and the
        snapshot serializer (obs/aggregate.py) share."""
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, _Family):
                yield name, m.kind, m.help, m.children
            else:
                yield name, m._kind, m._help, [m]

    # -- exposition ---------------------------------------------------------

    def render(self) -> str:
        """Prometheus text exposition of every registered metric."""
        out: list[str] = []
        for name, kind, help, children in self.families():
            if help:
                out.append(f"# HELP {name} {_escape(help)}")
            out.append(f"# TYPE {name} {kind}")
            for c in children:
                if kind == "histogram":
                    cum = 0
                    for edge, n in zip(c.buckets, c.bucket_counts):
                        cum += n
                        lbl = _label_str({**c.labels, "le": _fmt(edge)})
                        out.append(f"{name}_bucket{lbl} {cum}")
                    lbl = _label_str({**c.labels, "le": "+Inf"})
                    out.append(f"{name}_bucket{lbl} {c.count}")
                    base = _label_str(c.labels)
                    out.append(f"{name}_sum{base} {_fmt(c.sum)}")
                    out.append(f"{name}_count{base} {c.count}")
                else:
                    out.append(f"{name}{_label_str(c.labels)} "
                               f"{_fmt(c.value)}")
        return "\n".join(out) + ("\n" if out else "")

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.render())


def render_all(*registries: MetricsRegistry) -> str:
    """Concatenate expositions (metric names must be disjoint — the
    engine keeps lifetime-scoped registries, e.g. the prefix cache's,
    separate from the resettable stats registry)."""
    seen: set[str] = set()
    for r in registries:
        names = set(r._metrics)
        dup = seen & names
        if dup:
            raise ValueError(f"duplicate metric names across registries: "
                             f"{sorted(dup)}")
        seen |= names
    return "".join(r.render() for r in registries)


#: Process-global registry for publishers with no natural owner —
#: `distributed/ft.py` membership/straggler metrics land here, the way
#: spans land in the global ``trace.tracer``. The serving engine keeps
#: its own (resettable) registries; this one is for process-lifetime
#: infrastructure counters.
default_registry = MetricsRegistry()
