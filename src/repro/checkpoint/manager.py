"""Checkpointing: atomic, async, topology-resharding-capable.

Design points for 1000+-node runs:
  * **Atomicity** — writes go to ``step_N.tmp/`` then ``os.replace`` to
    ``step_N/``; a crash mid-write never corrupts the restore target.
  * **Async** — ``save()`` snapshots device arrays to host (blocking only
    for the device→host copy) and writes in a background thread, so the
    train loop overlaps checkpoint IO with the next steps.
  * **Resharding** — arrays are stored as full (unsharded) npz per leaf;
    ``restore(..., shardings=...)`` re-places them under ANY mesh, so a
    checkpoint taken on 512 chips restores onto 256 after an elastic
    shrink. (At real scale you'd write per-shard files; the full-array
    format keeps the restore-on-different-topology property this repo
    demonstrates with the least machinery.)
  * **Retention** — keep the latest ``keep`` checkpoints; GC the rest.
  * **Preemption-safety** — ``wait()`` drains pending writes; the fault-
    tolerance layer calls it from the SIGTERM handler.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None
        self._lock = threading.Lock()

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> int | None:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False,
             extra: dict | None = None):
        """Snapshot to host, then write asynchronously."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host, dtypes = [], []
        for x in leaves:
            a = np.asarray(x)
            dtypes.append(str(a.dtype))
            if a.dtype.kind not in "fiub?" or a.dtype.itemsize == 2 \
                    and "bfloat" in str(a.dtype):
                a = a.view(np.uint16)                # bf16 → lossless view
            host.append(a)
        meta = {"step": step, "treedef": str(treedef), "dtypes": dtypes,
                "time": time.time(), "extra": extra or {}}

        def _write():
            tmp = self._step_dir(step) + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "leaves.npz"),
                     **{f"leaf_{i}": h for i, h in enumerate(host)})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            os.replace(tmp, self._step_dir(step))    # atomic publish
            self._gc()

        t = threading.Thread(target=_write, daemon=True)
        t.start()
        with self._lock:
            self._pending = t
        if blocking:
            self.wait()

    def wait(self):
        with self._lock:
            t = self._pending
        if t is not None:
            t.join()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, tree_like, *, step: int | None = None,
                shardings=None) -> tuple[int, object]:
        """Restore into the structure of ``tree_like``; optionally place
        leaves with ``shardings`` (same pytree structure). Works across
        mesh topologies — leaves are full arrays re-placed at load."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        data = np.load(os.path.join(d, "leaves.npz"))
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        dtypes = meta.get("dtypes", [])
        leaves, treedef = jax.tree.flatten(tree_like)
        loaded = []
        for i, ref in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            if i < len(dtypes) and "bfloat16" in dtypes[i]:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            loaded.append(arr)
        out = jax.tree.unflatten(treedef, loaded)
        if shardings is not None:
            out = jax.tree.map(
                lambda a, s: jax.device_put(a, s), out, shardings)
        else:
            out = jax.tree.map(jnp_asarray, out)
        return step, out


def jnp_asarray(x):
    import jax.numpy as jnp
    return jnp.asarray(x)
