"""llama4-maverick-400b-a17b — MoE 128e top-1 [hf:meta-llama/Llama-4].

48L, d_model=5120, 40 heads (GQA kv=8, d=128), expert d_ff=8192,
vocab=202048; 128 experts top-1 + 1 shared expert on alternating layers
(dense/MoE interleave). Early-fusion multimodal frontend stubbed —
text-only input specs per assignment. Experts are EP-sharded
(expert dim over 'model', hidden over 'data'): see distributed/sharding.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    act="silu",
    gated_mlp=True,
    norm="rms",
    layer_pattern=("global", "global_moe"),
    moe=MoEConfig(n_experts=128, top_k=1, capacity_factor=1.25,
                  n_shared_experts=1, every=2),
)
