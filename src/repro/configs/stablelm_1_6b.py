"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b].

24L, d_model=2048, 32 heads MHA (kv=32, d=64), d_ff=5632, vocab=100352.
Uses LayerNorm. d=64 puts train_4k almost exactly at the paper's N0
crossover (N0(64)=4256) — flagged as a §Perf hillclimb cell.
Simplification: full RoPE instead of stablelm's 25% partial rotary.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="decoder",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    act="silu",
    gated_mlp=True,
    norm="ln",
    tie_embeddings=False,
)
