"""yi-9b — llama-architecture GQA decoder [arXiv:2403.04652].

48L, d_model=4096, 32 heads (GQA kv=4), d_ff=11008, vocab=64000.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="decoder",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    act="silu",
    gated_mlp=True,
    norm="rms",
    tie_embeddings=False,
)
