"""grok-1-314b — MoE 8 experts top-2 [hf:xai-org/grok-1].

64L, d_model=6144, 48 heads (GQA kv=8, d=128), expert d_ff=32768,
vocab=131072; every layer MoE.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    act="gelu",
    gated_mlp=True,
    norm="rms",
    layer_pattern=("global_moe",),
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25, every=1),
)
