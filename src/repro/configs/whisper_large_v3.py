"""whisper-large-v3 — enc-dec audio transformer [arXiv:2212.04356].

32L enc + 32L dec, d_model=1280, 20 heads (MHA), d_ff=5120, vocab=51866.
The conv frontend is a STUB per assignment: input_specs() provides
precomputed frame embeddings. TaylorShift sites: non-causal encoder
self-attn (the paper's exact setting), causal decoder self-attn, and
cross-attention (served via a frozen encoder TaylorState).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    act="gelu",
    gated_mlp=False,
    norm="ln",
    pos_embed="learned",
    max_seq_len=4096,
    decoder_len=448,
    encoder_frames=1500,
    frontend="audio_stub",
    tie_embeddings=True,
)
