"""gemma2-27b — local/global alternating, logit softcaps [arXiv:2408.00118].

46L, d_model=4608, 32 heads (GQA kv=16, head_dim=128), d_ff=36864,
vocab=256000, window 4096, pre+post RMSNorm. NOTE (docs/design.md
§Arch-applicability): attention-logit softcapping is incompatible with
the TaylorShift factorization — the learnable temperature tau takes its
role on Taylor layers; softcap_attn applies on the softmax baseline path.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="decoder",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    act="gelu",
    gated_mlp=True,
    norm="rms",
    post_norm=True,
    layer_pattern=("local", "global"),
    window=4096,
    softcap_attn=50.0,
    softcap_final=30.0,
)
