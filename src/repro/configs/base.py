"""Model / run configuration.

One ``ModelConfig`` dataclass covers every assigned architecture family
(dense decoder, MoE, hybrid SSM, xLSTM, encoder-decoder, VLM). Each
``src/repro/configs/<arch>.py`` exports ``CONFIG`` built from this class,
plus the registry maps ``--arch <id>`` to it.

``layer_pattern`` encodes periodic heterogeneity (gemma's local:global
alternation, zamba's shared-attention interleave, xlstm's sLSTM/mLSTM
alternation) as a repeating unit; the model scans over full periods and
unrolls the remainder, so compile time stays O(pattern), not O(layers).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Sequence

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    n_shared_experts: int = 0     # llama4-style always-on shared expert
    every: int = 1                # 1 = every layer, 2 = alternate dense/moe
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state: int = 64               # N (ssm state size)
    head_dim: int = 64            # P
    expansion: int = 2            # d_inner = expansion * d_model
    conv_width: int = 4
    n_groups: int = 1             # B/C groups (GVA-style)
    chunk: int = 64


@dataclass(frozen=True)
class TaylorConfig:
    """Paper knobs. Backend routing (which implementation serves which
    attention site under which mesh) is resolved from these by
    ``models/backend.py:select_backend`` — the single dispatch layer."""
    enabled: bool = True
    mode: str = "auto"            # auto | direct | efficient
    optimize_for: str = "speed"   # crossover flavor: speed (N0) | memory (N1)
    chunk: int = 128              # causal chunk size
    tau_init: float = 1.0         # learnable per-head temperature init
    normalize_inputs: bool = True
    output_scale: bool = True
    use_kernel: bool = False      # route through the Pallas kernels
    scan: str = "auto"            # causal chunk-scan core: auto | sequential
    #   | parallel — auto streams one state (lax.scan) on a single seq
    #   shard and switches to the associative form under a `seq` mesh axis


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-generation knobs (src/repro/spec/, docs/serving.md).

    The draft-length cap itself lives on ``EngineConfig.speculate_k``
    (0 disables speculation); this groups the drafter-side choices so
    the engine config stays one flat dataclass.
    """
    drafter: str = "ngram"        # ngram (prompt-lookup) | self (shallow)
    draft_layers: int = 1         # self-drafter: reuse the first j blocks
    adaptive: bool = True         # acceptance-rate-adaptive draft length
    ngram_max: int = 3            # longest history suffix matched
    ngram_min: int = 1            # shortest suffix before giving up
    ewma: float = 0.5             # acceptance-rate EWMA weight on new obs
    grow_above: float = 0.8       # raise draft length above this rate
    shrink_below: float = 0.4     # lower draft length below this rate


@dataclass(frozen=True)
class PrefixCacheConfig:
    """Shared-prefix state-cache knobs (serve/prefix_cache.py,
    docs/serving.md).

    Mirrors the ``SpecConfig`` split: the on/off knob and byte budget
    live on ``EngineConfig.prefix_cache_mb`` (0 disables the cache);
    this groups the trie-side choices. ``chunk_tokens`` is the trie key
    granularity — 0 follows ``EngineConfig.prefill_chunk``, and the
    engine *rejects* any other value (a finer grid would let
    power-of-two tail chunks form boundaries no cold prefill
    reproduces, breaking the bit-identity contract); it exists so
    offline tools can build a ``PrefixCache`` without an engine.
    ``max_entries`` bounds the entry count independently of bytes
    (0 = byte budget only) — Taylor entries are so small a pure byte
    budget can let the trie grow very wide.
    """
    chunk_tokens: int = 0
    max_entries: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "decoder"       # decoder | encdec | hybrid | xlstm | vlm | audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int | None = None
    head_dim: int | None = None   # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 32000
    act: str = "silu"
    gated_mlp: bool = True
    norm: str = "rms"             # rms | ln
    post_norm: bool = False       # gemma2-style post-block norms
    qk_norm: bool = False
    # --- attention ---------------------------------------------------------
    causal: bool = True           # False = encoder-style (paper's setting)
    attn_backend: str = "taylor"  # taylor | softmax
    taylor: TaylorConfig = field(default_factory=TaylorConfig)
    layer_pattern: Sequence[str] = ("global",)
    #   entries: global | local | mamba | shared_attn | slstm | mlstm | moe…
    #   ("moe" is orthogonal; use MoEConfig.every)
    window: int = 1024            # local-attention window
    softcap_attn: float = 0.0     # gemma2 attn logit softcap (softmax path)
    softcap_final: float = 0.0    # gemma2 final logit softcap
    rope_theta: float = 10000.0
    pos_embed: str = "rope"       # rope | learned | none
    max_seq_len: int = 8192       # for learned positions only
    tie_embeddings: bool = True
    # --- MoE / SSM ----------------------------------------------------------
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    shared_attn_every: int = 6    # zamba2: shared attn block period
    # --- encoder-decoder (whisper) ------------------------------------------
    n_encoder_layers: int = 0
    encoder_causal: bool = False
    decoder_len: int = 448        # training decoder length for encdec
    encoder_frames: int = 1500    # fixed encoder length for decode shapes
    # --- frontends (stubs per assignment) -----------------------------------
    frontend: str = "none"        # none | audio_stub | vision_stub
    n_patches: int = 576          # vlm stub: image patch tokens per example
    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    logits_chunk: int = 0         # 0 = auto (chunked xent for big vocab)
    loss_dtype: str = "float32"

    # --- derived -------------------------------------------------------------
    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def dim_head(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # -- smoke-test sizing ----------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family/pattern, tiny dims — for CPU smoke tests."""
        kw = dict(
            n_layers=max(len(self.layer_pattern), 2),
            d_model=64,
            n_heads=2,
            n_kv_heads=1 if (self.n_kv_heads or 0) and self.n_kv_heads < self.n_heads else None,
            head_dim=32,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            window=16,
            max_seq_len=256,
            decoder_len=16,
            encoder_frames=32,
            n_patches=8,
            remat=False,
            dtype="float32",
        )
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
        if self.moe.n_experts:
            kw["moe"] = replace(self.moe, n_experts=4, capacity_factor=2.0)
        if self.family in ("hybrid", "xlstm"):
            kw["ssm"] = replace(self.ssm, state=16, head_dim=16, chunk=8)
        kw["taylor"] = replace(self.taylor, chunk=16)
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


def asdict(cfg: ModelConfig) -> dict:
    return dataclasses.asdict(cfg)
