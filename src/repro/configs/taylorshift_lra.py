"""The paper's own model: TaylorShift Transformer encoder (LRA ListOps
hyperparameters, paper Appendix C Table 6: depth 4, d_embed=512, 8 heads,
MLP ratio 2). Used by examples/ and the accuracy-parity benchmark.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="taylorshift-lra",
    family="decoder",
    causal=False,               # non-causal encoder — the paper's setting
    n_layers=4,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=1024,
    vocab=32,
    act="gelu",
    gated_mlp=False,
    norm="ln",
    pos_embed="learned",
    max_seq_len=2048,
    tie_embeddings=True,
)
