"""gemma3-1b — dense decoder, 5:1 local:global [hf:google/gemma-3-1b-pt].

26L, d_model=1152, 4 heads (GQA kv=1, head_dim=256), d_ff=6912,
vocab=262144, sliding window 512. Global layers use efficient-TaylorShift
(d=256 => N0 ~ 66k: auto mode picks efficient only for the long shapes —
"and Back"); local layers use windowed direct-Taylor.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="decoder",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    act="gelu",
    gated_mlp=True,
    norm="rms",
    post_norm=True,
    qk_norm=True,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=512,
    rope_theta=1_000_000.0,
)
