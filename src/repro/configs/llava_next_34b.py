"""llava-next-34b — VLM (Yi-34B-class backbone) [hf:llava-hf/llava-v1.6].

60L, d_model=7168, 56 heads (GQA kv=8, d=128), d_ff=20480, vocab=64000.
The anyres vision tower is a STUB per assignment: input_specs() provides
patch embeddings (B, n_patches, d_model) prepended to the token stream.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    act="silu",
    gated_mlp=True,
    norm="rms",
    frontend="vision_stub",
    n_patches=576,
    tie_embeddings=False,
)
