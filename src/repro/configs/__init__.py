"""Architecture registry: ``--arch <id>`` → ModelConfig."""

from __future__ import annotations

import importlib

from repro.configs.base import (ModelConfig, MoEConfig, PrefixCacheConfig,
                                SpecConfig, SSMConfig, TaylorConfig)

_ARCH_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "gemma3-1b": "gemma3_1b",
    "yi-9b": "yi_9b",
    "stablelm-1.6b": "stablelm_1_6b",
    "gemma2-27b": "gemma2_27b",
    "llava-next-34b": "llava_next_34b",
    "zamba2-7b": "zamba2_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "grok-1-314b": "grok_1",
    "xlstm-125m": "xlstm_125m",
    "taylorshift-lra": "taylorshift_lra",   # the paper's own encoder
}

ARCH_IDS = [a for a in _ARCH_MODULES if a != "taylorshift-lra"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


__all__ = ["ModelConfig", "MoEConfig", "PrefixCacheConfig", "SpecConfig",
           "SSMConfig", "TaylorConfig", "get_config", "ARCH_IDS"]
