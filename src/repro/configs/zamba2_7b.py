"""zamba2-7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 layers, d_model=3584, ssm_state=64; a SHARED transformer-attention
block (single weight set) is applied every 6th layer. TaylorShift applies
to the shared attention; the Mamba2 SSD blocks are already linear-time
(docs/design.md §Arch-applicability). Simplifications: one shared block (not
two alternating), no per-invocation LoRA, shared block has no MLP.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    act="gelu",
    norm="rms",
    layer_pattern=("mamba", "mamba", "mamba", "mamba", "mamba",
                   "mamba_shared"),
    ssm=SSMConfig(state=64, head_dim=64, expansion=2, conv_width=4,
                  n_groups=1, chunk=64),
)
