"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L alternating (mLSTM, sLSTM), d_model=768, 4 heads, d_ff=0 (blocks
carry their own projections), vocab=50304. TaylorShift INAPPLICABLE:
attention-free (docs/design.md §Arch-applicability); the mLSTM matrix memory
is itself the nearest linear-attention cousin of the Taylor state.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    norm="ln",
    pos_embed="none",
    layer_pattern=("mlstm", "slstm"),
    ssm=SSMConfig(chunk=64),
)
