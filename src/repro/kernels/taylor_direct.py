"""Flash-style direct-TaylorShift Pallas TPU kernel.

Tiled O(N²d) attention with the Taylor-softmax numerator
``p(x) = x²/2 + α²·x + α⁴`` (inputs pre-scaled by α = d^¼, Alg. 1).

Key TPU adaptation vs FlashAttention: Taylor-softmax needs **no running
max and no rescaling** — the polynomial is positive and bounded after
the paper's normalization — so the kernel keeps only (nominator,
denominator) accumulators in VMEM and makes a single pass over K/V
tiles. One fewer VMEM tensor and no per-tile exp/rescale traffic than
online-softmax.

Inputs are (BH, N, d) with q, k already ℓ2-normalized and α-scaled
(ops.py does Alg. 1 lines 4–6). All accumulation in fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_nom, acc_den, *,
            alpha: float, causal: bool, block_q: int, block_k: int,
            n_seq: int, out_scale: bool, d: int, m_valid: int,
            raw: bool = False):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_nom[...] = jnp.zeros_like(acc_nom)
        acc_den[...] = jnp.zeros_like(acc_den)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)                    # (bk, d)

    x = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    a = 0.5 * x * x + (alpha ** 2) * x + alpha ** 4     # Taylor numerator
    if causal or m_valid < n_seq:
        kj = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_k), 1)
        if causal:
            qi = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            a = jnp.where(qi >= kj, a, 0.0)
        if m_valid < n_seq:     # keys beyond m_valid are padding
            a = jnp.where(kj < m_valid, a, 0.0)

    acc_nom[...] += jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)
    acc_den[...] += jnp.sum(a, axis=1)

    @pl.when(ik == nk - 1)
    def _finish():
        if raw:
            # VJP path: emit (denominator, nominator) unscaled — the
            # wrapper divides in jnp and keeps den as a residual.
            o_ref[0] = jnp.concatenate(
                [acc_den[...][:, None], acc_nom[...]], axis=1
            ).astype(o_ref.dtype)
            return
        y = acc_nom[...] / acc_den[...][:, None]
        if out_scale:
            if causal:
                qi = (iq * block_q
                      + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0))
                counts = (qi + 1).astype(jnp.float32)
            else:
                counts = jnp.full((block_q,), float(m_valid), jnp.float32)
            y = y * jnp.sqrt(counts / d)[:, None]
        o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "out_scale", "interpret",
                                             "m_valid", "raw"))
def taylor_direct_attention(q, k, v, *, causal: bool = False,
                            block_q: int | None = None,
                            block_k: int | None = None,
                            out_scale: bool = True, interpret: bool = False,
                            m_valid: int | None = None, raw: bool = False):
    """q, k, v: (BH, N, d) — q, k pre-normalized and α-scaled.

    ``block_q``/``block_k``: grid block shapes; ``None`` (the default)
    resolves through the installed tuning table's calibrated sweep
    (repro.tune, falling back to 128). Resolution happens at trace
    time — install the table before the first dispatch.

    ``m_valid``: number of real keys when k/v are zero-padded up to a
    block multiple (ops.py pad-and-mask path); keys ≥ m_valid are masked
    out of both nominator and denominator.

    ``raw``: emit (BH, N, d+1) fp32 ``concat(den, nom)`` without the
    division or output scaling — the custom-VJP forward uses this to keep
    the row denominators as residuals for the backward kernels.
    """
    bh, n, d = q.shape
    m = k.shape[1]
    m_valid = m if m_valid is None else m_valid
    if block_q is None or block_k is None:
        from repro.tune.table import kernel_blocks
        tq, tk = kernel_blocks(d)
        block_q = tq if block_q is None else block_q
        block_k = tk if block_k is None else block_k
    block_q = min(block_q, n)
    block_k = min(block_k, m)
    assert n % block_q == 0 and m % block_k == 0
    alpha = float(d) ** 0.25
    grid = (bh, n // block_q, m // block_k)

    kernel = functools.partial(
        _kernel, alpha=alpha, causal=causal, block_q=block_q,
        block_k=block_k, n_seq=m, out_scale=out_scale, d=d, m_valid=m_valid,
        raw=raw)

    d_out = d + 1 if raw else d
    out_dtype = jnp.float32 if raw else v.dtype
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d_out), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d_out), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
