"""Jitted wrappers around the Pallas TaylorShift kernels.

These are the entry points the attention layer uses when
``cfg.taylor.use_kernel`` is set: they apply Algorithm 1's input
normalization (ℓ2 + temperature τ + α-scaling) in plain JAX, reshape
(B, H, N, d) → (BH, N, d), and dispatch to the kernels. On non-TPU
backends they run the kernels in interpret mode (Python execution of the
kernel body) so correctness is testable anywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import taylor as T
from repro.kernels.taylor_direct import taylor_direct_attention
from repro.kernels.taylor_efficient import taylor_efficient_attention
from repro.kernels.taylor_grad import (taylor_direct_attention_vjp,
                                       taylor_efficient_attention_vjp)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flatten_heads(x):
    b, h, n, d = x.shape
    return x.reshape(b * h, n, d)


def _prep(q, k, tau):
    d = q.shape[-1]
    alpha = d ** 0.25
    q, k = T.normalize_qk(q, k, tau)
    return (q * alpha).astype(jnp.float32), (k * alpha).astype(jnp.float32)


def taylor_attention_kernel(q, k, v, *, tau=1.0, causal: bool = False,
                            mode: str = "auto", out_scale: bool = True,
                            block_q: int = 128, block_k: int = 128,
                            interpret: bool | None = None):
    """Fused TaylorShift attention. q,k,v: (B, H, N, d) raw.

    mode: auto → paper crossover N0(d); causal currently implies the
    direct kernel (the chunked-causal efficient form stays in core/).
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    b, h, n, d = q.shape
    m = k.shape[2]
    if mode == "auto":
        mode = T.pick_mode(n, d)
    if causal:
        mode = "direct"
    qs, ks = _prep(q, k, tau)
    qf = _flatten_heads(qs)
    kf = _flatten_heads(ks)
    vf = _flatten_heads(v)
    bq, n_pad = _good_block(n, block_q)
    bk, m_pad = _good_block(m, block_k)
    qf = _pad_rows(qf, n_pad)
    kf = _pad_rows(kf, m_pad)
    vf = _pad_rows(vf, m_pad)
    mv = m if m_pad != m else None
    # Dispatch through the custom-VJP entries (kernels/taylor_grad.py):
    # undifferentiated calls execute the plain forward kernels, while
    # jax.grad gets the hand-written Pallas backward — so this one entry
    # serves inference and training alike.
    if mode == "direct":
        y = taylor_direct_attention_vjp(qf, kf, vf, causal=causal, block_q=bq,
                                        block_k=bk, out_scale=out_scale,
                                        interpret=interp, m_valid=mv)
    else:
        y = taylor_efficient_attention_vjp(qf, kf, vf, block_q=bq, block_k=bk,
                                           out_scale=out_scale,
                                           interpret=interp, m_valid=mv)
    return y[:, :n].reshape(b, h, n, d)


def _good_block(n: int, want: int) -> tuple[int, int]:
    """(block, padded_n): keep the wanted block size and pad n up to the
    next multiple, rather than shrinking the block until it divides n
    (which degrades to block=1 — a catastrophic grid — for prime n).
    Padded keys are masked out via ``m_valid``; padded query rows are
    sliced off the output."""
    b = min(want, max(n, 1))
    return b, -(-n // b) * b


def _pad_rows(x, n_pad: int):
    n = x.shape[1]
    if n_pad == n:
        return x
    return jnp.pad(x, ((0, 0), (0, n_pad - n), (0, 0)))


__all__ = ["taylor_attention_kernel", "taylor_direct_attention",
           "taylor_efficient_attention", "taylor_direct_attention_vjp",
           "taylor_efficient_attention_vjp"]
