"""Fused TaylorShift decode-step Pallas kernel (serving hot path).

One generated token per call: absorb (k, v) into the S2 state and read
out with q — the O(d²(d+1)) inner loop that replaces KV-cache attention
(docs/design.md §4.2). Fusing update+readout halves state HBM traffic vs the
two-pass jnp form: S2 is read once, updated in VMEM, written once, and
the readout contraction happens on the already-resident tile.

Grid: (BH, d²-chunks). Each step owns a (cf·d, d+1) tile of S2:
  S2_c   += K2_c^T · v̂           (rank-1 in the chunk rows)
  y_part  = Q2_c · S2_c           (partial readout, summed in the wrapper)

The small S1/S0 terms (d·(d+1) and (d+1)) stay in jnp — they are < 1 %
of the traffic. Validated against core.taylor.taylor_decode_step in
tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

from repro.core import taylor as T
from repro.kernels.taylor_efficient import _pick_chunk_factor


def _decode_kernel(q_ref, qc_ref, k_ref, kc_ref, vh_ref, s2_ref, s2_out,
                   yp_ref, *, cf: int, d: int):
    q = q_ref[0].astype(jnp.float32)          # (1, d)
    qc = qc_ref[0].astype(jnp.float32)        # (1, cf)
    k = k_ref[0].astype(jnp.float32)
    kc = kc_ref[0].astype(jnp.float32)
    vh = vh_ref[0].astype(jnp.float32)
    s2 = s2_ref[0]

    k2 = (kc[:, :, None] * k[:, None, :]).reshape(1, cf * d)
    s2 = s2 + jax.lax.dot_general(k2, vh, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    s2_out[0] = s2

    q2 = (qc[:, :, None] * q[:, None, :]).reshape(1, cf * d)
    yp_ref[0] = jax.lax.dot_general(q2, s2, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("normalize_inputs",
                                             "output_scale", "interpret"))
def taylor_decode_kernel(state: T.TaylorState, q, k, v, *, tau=1.0,
                         normalize_inputs: bool = True,
                         output_scale: bool = True,
                         interpret: bool = False):
    """Fused decode step. q,k,v: (BH, 1, d); state.s2: (BH, d², d+1).

    Returns (y (BH, 1, d), new TaylorState) — bit-compatible with
    core.taylor.taylor_decode_step.
    """
    bh, _, d = q.shape
    alpha = d ** 0.25
    if normalize_inputs:
        q, k = T.normalize_qk(q, k, tau)
    qs = (q * alpha).astype(jnp.float32)
    ks = (k * alpha).astype(jnp.float32)
    ones = jnp.ones((bh, 1, 1), jnp.float32)
    vh = jnp.concatenate([ones, v.astype(jnp.float32)], axis=-1)

    cf = _pick_chunk_factor(d)
    nchunks = d // cf
    grid = (bh, nchunks)
    kernel = functools.partial(_decode_kernel, cf=cf, d=d)
    s2_new, y_parts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, c: (b, 0, 0)),       # q
            pl.BlockSpec((1, 1, cf), lambda b, c: (b, 0, c)),      # q chunk
            pl.BlockSpec((1, 1, d), lambda b, c: (b, 0, 0)),       # k
            pl.BlockSpec((1, 1, cf), lambda b, c: (b, 0, c)),      # k chunk
            pl.BlockSpec((1, 1, d + 1), lambda b, c: (b, 0, 0)),   # vh
            pl.BlockSpec((1, cf * d, d + 1), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cf * d, d + 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, d + 1), lambda b, c: (b, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, d * d, d + 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, nchunks, d + 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(qs, qs, ks, ks, vh, state.s2)

    # small terms in jnp (<1 % of traffic)
    s1 = state.s1 + jnp.einsum("bcd,bcf->bdf", ks, vh)
    s0 = state.s0 + vh
    n = state.n + 1
    y_hat = 0.5 * jnp.sum(y_parts, axis=1, keepdims=True)
    y_hat += (alpha**2) * jnp.einsum("bcd,bdf->bcf", qs, s1)
    y_hat += (alpha**4) * s0
    y = y_hat[..., 1:] / y_hat[..., :1]
    if output_scale:
        y = y * jnp.sqrt(T._nb(n, y.ndim) / d)   # n: scalar or per-row (BH,)
    return y.astype(v.dtype), T.TaylorState(s2=s2_new, s1=s1, s0=s0, n=n)
