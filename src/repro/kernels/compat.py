"""Pallas-TPU API compatibility across jax versions."""

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # fail here, not inside a pallas_call site
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; install jax within requirements-dev.txt's range")
