"""Fused efficient-TaylorShift Pallas TPU kernels.

This is the IO-aware implementation the paper's Appendix D.2 calls for:
the N×d² expanded tensors K^⊠2 / Q^⊠2 are **never materialized in HBM**.

Phase A (``amod``):  A_mod = Σ_blocks (K_blk^⊠2)ᵀ V̂_blk
  grid (BH, d²-chunks, N-blocks); each step forms the (block_k, cf·d)
  slice of K^⊠2 in VMEM registers and accumulates a (cf·d, d+1) tile of
  A_mod in VMEM scratch. HBM traffic: read K,V̂ once per d²-chunk,
  write A_mod once — O(N·d·ceil(d/cf) + d²·(d+1)) instead of O(N·d²).

Phase B (``readout``): Ŷ = ½ Q^⊠2 A_mod + α² Q (KᵀV̂) + α⁴ ΣV̂
  grid (BH, N-blocks, d²-chunks); accumulates (block_q, d+1) in scratch,
  adds the linear/constant Taylor terms on the last chunk, divides
  nominator by denominator and writes Y.

MXU alignment: the contraction dims are cf·d and d+1 — cf is chosen so
cf·d is a multiple of 128 where possible; d+1 costs one lane of padding
(the paper's trick of gluing the denominator onto V as column 0).

Inputs are (BH, N, d) with q, k pre-normalized and α-scaled, and
v̂ = concat(1, v) built by ops.py. fp32 accumulation throughout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _pick_chunk_factor(d: int, vmem_budget: int = 8 * 1024 * 1024) -> int:
    """How many d-row groups of A_mod to hold per VMEM tile."""
    best = 1
    for cf in range(1, d + 1):
        if d % cf:
            continue
        tile_bytes = cf * d * (d + 1) * 4
        if tile_bytes <= vmem_budget:
            best = cf
    return best


# ---------------------------------------------------------------------------
# Phase A: accumulate A_mod
# ---------------------------------------------------------------------------

def _amod_kernel(k_ref, kc_ref, vh_ref, a_ref, acc, *, cf: int, d: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    vh = vh_ref[0].astype(jnp.float32)                   # (bk, d+1)
    kc = kc_ref[0].astype(jnp.float32)                   # (bk, cf) chunk cols
    # K^⊠2 chunk: rows π(a, b) with a in this cf-slice: k[:, a] * k[:, b]
    k2 = (kc[:, :, None] * k[:, None, :]).reshape(k.shape[0], cf * d)
    acc[...] += jax.lax.dot_general(k2, vh, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        a_ref[0] = acc[...]


def _amod_call(k, vh, *, cf: int, block_k: int, interpret: bool):
    bh, n, d = k.shape
    nchunks = d // cf
    grid = (bh, nchunks, n // block_k)
    kernel = functools.partial(_amod_kernel, cf=cf, d=d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_k, d), lambda b, c, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, cf), lambda b, c, j: (b, j, c)),
            pl.BlockSpec((1, block_k, d + 1), lambda b, c, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, cf * d, d + 1), lambda b, c, j: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d * d, d + 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((cf * d, d + 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(k, k, vh)


# ---------------------------------------------------------------------------
# Phase B: readout
# ---------------------------------------------------------------------------

def _readout_kernel(q_ref, qc_ref, a_ref, kv_ref, s0_ref, o_ref, acc, *,
                    cf: int, d: int, coef2: float, coef1: float,
                    coef0: float, n_keys: int, out_scale: bool,
                    divide: bool):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    qc = qc_ref[0].astype(jnp.float32)                   # (bq, cf)
    a = a_ref[0]                                         # (cf·d, d+1) fp32
    q2 = (qc[:, :, None] * q[:, None, :]).reshape(q.shape[0], cf * d)
    acc[...] += coef2 * jax.lax.dot_general(
        q2, a, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ic == nc - 1)
    def _finish():
        kv = kv_ref[0]                                   # (d, d+1) fp32
        s0 = s0_ref[0]                                   # (1, d+1) fp32
        y = acc[...]
        y += coef1 * jax.lax.dot_general(
            q, kv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        y += coef0 * s0
        if not divide:
            # raw ŷ = (den, nom): shared by the custom-VJP forward (den is
            # a backward residual) and by the dV̂ backward contraction
            # (coefs 1,1,1), which is this same bilinear readout.
            o_ref[0] = y.astype(o_ref.dtype)
            return
        out = y[:, 1:] / y[:, :1]
        if out_scale:
            out = out * (float(n_keys) / d) ** 0.5
        o_ref[0] = out.astype(o_ref.dtype)


def _readout_call(q, a_mod, kv, s0, *, cf: int, block_q: int, n_keys: int,
                  out_scale: bool, out_dtype, interpret: bool,
                  coefs: tuple | None = None, divide: bool = True):
    bh, n, d = q.shape
    alpha = float(d) ** 0.25
    coef2, coef1, coef0 = (0.5, alpha ** 2, alpha ** 4) if coefs is None \
        else coefs
    nchunks = d // cf
    grid = (bh, n // block_q, nchunks)
    kernel = functools.partial(_readout_kernel, cf=cf, d=d, coef2=coef2,
                               coef1=coef1, coef0=coef0, n_keys=n_keys,
                               out_scale=out_scale, divide=divide)
    d_out = d if divide else d + 1
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, c: (b, i, 0)),
            pl.BlockSpec((1, block_q, cf), lambda b, i, c: (b, i, c)),
            pl.BlockSpec((1, cf * d, d + 1), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((1, d, d + 1), lambda b, i, c: (b, 0, 0)),
            pl.BlockSpec((1, 1, d + 1), lambda b, i, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d_out), lambda b, i, c: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d + 1), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, q, a_mod, kv, s0)


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------

def build_vhat(v, m_valid: int) -> jnp.ndarray:
    """V̂ = concat(1, v) fp32 with padded keys (≥ m_valid) zeroed — the
    ones column included, which is what removes a padded key from both
    nominator and denominator. Single home for the padding convention:
    the forward here and the backward (taylor_grad.py) must agree."""
    bh, m, _ = v.shape
    ones = jnp.ones((bh, m, 1), jnp.float32)
    vh = jnp.concatenate([ones, v.astype(jnp.float32)], axis=-1)
    if m_valid < m:
        vh = vh * (jnp.arange(m) < m_valid)[None, :, None]
    return vh

@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "out_scale", "interpret",
                                             "m_valid"))
def taylor_efficient_attention(q, k, v, *, block_q: int | None = None,
                               block_k: int | None = None,
                               out_scale: bool = True,
                               interpret: bool = False,
                               m_valid: int | None = None):
    """Non-causal efficient-TaylorShift, fused. q,k: α-scaled normalized
    (BH, N, d); v: (BH, M, d) raw values.

    ``block_q``/``block_k``: ``None`` (the default) resolves through
    the installed tuning table's calibrated block sweep (repro.tune,
    falling back to 128); resolution happens at trace time.

    ``m_valid``: number of real keys when inputs are zero-padded up to a
    block multiple (ops.py pad-and-mask path). A padded key only enters
    the computation through V̂ (the state sums are linear in V̂), so
    zeroing its V̂ row — including the denominator ones-column — removes
    it from nominator and denominator alike.
    """
    bh, n, d = q.shape
    m = k.shape[1]
    m_valid = m if m_valid is None else m_valid
    if block_q is None or block_k is None:
        from repro.tune.table import kernel_blocks
        tq, tk = kernel_blocks(d)
        block_q = tq if block_q is None else block_q
        block_k = tk if block_k is None else block_k
    block_q = min(block_q, n)
    block_k = min(block_k, m)
    assert n % block_q == 0 and m % block_k == 0
    alpha = float(d) ** 0.25
    cf = _pick_chunk_factor(d)

    vh = build_vhat(v, m_valid)

    a_mod = _amod_call(k, vh, cf=cf, block_k=block_k, interpret=interpret)
    # small summaries — plain XLA ops (negligible traffic)
    kv = jnp.einsum("bnd,bnf->bdf", k.astype(jnp.float32), vh)
    s0 = jnp.sum(vh, axis=1, keepdims=True)
    return _readout_call(q, a_mod, kv, s0, cf=cf, block_q=block_q,
                         n_keys=m_valid, out_scale=out_scale,
                         out_dtype=v.dtype, interpret=interpret)
