"""Hand-written Pallas backward kernels + custom VJPs for TaylorShift.

Makes the fused attention kernels differentiable without ever
materializing what the forward avoided materializing:

* **direct** — flash-style recomputation backward. Residuals are only
  the row denominators (and the unscaled output); the N×M score matrix
  is rebuilt tile-by-tile in VMEM in both backward kernels. With
  ``u = a/den`` (the normalized Taylor scores) and ``y0`` the unscaled
  output, the cotangent chain is::

      da_ij = (g0_i·v_j - g0_i·y0_i) / den_i        (quotient rule)
      dx_ij = da_ij · (x_ij + α²)                   (p'(x) = x + α²)
      dq_i  = Σ_j dx_ij k_j     dk_j = Σ_i dx_ij q_i     dv_j = Σ_i u_ij g0_i

* **efficient** — the ⊠ tensor-product trick applies to the backward
  too. With ĝ_i = (-(g0_i·y0_i)/den_i, g0_i/den_i) ∈ R^{d+1} the
  cotangent of ŷ_i, every gradient is a rank-structured contraction:

      dA_mod = ½ (Q^⊠2)ᵀ Ĝ                          (an amod pass over Q)
      dq_i   = ½ (M_i + M_iᵀ) q_i + α² KV̂ ĝ_i,  M_i = mat(A_mod ĝ_i)
      dk_j   = (W_j + W_jᵀ) k_j + dKV̂ v̂_j,      W_j = mat(dA_mod v̂_j)
      dv̂_j  = K^⊠2_j dA_mod + k_j dKV̂ + dS0      (a raw readout pass)

  A_mod / KV̂ / ΣV̂ are *recomputed* from k, v̂ in the backward (they are
  cheaper to rebuild than to hold as residuals), and the N×d² expanded
  tensors are never formed in HBM: the symmetric-quadratic kernel below
  streams cf·d-row chunks of A_mod through VMEM exactly like the
  forward's two phases. Peak backward memory stays O(N·d + d³).

The causal chunked backward lives in ``core/taylor.py`` (pure-jnp
two-scan recompute custom VJP) since the causal path is not a Pallas
kernel to begin with.

All entries take (BH, N, d) inputs with q, k pre-normalized and
α-scaled, mirroring the forward kernels; ops.py applies Algorithm 1's
input normalization outside (autodiff handles it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams
from repro.kernels.taylor_direct import taylor_direct_attention
from repro.kernels.taylor_efficient import (_amod_call, _pick_chunk_factor,
                                            _readout_call, build_vhat)


# ---------------------------------------------------------------------------
# Direct backward — flash-style recompute kernels
# ---------------------------------------------------------------------------

def _dq_bwd_kernel(q_ref, gaux_ref, k_ref, v_ref, dq_ref, acc, *,
                   alpha: float, causal: bool, block_q: int, block_k: int,
                   n_seq: int, m_valid: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)                     # (bk, d)
    gaux = gaux_ref[0]                                   # (bq, d+2) fp32
    den, delta, g0 = gaux[:, 0:1], gaux[:, 1:2], gaux[:, 2:]

    x = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    gv = jax.lax.dot_general(g0, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    da = (gv - delta) / den
    if causal or m_valid < n_seq:
        kj = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_k), 1)
        if causal:
            qi = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            da = jnp.where(qi >= kj, da, 0.0)
        if m_valid < n_seq:
            da = jnp.where(kj < m_valid, da, 0.0)
    dx = da * (x + alpha ** 2)
    acc[...] += jax.lax.dot_general(dx, k, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        dq_ref[0] = acc[...].astype(dq_ref.dtype)


def _dkv_bwd_kernel(k_ref, v_ref, q_ref, gaux_ref, dk_ref, dv_ref,
                    acc_dk, acc_dv, *, alpha: float, causal: bool,
                    block_q: int, block_k: int, n_seq: int, m_valid: int):
    jk = pl.program_id(1)
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        acc_dk[...] = jnp.zeros_like(acc_dk)
        acc_dv[...] = jnp.zeros_like(acc_dv)

    k = k_ref[0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0].astype(jnp.float32)                     # (bk, d)
    q = q_ref[0].astype(jnp.float32)                     # (bq, d)
    gaux = gaux_ref[0]                                   # (bq, d+2) fp32
    den, delta, g0 = gaux[:, 0:1], gaux[:, 1:2], gaux[:, 2:]

    x = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    a = 0.5 * x * x + (alpha ** 2) * x + alpha ** 4
    gv = jax.lax.dot_general(g0, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    da = (gv - delta) / den
    if causal or m_valid < n_seq:
        kj = jk * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_k), 1)
        keep = jnp.ones_like(x, dtype=bool)
        if causal:
            qi = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            keep &= qi >= kj
        if m_valid < n_seq:
            keep &= kj < m_valid
        a = jnp.where(keep, a, 0.0)
        da = jnp.where(keep, da, 0.0)
    u = a / den
    acc_dv[...] += jax.lax.dot_general(u, g0, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dx = da * (x + alpha ** 2)
    acc_dk[...] += jax.lax.dot_general(dx, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _finish():
        dk_ref[0] = acc_dk[...].astype(dk_ref.dtype)
        dv_ref[0] = acc_dv[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret", "m_valid"))
def _direct_bwd_call(q, k, v, gaux, *, causal: bool, block_q: int,
                     block_k: int, interpret: bool, m_valid: int):
    bh, n, d = q.shape
    m = k.shape[1]
    alpha = float(d) ** 0.25
    common = dict(alpha=alpha, causal=causal, block_q=block_q,
                  block_k=block_k, n_seq=m, m_valid=m_valid)

    dq = pl.pallas_call(
        functools.partial(_dq_bwd_kernel, **common),
        grid=(bh, n // block_q, m // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q, d + 2), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, gaux, k, v)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_bwd_kernel, **common),
        grid=(bh, m // block_k, n // block_q),
        in_specs=[
            pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_q, d + 2), lambda b, j, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, m, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, m, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(k, v, q, gaux)
    return dq, dk, dv


def _direct_row_scale(n: int, d: int, causal: bool, m_valid: int):
    """sqrt(counts/d) per query row, matching the forward kernel."""
    if causal:
        counts = jnp.arange(1, n + 1, dtype=jnp.float32)
    else:
        counts = jnp.full((n,), float(m_valid), jnp.float32)
    return jnp.sqrt(counts / d)[None, :, None]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _direct_vjp(cfg, q, k, v):
    causal, block_q, block_k, out_scale, interpret, m_valid = cfg
    return taylor_direct_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        out_scale=out_scale, interpret=interpret, m_valid=m_valid)


def _direct_vjp_fwd(cfg, q, k, v):
    causal, block_q, block_k, out_scale, interpret, m_valid = cfg
    raw = taylor_direct_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        out_scale=out_scale, interpret=interpret, m_valid=m_valid, raw=True)
    den, y0 = raw[..., :1], raw[..., 1:] / raw[..., :1]
    n, d = q.shape[1], q.shape[2]
    y = y0 * _direct_row_scale(n, d, causal, m_valid) if out_scale else y0
    return y.astype(v.dtype), (q, k, v, den, y0)


def _direct_vjp_bwd(cfg, res, g):
    causal, block_q, block_k, out_scale, interpret, m_valid = cfg
    q, k, v, den, y0 = res
    n, d = q.shape[1], q.shape[2]
    g0 = g.astype(jnp.float32)
    if out_scale:
        g0 = g0 * _direct_row_scale(n, d, causal, m_valid)
    delta = jnp.sum(g0 * y0, axis=-1, keepdims=True)
    gaux = jnp.concatenate([den, delta, g0], axis=-1)
    dq, dk, dv = _direct_bwd_call(q, k, v, gaux, causal=causal,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret, m_valid=m_valid)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_direct_vjp.defvjp(_direct_vjp_fwd, _direct_vjp_bwd)


def taylor_direct_attention_vjp(q, k, v, *, causal: bool = False,
                                block_q: int = 128, block_k: int = 128,
                                out_scale: bool = True,
                                interpret: bool = False,
                                m_valid: int | None = None):
    """Differentiable fused direct-TaylorShift (custom VJP).

    Undifferentiated calls run the plain forward kernel; under jax.grad
    the flash-style backward kernels above produce dq/dk/dv without an
    N×M HBM residual.
    """
    m_valid = k.shape[1] if m_valid is None else m_valid
    cfg = (causal, min(block_q, q.shape[1]), min(block_k, k.shape[1]),
           out_scale, interpret, m_valid)
    return _direct_vjp(cfg, q, k, v)


# ---------------------------------------------------------------------------
# Efficient backward — symmetric-quadratic chunk kernel
# ---------------------------------------------------------------------------

def _sym_quad_kernel(x_ref, xc_ref, u_ref, a_ref, o1_ref, o2_ref, acc, *,
                     cf: int, d: int):
    """out_i = (M_i + M_iᵀ) x_i with M_i = mat(A u_i), streamed over cf·d
    row-chunks of A so the (N, d²) intermediate never leaves VMEM.

    Chunk c holds A rows π(a, b) for a ∈ [c·cf, (c+1)·cf):
      t = u A_cᵀ reshaped (bq, cf, d) is M_i restricted to those rows, so
      o1 (the M x term) lands directly in output columns c·cf:(c+1)·cf,
      while the Mᵀ x term needs x's *own* chunk columns and accumulates
      over chunks into o2.
    """
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0].astype(jnp.float32)                     # (bq, d)
    xc = xc_ref[0].astype(jnp.float32)                   # (bq, cf)
    u = u_ref[0].astype(jnp.float32)                     # (bq, d+1)
    a = a_ref[0]                                         # (cf·d, d+1) fp32
    t = jax.lax.dot_general(u, a, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    t = t.reshape(x.shape[0], cf, d)
    o1_ref[0] = jnp.sum(t * x[:, None, :], axis=2).astype(o1_ref.dtype)
    acc[...] += jnp.sum(t * xc[:, :, None], axis=1)

    @pl.when(ic == nc - 1)
    def _finish():
        o2_ref[0] = acc[...].astype(o2_ref.dtype)


def _sym_quad_call(x, u, a_mod, *, cf: int, block_q: int, interpret: bool):
    """(BH, N, d), (BH, N, d+1), (BH, d², d+1) -> (BH, N, d)."""
    bh, n, d = x.shape
    grid = (bh, n // block_q, d // cf)
    o1, o2 = pl.pallas_call(
        functools.partial(_sym_quad_kernel, cf=cf, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, c: (b, i, 0)),
            pl.BlockSpec((1, block_q, cf), lambda b, i, c: (b, i, c)),
            pl.BlockSpec((1, block_q, d + 1), lambda b, i, c: (b, i, 0)),
            pl.BlockSpec((1, cf * d, d + 1), lambda b, i, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, cf), lambda b, i, c: (b, i, c)),
            pl.BlockSpec((1, block_q, d), lambda b, i, c: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, x, u, a_mod)
    return o1 + o2


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _efficient_vjp(cfg, q, k, v):
    from repro.kernels.taylor_efficient import taylor_efficient_attention
    block_q, block_k, out_scale, interpret, m_valid = cfg
    return taylor_efficient_attention(
        q, k, v, block_q=block_q, block_k=block_k, out_scale=out_scale,
        interpret=interpret, m_valid=m_valid)


def _efficient_vjp_fwd(cfg, q, k, v):
    block_q, block_k, out_scale, interpret, m_valid = cfg
    bh, n, d = q.shape
    cf = _pick_chunk_factor(d)
    vh = build_vhat(v, m_valid)
    a_mod = _amod_call(k, vh, cf=cf, block_k=block_k, interpret=interpret)
    kv = jnp.einsum("bmd,bmf->bdf", k.astype(jnp.float32), vh)
    s0 = jnp.sum(vh, axis=1, keepdims=True)
    yhat = _readout_call(q, a_mod, kv, s0, cf=cf, block_q=block_q,
                         n_keys=m_valid, out_scale=False,
                         out_dtype=jnp.float32, interpret=interpret,
                         divide=False)
    den, y0 = yhat[..., :1], yhat[..., 1:] / yhat[..., :1]
    y = y0 * (float(m_valid) / d) ** 0.5 if out_scale else y0
    return y.astype(v.dtype), (q, k, v, den, y0)


def _efficient_vjp_bwd(cfg, res, g):
    block_q, block_k, out_scale, interpret, m_valid = cfg
    q, k, v, den, y0 = res
    bh, n, d = q.shape
    m = k.shape[1]
    alpha = float(d) ** 0.25
    cf = _pick_chunk_factor(d)

    g0 = g.astype(jnp.float32)
    if out_scale:
        g0 = g0 * (float(m_valid) / d) ** 0.5
    ghat = jnp.concatenate(
        [-jnp.sum(g0 * y0, axis=-1, keepdims=True) / den, g0 / den], axis=-1)

    # A_mod / KV̂ recomputed rather than saved (ISSUE: recompute-based)
    vh = build_vhat(v, m_valid)
    a_mod = _amod_call(k, vh, cf=cf, block_k=block_k, interpret=interpret)
    kv = jnp.einsum("bmd,bmf->bdf", k.astype(jnp.float32), vh)

    dA = 0.5 * _amod_call(q, ghat, cf=cf, block_k=block_q,
                          interpret=interpret)
    dKV = (alpha ** 2) * jnp.einsum("bnd,bnf->bdf", q, ghat)
    dS0 = (alpha ** 4) * jnp.sum(ghat, axis=1, keepdims=True)

    dq = 0.5 * _sym_quad_call(q, ghat, a_mod, cf=cf, block_q=block_q,
                              interpret=interpret)
    dq += (alpha ** 2) * jnp.einsum("bnf,bdf->bnd", ghat, kv)
    dk = _sym_quad_call(k, vh, dA, cf=cf, block_q=block_k,
                        interpret=interpret)
    dk += jnp.einsum("bmf,bdf->bmd", vh, dKV)
    dvh = _readout_call(k, dA, dKV, dS0, cf=cf, block_q=block_k, n_keys=m,
                        out_scale=False, out_dtype=jnp.float32,
                        interpret=interpret, coefs=(1.0, 1.0, 1.0),
                        divide=False)
    if m_valid < m:
        dvh = dvh * (jnp.arange(m) < m_valid)[None, :, None]
    dv = dvh[..., 1:]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_efficient_vjp.defvjp(_efficient_vjp_fwd, _efficient_vjp_bwd)


def taylor_efficient_attention_vjp(q, k, v, *, block_q: int = 128,
                                   block_k: int = 128,
                                   out_scale: bool = True,
                                   interpret: bool = False,
                                   m_valid: int | None = None):
    """Differentiable fused efficient-TaylorShift (custom VJP).

    Backward peak memory is O(N·d + d³): no N×N matrix and no HBM-resident
    N×d² expansion, matching the forward's linear-memory claim end-to-end.
    """
    m_valid = k.shape[1] if m_valid is None else m_valid
    cfg = (min(block_q, q.shape[1]), min(block_k, k.shape[1]),
           out_scale, interpret, m_valid)
    return _efficient_vjp(cfg, q, k, v)


__all__ = ["taylor_direct_attention_vjp", "taylor_efficient_attention_vjp"]
