"""Pure-jnp oracles for the Pallas kernels.

These delegate to repro.core.taylor — the reference implementations that
tests/test_taylor_core.py already proves equivalent to each other and to
the paper's Algorithm 1. Kernel tests assert allclose against these.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import taylor as T


def direct_ref(q, k, v, *, tau=1.0, causal=False, out_scale=True):
    """q,k,v: (BH, N, d) raw (un-normalized)."""
    return T.direct_taylorshift(q, k, v, tau=tau, causal=causal,
                                normalize_inputs=True,
                                output_scale=out_scale)


def efficient_ref(q, k, v, *, tau=1.0, out_scale=True):
    return T.efficient_taylorshift(q, k, v, tau=tau, normalize_inputs=True,
                                   output_scale=out_scale)


def amod_ref(k_scaled, v):
    """A_mod = (K^⊠2)ᵀ V̂ for already α-scaled k. (BH, N, d) -> (BH, d², d+1)."""
    ones = jnp.ones((*v.shape[:-1], 1), jnp.float32)
    vh = jnp.concatenate([ones, v.astype(jnp.float32)], axis=-1)
    k2 = T.boxtimes(k_scaled.astype(jnp.float32), k_scaled.astype(jnp.float32))
    return jnp.einsum("bne,bnf->bef", k2, vh)
