"""Observability layer (src/repro/obs/): tracer, metrics, decisions.

Three invariants matter:
  * off = free and invisible — a disabled tracer/decision-log allocates
    nothing and the instrumented code paths behave identically
    (tests/test_serve.py carries the end-to-end bit-identical-streams
    check);
  * on = well-formed — traces pass the Chrome-trace validator, the
    exposition passes the Prometheus validator, decision records carry
    the full audit schema;
  * views agree — ``EngineStats.summary()`` numbers are the registry's
    numbers, and ``since_reset`` prefix-cache deltas are
    self-consistent after ``reset_metrics()``.
"""

import json

import jax
import pytest

from repro.configs import get_config
from repro.models import backend as B
from repro.models import model as M
from repro.obs import decisions as OD
from repro.obs import validate as V
from repro.obs.metrics import (Histogram, MetricsRegistry, render_all)
from repro.obs.trace import Tracer
from repro.serve import Engine, EngineConfig, Request


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_tracer_disabled_allocates_nothing():
    tr = Tracer()
    with tr.span("outer", foo=1) as sp:
        sp.set("bar", 2)
        tr.instant("marker")
    assert tr.events == []
    # the null span is one shared singleton, not a per-call allocation
    assert tr.span("a") is tr.span("b")


def test_tracer_nesting_and_chrome_validity():
    tr = Tracer()
    tr.enable()
    with tr.span("step", step_num=0):
        with tr.span("admit"):
            pass
        with tr.span("decode", compile_key=("decode", 2), slots=2):
            pass
    with tr.span("step", step_num=1):
        with tr.span("decode", compile_key=("decode", 2)):
            pass
    doc = tr.export()
    assert V.validate_chrome_trace(
        doc, require_spans=("step", "admit", "decode")) == []
    # B/E pairs per span, in nesting order (metadata events precede)
    phs = [(e["name"], e["ph"]) for e in doc["traceEvents"]
           if e["ph"] != "M"]
    assert phs == [("step", "B"), ("admit", "B"), ("admit", "E"),
                   ("decode", "B"), ("decode", "E"), ("step", "E"),
                   ("step", "B"), ("decode", "B"), ("decode", "E"),
                   ("step", "E")]
    # first dispatch per compile_key is tagged, repeats are not
    decodes = [e for e in doc["traceEvents"]
               if e["name"] == "decode" and e["ph"] == "B"]
    assert decodes[0]["args"]["compile"] is True
    assert "compile" not in decodes[1].get("args", {})


def test_tracer_error_span_still_closes():
    tr = Tracer()
    tr.enable()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    doc = tr.export()
    assert V.validate_chrome_trace(doc) == []
    end = doc["traceEvents"][-1]
    assert end["ph"] == "E" and end["args"]["error"] == "RuntimeError"


def test_trace_validator_rejects_malformed():
    bad_nesting = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 1, "pid": 1, "tid": 1},
        {"name": "b", "ph": "E", "ts": 2, "pid": 1, "tid": 1}]}
    assert V.validate_chrome_trace(bad_nesting)
    unclosed = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 1, "pid": 1, "tid": 1}]}
    assert V.validate_chrome_trace(unclosed)
    backwards = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 5, "pid": 1, "tid": 1},
        {"name": "a", "ph": "E", "ts": 4, "pid": 1, "tid": 1}]}
    assert V.validate_chrome_trace(backwards)
    assert V.validate_chrome_trace({"traceEvents": []})


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_labels():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(3)
    assert reg.value("reqs_total") == 4
    with pytest.raises(ValueError):
        c.inc(-1)                       # counters are monotone
    # create-or-return: second registration is the same object
    assert reg.counter("reqs_total") is c
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")         # a name can never change kind

    g = reg.gauge("depth")
    g.set(5)
    g.dec(2)
    assert reg.value("depth") == 3

    fam = reg.counter("by_site_total", "per site", labelnames=("site",))
    fam.labels(site="decode").inc(2)
    fam.labels(site="prefill").inc()
    assert fam.labels(site="decode").value == 2
    text = reg.render()
    assert 'by_site_total{site="decode"} 2' in text
    assert V.validate_prometheus_text(
        text, require_metrics=("reqs_total", "depth", "by_site_total")) == []


def test_histogram_percentiles_exact_then_bucketed():
    h = Histogram(buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.2, 0.3, 0.4, 5.0):
        h.observe(v)
    assert h.quantile(0.0) == pytest.approx(0.05)
    assert h.quantile(0.5) == pytest.approx(0.3)   # exact from samples
    assert h.quantile(1.0) == pytest.approx(5.0)
    assert h.mean == pytest.approx(sum((0.05, 0.2, 0.3, 0.4, 5.0)) / 5)

    # past the cap: bucket interpolation, still monotone and bounded
    h2 = Histogram(buckets=(0.1, 1.0, 10.0))
    h2.MAX_SAMPLES = 4
    orig, Histogram.MAX_SAMPLES = Histogram.MAX_SAMPLES, 4
    try:
        for v in (0.05, 0.2, 0.3, 0.4, 5.0, 0.5):
            h2.observe(v)
    finally:
        Histogram.MAX_SAMPLES = orig
    assert len(h2.samples) < h2.count
    qs = [h2.quantile(q) for q in (0.1, 0.5, 0.9, 1.0)]
    assert qs == sorted(qs)
    assert 0.0 <= qs[0] and qs[-1] <= 10.0


def test_histogram_exposition_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    text = reg.render()
    assert V.validate_prometheus_text(text) == []
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text


def test_render_all_rejects_duplicates():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("x_total")
    b.counter("x_total")
    with pytest.raises(ValueError, match="duplicate"):
        render_all(a, b)
    c = MetricsRegistry()
    c.counter("y_total")
    assert V.validate_prometheus_text(render_all(a, c)) == []


def test_prometheus_validator_rejects_malformed():
    assert V.validate_prometheus_text("x_total{bad 1\n")      # unparseable
    assert V.validate_prometheus_text("x_total 1\n")          # no TYPE
    assert V.validate_prometheus_text(
        "# TYPE x gauge\nx NaN\n")                            # NaN
    noncum = ("# TYPE h histogram\n"
              'h_bucket{le="0.1"} 5\nh_bucket{le="+Inf"} 3\nh_count 3\n')
    assert V.validate_prometheus_text(noncum)


# ---------------------------------------------------------------------------
# Decision log
# ---------------------------------------------------------------------------

def test_select_backend_records_decisions(tmp_path):
    cfg = get_config("stablelm-1.6b").reduced()
    with OD.log.capture() as records:
        for site, n in (("full", 64), ("prefill", 64), ("decode", 1)):
            B.select_backend(cfg, N=n, d=cfg.dim_head, site=site)
        B.select_backend(cfg, N=1, d=cfg.dim_head, site="decode",
                         cache_kind="kv")
    assert not OD.log.enabled            # capture() restored the state
    assert V.validate_decision_log(records) == []
    sites = [r["site"] for r in records]
    assert sites == ["full", "prefill", "decode", "decode"]
    assert records[-1]["cache_kind"] == "kv"
    assert records[-1]["backend"] == "direct"
    for r in records:
        assert r["n0"] > r["n1"] > 0     # Eq. (7)/(9) attached to every row

    path = tmp_path / "decisions.jsonl"
    OD.log.records[:] = records
    OD.log.write_jsonl(str(path))
    assert OD.read_jsonl(str(path)) == records

    from benchmarks.crossover import audit_decision_log
    audit = audit_decision_log(records)
    assert audit["n0_n1_mismatches"] == []
    for dv in audit["divergences"]:
        assert dv["reason"]              # every divergence is explained
    OD.log.records.clear()


def test_decision_validator_rejects_malformed():
    assert V.validate_decision_log([])
    assert V.validate_decision_log([{"seq": 0}])
    good = {k: 1 for k in V.DECISION_KEYS}
    assert V.validate_decision_log([dict(good, seq=0),
                                    dict(good, seq=2)])  # not dense


# ---------------------------------------------------------------------------
# Engine integration: trace coverage, exposition, since_reset view
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_artifacts(tmp_path_factory):
    """One traced engine session; every downstream assertion reads these.

    Prompts share a prefix and the cache is on, so admission exercises
    prefix_lookup; two requests and gen=6 exercise batched decode."""
    from repro.obs.trace import tracer

    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=8, token_budget=24, max_seq_len=48,
        prefix_cache_mb=8.0))
    shared = [int(t) for t in jax.random.randint(
        jax.random.PRNGKey(5), (16,), 0, cfg.vocab)]

    def run(tag):
        for i in range(2):
            eng.submit(Request(f"{tag}{i}", shared + [7 + i],
                               max_new_tokens=6))
        for _ in eng.run():
            pass

    tracer.clear()
    tracer.enable()
    try:
        run("a")                         # cold: inserts the shared prefix
        eng.reset_metrics()
        run("b")                         # warm: hits it, post-reset
    finally:
        tracer.disable()
    doc = tracer.export()
    tracer.clear()
    return eng, doc, eng.render_metrics(), eng.stats.summary()


def test_engine_trace_covers_phases(engine_artifacts):
    _, doc, _, _ = engine_artifacts
    assert V.validate_chrome_trace(doc, require_spans=(
        "engine_step", "admit", "admission", "prefix_lookup",
        "prefill_batch", "decode_batch")) == []
    compiles = [e for e in doc["traceEvents"]
                if e.get("args", {}).get("compile")]
    assert compiles, "no first-dispatch span was tagged compile=true"
    assert json.dumps(doc)               # JSON-serializable end to end


def test_engine_trace_is_request_scoped(engine_artifacts):
    """Every request's id threads through admission → prefill → decode
    → first_token → finish, so ``request_spans`` reconstructs a full
    per-request timeline from the engine trace alone."""
    from repro.obs.trace import request_spans

    _, doc, _, _ = engine_artifacts
    for rid in ("a0", "a1", "b0", "b1"):
        spans = request_spans(doc, rid)
        names = {s["name"] for s in spans}
        assert {"admission", "prefix_lookup", "decode_batch",
                "first_token", "finish"} <= names, \
            f"{rid}: incomplete timeline {sorted(names)}"
        # prefill shows up either as pooled per-slot markers (cold) or
        # per-chunk spans (cache-resumed suffix)
        assert names & {"prefill_slot", "prefill_chunk"}, \
            f"{rid}: no prefill attribution in {sorted(names)}"
        ts = [s["ts"] for s in spans]
        assert ts == sorted(ts)
        # admission precedes first_token precedes finish
        order = [s["name"] for s in spans]
        assert order.index("admission") < order.index("first_token") \
            < order.index("finish")


def test_engine_trace_process_metadata(engine_artifacts):
    """Exported docs carry emit-time pids plus process/thread metadata
    events — the fix for multi-process traces aliasing onto one track."""
    import os

    _, doc, _, _ = engine_artifacts
    assert {e["pid"] for e in doc["traceEvents"]} == {os.getpid()}
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in metas)
    assert any(e["name"] == "thread_name" for e in metas)


def test_engine_exposition_valid(engine_artifacts):
    _, _, text, _ = engine_artifacts
    assert V.validate_prometheus_text(text, require_metrics=(
        "engine_steps_total", "engine_decode_tokens_total",
        "engine_ttft_seconds", "engine_itl_seconds",
        "prefix_cache_lookups_total", "prefix_cache_hits_total",
        "scheduler_plans_total")) == []


def test_summary_is_registry_view(engine_artifacts):
    eng, _, _, s = engine_artifacts
    reg = eng.stats.registry
    assert s["decode_tokens"] == reg.value("engine_decode_tokens_total")
    assert s["completed_requests"] == reg.value(
        "engine_completed_requests_total")
    for k in ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
              "itl_p50_s", "itl_p95_s", "itl_p99_s"):
        assert k in s and s[k] >= 0.0
    assert s["ttft_p50_s"] <= s["ttft_p95_s"] <= s["ttft_p99_s"]


def test_prefix_cache_since_reset_self_consistent(engine_artifacts):
    """Post-reset summaries must be self-consistent: the lifetime
    counters keep the cold run's traffic, since_reset holds only the
    warm run's — and its hit_rate is computed from its own deltas."""
    eng, _, _, s = engine_artifacts
    pc = s["prefix_cache"]
    sr = pc["since_reset"]
    assert sr["lookups"] == 2 and sr["hits"] == 2
    assert sr["hit_rate"] == pytest.approx(1.0)
    assert pc["lookups"] == 4            # lifetime: cold misses + warm hits
    assert pc["hits"] == 2
    assert sr["inserts"] == 0            # warm run inserted nothing new
    assert pc["inserts"] >= 1


def test_itl_tracked_per_request(engine_artifacts):
    """Each request's per-token gaps land in its result and the
    histogram: 2 runs x 2 requests x (6 tokens - 1 first) = 20 gaps
    lifetime, 10 since the reset."""
    eng, _, _, s = engine_artifacts
    assert len(eng.stats.itls) == 10     # registry was reset mid-session
    for res in eng.results.values():
        assert len(res.itls) == 5
        assert all(g >= 0.0 for g in res.itls)


# ---------------------------------------------------------------------------
# Serving benchmark document schema
# ---------------------------------------------------------------------------

def _cell():
    return {"batch": 2, "prompt_len": 64, "gen_len": 16,
            "naive_tok_s": 10.0, "engine_tok_s": 20.0,
            "engine_kv_tok_s": 15.0, "speedup_vs_naive": 2.0,
            "ttft_mean_s": 0.1, "ttft_p50_s": 0.1, "ttft_p95_s": 0.2,
            "ttft_p99_s": 0.2, "itl_p50_s": 0.01, "itl_p95_s": 0.02,
            "itl_p99_s": 0.02}


def test_serving_doc_schema():
    from benchmarks.run import validate_serving_doc

    doc = {"name": "serving_throughput", "config": {}, "cells": [_cell()]}
    assert validate_serving_doc(doc) == []

    missing = {"name": "serving_throughput", "config": {},
               "cells": [{k: v for k, v in _cell().items()
                          if k != "itl_p99_s"}]}
    assert any("itl_p99_s" in p for p in validate_serving_doc(missing))

    nan = {"name": "serving_throughput", "config": {},
           "cells": [dict(_cell(), engine_tok_s=float("nan"))]}
    assert any("non-finite" in p for p in validate_serving_doc(nan))

    spec_missing_ledger = {
        "name": "serving_decode_heavy", "config": {},
        "cells": [{"batch": 1, "drafter": "ngram", "speculate_k": 4,
                   "tok_s": 5.0, "speedup": 1.2}]}
    assert any("acceptance_rate" in p
               for p in validate_serving_doc(spec_missing_ledger))

    assert validate_serving_doc({"name": "nope"})
