"""Correctness of the core TaylorShift algorithms.

The paper's central mathematical claim — direct- and efficient-TaylorShift
compute the *same* function — is asserted here to tight tolerance, along
with the causal/chunked/recurrent extensions.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.taylor import (
    TaylorState,
    boxtimes,
    causal_direct_taylorshift,
    causal_taylorshift,
    crossover_n0,
    crossover_n1,
    direct_taylorshift,
    efficient_taylorshift,
    entries_direct,
    entries_efficient,
    ops_direct,
    ops_efficient,
    pick_mode,
    taylor_decode_step,
    taylor_softmax,
    taylorshift_attention,
)

jax.config.update("jax_enable_x64", False)


def rand_qkv(key, b, h, n, d, dtype=jnp.float32):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, n, d), dtype)
    k = jax.random.normal(kk, (b, h, n, d), dtype)
    v = jax.random.normal(kv, (b, h, n, d), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# Taylor softmax basics
# ---------------------------------------------------------------------------

class TestTaylorSoftmax:
    def test_rows_sum_to_one(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        s = taylor_softmax(x)
        np.testing.assert_allclose(jnp.sum(s, -1), jnp.ones(4), rtol=1e-6)

    def test_positive_for_even_order(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (128,)) * 10
        assert jnp.all(taylor_softmax(x) > 0)  # 1 + x + x²/2 > 0 ∀x

    def test_approximates_softmax_for_small_logits(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 32)) * 0.1
        np.testing.assert_allclose(
            taylor_softmax(x), jax.nn.softmax(x, -1), atol=1e-3)


# ---------------------------------------------------------------------------
# Paper §3: direct == efficient (the core identity)
# ---------------------------------------------------------------------------

class TestDirectEfficientEquivalence:
    @pytest.mark.parametrize("d", [4, 8, 16, 32, 64])
    @pytest.mark.parametrize("n", [16, 128])
    def test_equivalence(self, n, d):
        q, k, v = rand_qkv(jax.random.PRNGKey(d * 1000 + n), 2, 3, n, d)
        y_dir = direct_taylorshift(q, k, v, tau=1.7)
        y_eff = efficient_taylorshift(q, k, v, tau=1.7)
        np.testing.assert_allclose(y_dir, y_eff, rtol=2e-4, atol=2e-4)

    def test_equivalence_no_output_scale(self):
        q, k, v = rand_qkv(jax.random.PRNGKey(7), 1, 2, 64, 16)
        y_dir = direct_taylorshift(q, k, v, output_scale=False)
        y_eff = efficient_taylorshift(q, k, v, output_scale=False)
        np.testing.assert_allclose(y_dir, y_eff, rtol=2e-4, atol=2e-4)

    def test_cross_attention_shapes(self):
        key = jax.random.PRNGKey(3)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (2, 4, 32, 16))
        k = jax.random.normal(kk, (2, 4, 80, 16))
        v = jax.random.normal(kv, (2, 4, 80, 16))
        y_dir = direct_taylorshift(q, k, v)
        y_eff = efficient_taylorshift(q, k, v)
        assert y_dir.shape == (2, 4, 32, 16)
        np.testing.assert_allclose(y_dir, y_eff, rtol=2e-4, atol=2e-4)

    def test_per_head_tau_vector(self):
        q, k, v = rand_qkv(jax.random.PRNGKey(9), 2, 4, 32, 8)
        tau = jnp.array([0.5, 1.0, 2.0, 4.0]).reshape(1, 4, 1, 1)
        y_dir = direct_taylorshift(q, k, v, tau=tau)
        y_eff = efficient_taylorshift(q, k, v, tau=tau)
        np.testing.assert_allclose(y_dir, y_eff, rtol=2e-4, atol=2e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(4, 96),
        d=st.sampled_from([2, 4, 8, 16]),
        tau=st.floats(0.25, 4.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_equivalence_property(self, n, d, tau, seed):
        q, k, v = rand_qkv(jax.random.PRNGKey(seed), 1, 1, n, d)
        y_dir = direct_taylorshift(q, k, v, tau=tau)
        y_eff = efficient_taylorshift(q, k, v, tau=tau)
        np.testing.assert_allclose(y_dir, y_eff, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# Causal extensions (beyond paper): chunked == masked direct == decode
# ---------------------------------------------------------------------------

class TestCausal:
    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_chunked_matches_direct(self, chunk):
        q, k, v = rand_qkv(jax.random.PRNGKey(11), 2, 2, 64, 16)
        y_ref = causal_direct_taylorshift(q, k, v, tau=1.3)
        y_chk = causal_taylorshift(q, k, v, tau=1.3, chunk=chunk)
        np.testing.assert_allclose(y_ref, y_chk, rtol=2e-4, atol=2e-4)

    def test_chunk_size_equals_n(self):
        q, k, v = rand_qkv(jax.random.PRNGKey(12), 1, 1, 32, 8)
        y_ref = causal_direct_taylorshift(q, k, v)
        y_chk = causal_taylorshift(q, k, v, chunk=32)
        np.testing.assert_allclose(y_ref, y_chk, rtol=2e-4, atol=2e-4)

    def test_decode_matches_prefill(self):
        """Token-by-token recurrent decode == full causal attention."""
        b, h, n, d = 1, 2, 24, 8
        q, k, v = rand_qkv(jax.random.PRNGKey(13), b, h, n, d)
        y_full = causal_direct_taylorshift(q, k, v, tau=0.9)
        state = TaylorState.zeros((b, h), d)
        ys = []
        for t in range(n):
            y_t, state = taylor_decode_step(
                state, q[:, :, t:t+1], k[:, :, t:t+1], v[:, :, t:t+1], tau=0.9)
            ys.append(y_t)
        y_dec = jnp.concatenate(ys, axis=2)
        np.testing.assert_allclose(y_full, y_dec, rtol=5e-4, atol=5e-4)

    def test_prefill_state_then_decode(self):
        """Chunked prefill state hands off exactly to the decode step."""
        b, h, n, d = 1, 2, 32, 8
        q, k, v = rand_qkv(jax.random.PRNGKey(14), b, h, n + 1, d)
        y_full = causal_direct_taylorshift(q, k, v, tau=1.1)
        _, state = causal_taylorshift(
            q[:, :, :n], k[:, :, :n], v[:, :, :n], tau=1.1, chunk=8,
            return_state=True)
        y_last, _ = taylor_decode_step(
            state, q[:, :, n:], k[:, :, n:], v[:, :, n:], tau=1.1)
        np.testing.assert_allclose(
            y_full[:, :, -1:], y_last, rtol=5e-4, atol=5e-4)
        assert int(state.n) == n

    def test_chunked_prefill_continuation(self):
        """Two chunked calls chained via state == one big call."""
        b, h, d = 2, 1, 8
        q, k, v = rand_qkv(jax.random.PRNGKey(15), b, h, 48, d)
        y_full = causal_taylorshift(q, k, v, chunk=8)
        y1, st = causal_taylorshift(q[:, :, :16], k[:, :, :16], v[:, :, :16],
                                    chunk=8, return_state=True)
        y2 = causal_taylorshift(q[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
                                chunk=8, initial_state=st)
        np.testing.assert_allclose(
            y_full, jnp.concatenate([y1, y2], 2), rtol=5e-4, atol=5e-4)

    def test_causality(self):
        """Perturbing future tokens must not change past outputs."""
        q, k, v = rand_qkv(jax.random.PRNGKey(16), 1, 1, 32, 8)
        y1 = causal_taylorshift(q, k, v, chunk=8)
        k2 = k.at[:, :, 20:].set(jax.random.normal(jax.random.PRNGKey(1),
                                                   k[:, :, 20:].shape))
        y2 = causal_taylorshift(q, k2, v, chunk=8)
        np.testing.assert_allclose(y1[:, :, :20], y2[:, :, :20],
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Paper §4: crossover formulas (Table 2 values) and auto dispatch
# ---------------------------------------------------------------------------

class TestCrossover:
    def test_table2_values_d128(self):
        # Paper Table 2 prints N0=16513, N1=8446 for d=128.
        assert round(crossover_n0(128)) == 16513
        assert round(crossover_n1(128)) == 8446

    @pytest.mark.parametrize("d", [8, 16, 32, 64, 128])
    def test_bounds(self, d):
        assert crossover_n0(d) <= d * d + d + 0.75            # Eq. (7)
        assert crossover_n1(d) <= 0.5 * d * d + 2 * d + 0.5   # Eq. (9)
        assert crossover_n1(d) < crossover_n0(d)              # §4.2 remark

    @pytest.mark.parametrize("d", [8, 16, 32, 64, 128])
    def test_flop_model_consistency(self, d):
        n0 = crossover_n0(d)
        lo, hi = int(n0 * 0.9), int(n0 * 1.1)
        assert ops_direct(lo, d) < ops_efficient(lo, d)
        assert ops_direct(hi, d) > ops_efficient(hi, d)
        n1 = crossover_n1(d)
        lo, hi = int(n1 * 0.9), int(n1 * 1.1) + 2
        assert entries_direct(lo, d) < entries_efficient(lo, d)
        assert entries_direct(hi, d) > entries_efficient(hi, d)

    def test_pick_mode(self):
        assert pick_mode(512, 64) == "direct"
        assert pick_mode(8192, 64) == "efficient"
        assert pick_mode(4096, 64, optimize_for="memory") == "efficient"

    def test_auto_dispatch_matches_both(self):
        q, k, v = rand_qkv(jax.random.PRNGKey(17), 1, 1, 32, 4)
        y_auto = taylorshift_attention(q, k, v, mode="auto")
        y_dir = taylorshift_attention(q, k, v, mode="direct")
        np.testing.assert_allclose(y_auto, y_dir, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Numerical stability (paper §3.3 / App. B.1)
# ---------------------------------------------------------------------------

class TestStability:
    def test_large_inputs_stable_with_normalization(self):
        q, k, v = rand_qkv(jax.random.PRNGKey(18), 1, 1, 256, 16)
        q, k = q * 1e3, k * 1e3  # would overflow the naive formulation
        y = efficient_taylorshift(q, k, v, tau=1.0)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_bf16_inputs_fp32_internals(self):
        q, k, v = rand_qkv(jax.random.PRNGKey(19), 1, 2, 128, 16,
                           dtype=jnp.bfloat16)
        y_eff = efficient_taylorshift(q, k, v)
        y_dir = direct_taylorshift(q, k, v)
        assert y_eff.dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(y_eff.astype(jnp.float32))))
        np.testing.assert_allclose(
            y_eff.astype(jnp.float32), y_dir.astype(jnp.float32),
            rtol=0.1, atol=0.1)

    def test_long_sequence_decode_state_fp32(self):
        """State sums stay finite after many tokens (raw-sum convention)."""
        b, h, d = 1, 1, 8
        state = TaylorState.zeros((b, h), d)
        key = jax.random.PRNGKey(20)

        @jax.jit
        def step(state, key):
            q, k, v = rand_qkv(key, b, h, 1, d)
            y, state = taylor_decode_step(state, q, k, v)
            return state, y

        for i in range(50):
            state, y = step(state, jax.random.fold_in(key, i))
        assert bool(jnp.all(jnp.isfinite(state.s2)))
        assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# boxtimes algebra
# ---------------------------------------------------------------------------

class TestBoxtimes:
    def test_identity(self):
        """[A^⊠2]_{n,π(k,l)} = A_{nk} A_{nl} (paper §3.2)."""
        a = jax.random.normal(jax.random.PRNGKey(21), (5, 3))
        b2 = boxtimes(a, a)
        for n in range(5):
            np.testing.assert_allclose(
                b2[n].reshape(3, 3), jnp.outer(a[n], a[n]), rtol=1e-6)

    def test_linearization_identity(self):
        """(QKᵀ)^⊙2 == Q^⊠2 (K^⊠2)ᵀ — the paper's key algebraic step."""
        q = jax.random.normal(jax.random.PRNGKey(22), (7, 4))
        k = jax.random.normal(jax.random.PRNGKey(23), (9, 4))
        lhs = (q @ k.T) ** 2
        rhs = boxtimes(q, q) @ boxtimes(k, k).T
        np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)


class TestGQABroadcast:
    """GQA passes k/v with broadcastable lead dims: (B, KV, 1, N, d) vs
    q (B, KV, G, N, d) — the chunked causal path must handle it."""

    def test_causal_chunked_gqa(self):
        b, kv, g, n, d = 2, 2, 3, 32, 8
        key = jax.random.PRNGKey(31)
        q = jax.random.normal(key, (b, kv, g, n, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, 1, n, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, 1, n, d))
        y = causal_taylorshift(q, k, v, chunk=8)
        assert y.shape == (b, kv, g, n, d)
        y_ref = causal_direct_taylorshift(
            q, jnp.broadcast_to(k, q.shape), jnp.broadcast_to(v, q.shape))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=5e-4, atol=5e-4)

    def test_efficient_gqa(self):
        b, kv, g, n, d = 1, 2, 4, 64, 8
        key = jax.random.PRNGKey(33)
        q = jax.random.normal(key, (b, kv, g, n, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, 1, n, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, 1, n, d))
        y = efficient_taylorshift(q, k, v)
        y_ref = direct_taylorshift(q, jnp.broadcast_to(k, q.shape),
                                   jnp.broadcast_to(v, q.shape))
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=5e-4, atol=5e-4)
