"""Paper Table 1 / App. B.2: scaling behavior of intermediate tensors.

The normalization scheme is derived from how intermediates grow with N
and d; we validate the *growth laws* as property tests (the paper fits
the same laws empirically — its App. B.2 reports ≤1% error for large N).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import taylor as T


def unit_rows(key, n, d):
    x = jax.random.normal(key, (n, d))
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def amod_fro(key, n, d):
    k = unit_rows(key, n, d)
    v = unit_rows(jax.random.fold_in(key, 1), n, d)
    vh = jnp.concatenate([jnp.ones((n, 1)), v], -1)
    am = T.boxtimes(k, k).T @ vh
    return float(jnp.sqrt(jnp.sum(am * am)))


class TestTable1ScalingLaws:
    @pytest.mark.parametrize("d", [8, 16])
    def test_amod_linear_in_n(self, d):
        """|A_mod| ~ (N+1)/sqrt(d): doubling N doubles the norm."""
        key = jax.random.PRNGKey(d)
        r = amod_fro(key, 2048, d) / amod_fro(key, 1024, d)
        assert 1.7 < r < 2.3, r

    def test_amod_decreases_with_d(self):
        """|A_mod| ~ 1/sqrt(d) at fixed N."""
        key = jax.random.PRNGKey(0)
        n = 2048
        a8 = amod_fro(key, n, 8)
        a32 = amod_fro(key, n, 32)
        # sqrt(32/8) = 2; allow generous tolerance for the constant
        assert 1.4 < a8 / a32 < 2.9, a8 / a32

    @pytest.mark.parametrize("d", [8, 16])
    def test_output_scale_without_norm_is_sqrt_d_over_n(self, d):
        """|Y| ~ sqrt(d/N) pre-output-scaling (Table 1, last column):
        the paper multiplies by sqrt(N/d) to undo exactly this."""
        key = jax.random.PRNGKey(d + 100)
        sizes = {}
        for n in (256, 1024):
            q = unit_rows(key, n, d)[None, None]
            k = unit_rows(jax.random.fold_in(key, 1), n, d)[None, None]
            v = unit_rows(jax.random.fold_in(key, 2), n, d)[None, None]
            y = T.efficient_taylorshift(q, k, v, normalize_inputs=False,
                                        output_scale=False)
            sizes[n] = float(jnp.mean(jnp.linalg.norm(y[0, 0], axis=-1)))
        # N x4 => |Y| halves (asymptotic; d=8 sits off the large-N
        # asymptote the paper fits, so the band is generous above)
        r = sizes[256] / sizes[1024]
        assert 1.5 < r < 3.2, r

    def test_output_scale_normalizes_mean_size(self):
        """The sqrt(N/d) output scaling (§3.3) undoes the sqrt(d/N) decay:
        WITHOUT it |Y| falls ~sqrt(1/N); WITH it |Y| is ~N-independent.
        Averaged over seeds (single draws of the Taylor-weighted mean of
        unit vectors are heavy-tailed)."""
        d = 16

        def mean_size(n, scale, seeds=6):
            tot = 0.0
            for s in range(seeds):
                key = jax.random.PRNGKey(7 + s)
                q = jax.random.normal(key, (1, 1, n, d))
                k = jax.random.normal(jax.random.fold_in(key, 1),
                                      (1, 1, n, d))
                v = unit_rows(jax.random.fold_in(key, 2), n, d)[None, None]
                y = T.efficient_taylorshift(q, k, v, output_scale=scale)
                tot += float(jnp.mean(jnp.linalg.norm(y[0, 0], axis=-1)))
            return tot / seeds

        r_without = mean_size(2048, False) / mean_size(256, False)
        r_with = mean_size(2048, True) / mean_size(256, True)
        assert r_without < 0.6, r_without        # ~ sqrt(256/2048) = 0.35
        assert 0.45 < r_with < 2.2, r_with       # ~ constant

    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([128, 256, 512]), d=st.sampled_from([4, 8, 16]),
           seed=st.integers(0, 1000))
    def test_denominator_positive(self, n, d, seed):
        """Y_denom > 0 always (Taylor numerator is positive) — division
        is safe at any scale after normalization."""
        key = jax.random.PRNGKey(seed)
        q = jax.random.normal(key, (1, 1, n, d)) * 100
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, n, d)) * 100
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, n, d))
        y = T.efficient_taylorshift(q, k, v)
        assert bool(jnp.all(jnp.isfinite(y)))
