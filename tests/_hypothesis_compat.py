"""Optional-`hypothesis` shim.

`hypothesis` is a declared dev dependency (see pyproject.toml /
requirements-dev.txt) but may be absent in minimal environments. Test
modules import `given, settings, st` from here: with hypothesis
installed they are the real thing; without it, property tests are
skipped individually and every non-property test in the module still
runs (a module-level `pytest.importorskip` would throw those away too).
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:                       # pragma: no cover — CI installs it
    import pytest

    class _Strategy:
        """Stands in for `st.<anything>(...)` at decoration time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _Strategy()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped(*a, **k):  # pragma: no cover
                pass

            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped

        return deco
