"""Shared-prefix state cache (serve/prefix_cache.py).

The load-bearing invariant: **a cache hit never changes emitted
tokens**. Entries sit on the full-prefill-chunk grid, so a resumed
suffix runs exactly the chunk decomposition a cold prefill would run
after the same boundary — same float ops, same order, bit-identical
streams. The engine-level tests pin that for greedy and seeded
sampling, speculation on and off, and both cache kinds; the trie unit
tests pin lookup/insert/LRU/byte-budget semantics without any jax
arrays in the loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve import Engine, EngineConfig, Request
from repro.serve.pool import StatePool
from repro.serve.prefix_cache import PrefixCache, tree_nbytes


# ---------------------------------------------------------------------------
# Trie unit tests (no model, no engine)
# ---------------------------------------------------------------------------

def _arr(n_floats):
    return np.zeros((n_floats,), np.float32)


def _mk(chunk=4, budget=0, max_entries=0):
    return PrefixCache(chunk, budget_bytes=budget, max_entries=max_entries)


def test_lookup_returns_longest_cached_prefix():
    pc = _mk(chunk=4)
    prompt = list(range(16))
    assert pc.lookup(prompt) is None
    pc.insert(prompt, 4, _arr(1), _arr(1))
    pc.insert(prompt, 12, _arr(1), _arr(1))
    hit = pc.lookup(prompt)
    assert hit.n_tokens == 12
    # a diverging prompt only matches through the shared chunks
    other = prompt[:8] + [99] * 8
    assert pc.lookup(other).n_tokens == 4      # 8-boundary was never cached
    pc.insert(other, 8, _arr(1), _arr(1))
    assert pc.lookup(other).n_tokens == 8      # shared chunk grid, own branch
    assert pc.lookup(prompt).n_tokens == 12    # original branch untouched


def test_insert_rejects_off_grid_boundaries():
    pc = _mk(chunk=4)
    prompt = list(range(10))
    assert not pc.insert(prompt, 3, _arr(1), _arr(1))    # mid-chunk
    assert not pc.insert(prompt, 10, _arr(1), _arr(1))   # pow2-tail boundary
    assert not pc.insert(prompt, 0, _arr(1), _arr(1))
    assert not pc.insert(prompt, 12, _arr(1), _arr(1))   # beyond the prompt
    assert pc.insert(prompt, 8, _arr(1), _arr(1))
    assert pc.stats()["entries"] == 1


def test_full_prompt_boundary_is_cacheable():
    """A boundary covering the whole prompt is a valid entry — the
    full-hit path samples the first token from its stored logits."""
    pc = _mk(chunk=4)
    prompt = list(range(8))
    assert pc.insert(prompt, 8, _arr(1), _arr(2))
    assert pc.lookup(prompt).n_tokens == 8


def test_duplicate_insert_keeps_canonical_entry():
    pc = _mk(chunk=4)
    prompt = list(range(8))
    first = _arr(1)
    pc.insert(prompt, 4, first, _arr(1))
    pc.insert(prompt, 4, _arr(1), _arr(1))
    assert pc.lookup(prompt).state is first
    s = pc.stats()
    assert s["inserts"] == 1 and s["duplicate_inserts"] == 1
    assert s["entries"] == 1


def test_lru_eviction_under_byte_budget():
    entry_bytes = 2 * 4                       # state + logits, 4B floats
    pc = _mk(chunk=2, budget=3 * entry_bytes)
    prompts = [[i, i] for i in range(4)]
    for p in prompts[:3]:
        assert pc.insert(p, 2, _arr(1), _arr(1))
    assert pc.stats()["entries"] == 3
    pc.lookup(prompts[0])                     # refresh: 0 is now MRU
    assert pc.insert(prompts[3], 2, _arr(1), _arr(1))
    s = pc.stats()
    assert s["entries"] == 3 and s["evictions"] == 1
    assert pc.lookup(prompts[1]) is None      # LRU victim
    assert pc.lookup(prompts[0]) is not None  # refreshed entry survived
    assert pc.lookup(prompts[3]) is not None  # newest entry survived
    assert s["bytes"] == 3 * entry_bytes


def test_eviction_prunes_trie_paths():
    pc = _mk(chunk=2, max_entries=1)
    pc.insert([1, 2, 3, 4], 4, _arr(1), _arr(1))   # deep entry: 2 nodes
    pc.insert([5, 6], 2, _arr(1), _arr(1))         # evicts the deep one
    assert pc.lookup([1, 2, 3, 4]) is None
    assert not pc.root.children.get((1, 2))        # skeleton path pruned
    assert pc.lookup([5, 6]) is not None


def test_oversized_entry_is_refused():
    pc = _mk(chunk=2, budget=4)
    assert not pc.insert([1, 2], 2, _arr(64), _arr(1))
    assert pc.stats()["entries"] == 0
    # and the refusal happens BEFORE any trie path is built — a budget
    # smaller than one entry must not leak skeleton nodes per prompt
    assert not pc.root.children


def test_engine_rejects_mismatched_chunk_tokens():
    """Any trie granularity other than prefill_chunk would let pow2
    tail chunks form off-grid boundaries (bit-identity break) — the
    engine refuses it up front."""
    from repro.configs.base import PrefixCacheConfig

    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="chunk_tokens"):
        Engine(cfg, params, EngineConfig(
            n_slots=1, prefill_chunk=8, max_seq_len=64,
            prefix_cache_mb=1.0, prefix=PrefixCacheConfig(chunk_tokens=4)))


def test_cli_workload_full_overlap_fits_max_seq_len():
    """--shared-prefix 1.0 (the repeated-prompt limit) must produce
    prompts the engine accepts under max_seq_len = prompt_len + gen + 1."""
    from repro.launch.serve import mixed_arrival_workload

    cfg = get_config("stablelm-1.6b").reduced()
    for frac in (0.0, 0.7, 1.0):
        reqs, _ = mixed_arrival_workload(cfg, 4, 24, 6, shared_frac=frac)
        assert all(1 <= len(r.prompt) <= 24 for r in reqs)


def test_clear_drops_entries_not_counters():
    pc = _mk(chunk=2)
    pc.insert([1, 2], 2, _arr(1), _arr(1))
    pc.lookup([1, 2])
    pc.clear()
    assert pc.lookup([1, 2]) is None
    s = pc.stats()
    assert s["entries"] == 0 and s["bytes"] == 0 and s["inserts"] == 1


# ---------------------------------------------------------------------------
# Engine-level bit-identity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _toks(cfg, n, seed):
    return [int(t) for t in jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, cfg.vocab)]


def _engine(cfg, params, *, cache_mb, cache_kind="taylor", speculate_k=0,
            n_slots=2, temperature=0.0):
    return Engine(cfg, params, EngineConfig(
        n_slots=n_slots, prefill_chunk=8, token_budget=32, max_seq_len=64,
        cache_kind=cache_kind, temperature=temperature,
        speculate_k=speculate_k, prefix_cache_mb=cache_mb))


def _shared_prefix_requests(cfg, **req_kw):
    """Three requests sharing a 16-token (2-chunk) prefix; the third
    repeats the first prompt exactly (full-prompt-hit candidate)."""
    prefix = _toks(cfg, 16, seed=100)
    reqs = [Request("a", prefix + _toks(cfg, 7, seed=101), 6, **req_kw),
            Request("b", prefix + _toks(cfg, 5, seed=102), 6, **req_kw),
            Request("c", prefix + _toks(cfg, 7, seed=101), 6, **req_kw)]
    return reqs


@pytest.mark.slow
@pytest.mark.parametrize("cache_kind", ["taylor", "kv"])
@pytest.mark.parametrize("speculate_k", [0, 2])
def test_cache_hit_streams_bit_identical(setup, cache_kind, speculate_k):
    """Greedy streams with the prefix cache on == streams with it off,
    for both cache kinds, speculation on and off. Sequential submission
    maximizes hits (later requests see earlier boundaries)."""
    cfg, params = setup
    reqs = _shared_prefix_requests(cfg)

    def run(cache_mb):
        eng = _engine(cfg, params, cache_mb=cache_mb, cache_kind=cache_kind,
                      speculate_k=speculate_k, n_slots=1)
        out = {}
        for r in reqs:              # one at a time: every later request
            out.update(eng.generate([Request(r.request_id, r.prompt,
                                             r.max_new_tokens)]))
            eng.results.clear()
        return out, eng

    cold, _ = run(0.0)
    hot, eng = run(-1.0)
    assert cold == hot
    s = eng.prefix_cache.stats()
    assert s["hits"] >= 2 and s["hit_tokens"] >= 2 * 16


@pytest.mark.slow
def test_full_prompt_hit_skips_prefill_entirely(setup):
    """An exact repeated prompt (length on the chunk grid) resumes with
    zero prefill dispatches: the slot is seeded straight from the
    snapshot and the first token comes from the cached boundary
    logits."""
    cfg, params = setup
    prompt = _toks(cfg, 16, seed=200)          # 16 = 2 full chunks of 8
    eng = _engine(cfg, params, cache_mb=-1.0, n_slots=1)
    first = eng.generate([Request("x", prompt, max_new_tokens=5)])["x"]
    n_steps = len(eng.stats.steps)
    second = eng.generate([Request("y", prompt, max_new_tokens=5)])["y"]
    assert first == second
    steps = eng.stats.steps[n_steps:]
    assert sum(m.prefill_tokens for m in steps) == 0
    assert sum(m.cached_prefix_tokens for m in steps) == len(prompt)
    # and the cold-baseline engine agrees
    ref = _engine(cfg, params, cache_mb=0.0, n_slots=1)
    assert ref.generate([Request("z", prompt, max_new_tokens=5)])["z"] == first


@pytest.mark.slow
def test_seeded_sampling_reproducible_across_cache(setup):
    """Per-request sampling is keyed on (seed, request_id, index) — a
    cache hit must not move any sampled token either."""
    cfg, params = setup
    reqs = _shared_prefix_requests(cfg, temperature=0.9, top_k=8)

    def run(cache_mb):
        eng = _engine(cfg, params, cache_mb=cache_mb, n_slots=1)
        out = {}
        for r in reqs:
            out.update(eng.generate(
                [Request(r.request_id, r.prompt, r.max_new_tokens,
                         temperature=0.9, top_k=8)]))
            eng.results.clear()
        return out

    assert run(0.0) == run(-1.0)


@pytest.mark.slow
def test_concurrent_sequences_share_one_entry_safely(setup):
    """Two sequences resuming from the same cached entry, decoding and
    speculating concurrently, must not alias: snapshots are immutable,
    so each functionally updates its own state."""
    cfg, params = setup
    prefix = _toks(cfg, 16, seed=300)
    pa, pb = prefix + _toks(cfg, 6, seed=301), prefix + _toks(cfg, 4, seed=302)

    warm = _engine(cfg, params, cache_mb=-1.0, speculate_k=2, n_slots=2)
    warm.generate([Request("seed", prefix + [1, 2], max_new_tokens=1)])
    warm.results.clear()
    hot = warm.generate([Request("a", pa, max_new_tokens=6),
                         Request("b", pb, max_new_tokens=6)])
    assert warm.prefix_cache.stats()["hits"] >= 2

    ref = _engine(cfg, params, cache_mb=0.0, speculate_k=2, n_slots=2)
    assert ref.generate([Request("a", pa, max_new_tokens=6),
                         Request("b", pb, max_new_tokens=6)]) == hot


@pytest.mark.slow
def test_tiny_budget_still_correct(setup):
    """A budget too small to hold anything useful degrades to a cold
    engine — never to wrong tokens."""
    cfg, params = setup
    reqs = _shared_prefix_requests(cfg)
    cold = _engine(cfg, params, cache_mb=0.0, n_slots=1)
    tiny = _engine(cfg, params, cache_mb=1e-4, n_slots=1)   # ~100 bytes
    for r in reqs:
        a = cold.generate([Request(r.request_id, r.prompt, 6)])
        b = tiny.generate([Request(r.request_id, r.prompt, 6)])
        assert a == b
        cold.results.clear(), tiny.results.clear()


# ---------------------------------------------------------------------------
# prefill_from_state: the per-slot (pool-seeded) generalization
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_prefill_from_state_per_slot_matches_private_resume(setup):
    """Seeding a cold pool slot straight from a snapshot and absorbing
    the suffix with per-slot counters (the verify body) must agree with
    the private scalar-counter resume (the prefill body) — the
    generalization ``models.model.prefill_from_state`` dispatches on."""
    cfg, params = setup
    prompt = jnp.asarray([_toks(cfg, 12, seed=400)], jnp.int32)

    # prefix state: absorb 8 tokens into a fresh single-sequence cache
    cache = M.init_decode_state(cfg, 1, cache_len=32, cache_kind="taylor",
                                dtype=jnp.float32)
    _, snap = M.prefill_from_state(params, cfg,
                                   {"tokens": prompt[:, :8]}, cache)

    # scalar-counter resume (what the engine runs on a cache hit)
    lg_priv, cache_priv = M.prefill_from_state(
        params, cfg, {"tokens": prompt[:, 8:]}, snap)

    # per-slot resume: scatter the snapshot into slot 1 of a pool and
    # absorb the suffix from the gathered per-slot view
    pool = StatePool(cfg, 3, cache_len=32, cache_kind="taylor")
    pool.scatter(snap, 1)
    sub = pool.gather(1)
    assert sub["pos"].ndim == 1               # (1,) per-slot counter
    lg_slot, sub = M.prefill_from_state(params, cfg,
                                        {"tokens": prompt[:, 8:]}, sub)
    np.testing.assert_allclose(np.asarray(lg_priv), np.asarray(lg_slot),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache_priv["pos"]),
                               np.asarray(sub["pos"]))


def test_tree_nbytes_counts_every_leaf():
    tree = {"a": np.zeros((4, 2), np.float32), "b": [np.zeros(3, np.int32)]}
    assert tree_nbytes(tree) == 4 * 2 * 4 + 3 * 4


# ---------------------------------------------------------------------------
# Batched multi-slot prefill (pool-resident, taylor pools)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_batched_prefill_streams_bit_identical(setup):
    """Pooled same-chunk-length prefill dispatch vs the per-sequence
    path: greedy streams must match token for token, prefix cache on
    and off — the per-slot prefill body is bit-identical to the scalar
    one for Taylor states."""
    cfg, params = setup
    prefix = _toks(cfg, 16, seed=500)
    reqs = [Request("a", prefix + _toks(cfg, 7, seed=501), 6),
            Request("b", prefix + _toks(cfg, 7, seed=502), 6),
            Request("c", _toks(cfg, 21, seed=503), 6),
            Request("d", prefix + _toks(cfg, 7, seed=501), 6)]

    def run(batch_prefill, cache_mb):
        eng = Engine(cfg, params, EngineConfig(
            n_slots=3, prefill_chunk=8, token_budget=32, max_seq_len=64,
            batch_prefill=batch_prefill, prefix_cache_mb=cache_mb))
        return eng.generate([Request(r.request_id, r.prompt,
                                     r.max_new_tokens) for r in reqs]), eng

    for cache_mb in (0.0, -1.0):
        pooled, eng = run(True, cache_mb)
        per_seq, _ = run(False, cache_mb)
        assert pooled == per_seq
        if cache_mb:
            # pooled boundaries entered the trie in the canonical
            # single-sequence layout and were actually usable
            assert eng.prefix_cache.stats()["inserts"] >= 1


@pytest.mark.slow
def test_batched_prefill_groups_share_one_dispatch(setup):
    """Same-length prompts admitted together must prefill as grouped
    pool dispatches, not one dispatch per sequence."""
    from repro.obs.trace import tracer

    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        n_slots=3, prefill_chunk=8, token_budget=64, max_seq_len=64))
    assert eng._batch_prefill
    tracer.enable()
    try:
        eng.generate([Request(f"r{i}", _toks(cfg, 16, seed=510 + i), 2)
                      for i in range(3)])
        spans = [e for e in tracer.export()["traceEvents"]
                 if e.get("name") == "prefill_batch" and e["ph"] == "B"]
    finally:
        tracer.disable()
        tracer.clear()
    # 3 sequences x 2 chunks each = 6 per-seq dispatches; grouped they
    # collapse to 2 (one per chunk round, all 3 slots per dispatch)
    assert len(spans) == 2
    assert all(s["args"]["slots"] == 3 for s in spans)


def test_batched_prefill_gated_off_for_kv_pools(setup):
    """kv caches attend over a different extent in the per-slot body —
    not bit-identical to the scalar one — so the engine must keep them
    on the per-sequence path even with batch_prefill requested."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=8, max_seq_len=64,
        cache_kind="kv", batch_prefill=True))
    assert not eng._batch_prefill


@pytest.mark.slow
def test_pool_resident_prefill_survives_interleaved_decode(setup):
    """A partially-prefilled pool slot must keep its state bit-exactly
    across decode/verify steps of other slots (the mask merge): a long
    prompt arriving while another sequence decodes is the aliasing
    worst case."""
    cfg, params = setup
    reqs = [Request("short", _toks(cfg, 4, seed=520), 12),
            Request("long", _toks(cfg, 56, seed=521), 4)]

    def run(batch_prefill):
        eng = Engine(cfg, params, EngineConfig(
            n_slots=2, prefill_chunk=8, token_budget=8, max_seq_len=64,
            batch_prefill=batch_prefill))
        eng.submit(reqs[0])
        eng.step()                      # "short" reaches DECODING first
        eng.submit(reqs[1])             # "long" prefills across many steps
        while not eng.idle:
            eng.step()
        return {r.request_id: eng.results[r.request_id].out_tokens
                for r in reqs}

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# kv partial-prefix reuse (positional truncation)
# ---------------------------------------------------------------------------

def test_partial_lookup_truncates_counters():
    """Trie unit: a prompt diverging mid-chunk hits the cached branch
    at the shared token depth, counters clamped, nothing new stored."""
    pc = PrefixCache(4, kv_partial=True)
    cached = list(range(12))
    state = {"pos": np.asarray(8), "k": np.arange(6.0)}
    pc.insert(cached, 8, state, _arr(1))
    # shares chunk [0..4) plus 2 tokens of chunk [4..8)
    probe = cached[:6] + [99] * 6
    hit = pc.lookup(probe)
    assert hit is not None and hit.n_tokens == 6
    assert hit.logits is None                      # always re-runs a chunk
    assert int(hit.state["pos"]) == 6              # clamped
    assert np.array_equal(hit.state["k"], state["k"])  # rows untouched
    s = pc.stats()
    assert s["partial_hits"] == 1 and s["truncated_tokens"] == 2
    assert s["hits"] == 1 and s["hit_tokens"] == 6
    assert s["entries"] == 1                       # ephemeral, not stored


def test_partial_lookup_prefers_deeper_exact_hit():
    pc = PrefixCache(4, kv_partial=True)
    cached = list(range(12))
    pc.insert(cached, 4, {"pos": np.asarray(4)}, _arr(1))
    pc.insert(cached, 8, {"pos": np.asarray(8)}, _arr(1))
    # diverges after 5 tokens: partial depth 5 < exact boundary 8? No —
    # probe shares both full chunks, then diverges: exact 8 beats 8+0
    probe = cached[:8] + [99] * 4
    hit = pc.lookup(probe)
    assert hit.n_tokens == 8 and hit.logits is not None
    assert pc.stats()["partial_hits"] == 0
    # diverging inside the SECOND chunk: partial 6 beats exact 4
    probe2 = cached[:6] + [99] * 6
    assert pc.lookup(probe2).n_tokens == 6
    assert pc.stats()["partial_hits"] == 1


def test_partial_lookup_caps_below_full_prompt():
    """A prompt that is a strict prefix of a cached longer prompt must
    leave at least one token to prefill — no entry holds its boundary
    logits."""
    pc = PrefixCache(4, kv_partial=True)
    cached = list(range(12))
    pc.insert(cached, 12, {"pos": np.asarray(12)}, _arr(1))
    hit = pc.lookup(cached[:6])
    assert hit is not None
    assert hit.n_tokens == 5                       # len(prompt) - 1
    assert int(hit.state["pos"]) == 5


def test_partial_lookup_off_by_default():
    pc = PrefixCache(4)
    cached = list(range(8))
    pc.insert(cached, 8, {"pos": np.asarray(8)}, _arr(1))
    assert pc.lookup(cached[:6] + [99, 99]) is None


def test_cache_truncate_rejects_taylor_states():
    from repro.core import taylor as T

    state = T.TaylorState.zeros((1, 2, 1), 4, n_dims=())
    with pytest.raises(ValueError, match="kv caches only"):
        M.cache_truncate({"groups": [state], "rem": [],
                          "pos": jnp.asarray(8)}, 4)


@pytest.mark.slow
def test_kv_partial_hit_streams_bit_identical(setup):
    """Engine level: kv pools serve diverging prompts from truncated
    entries (partial_hits > 0) and the streams still match a cold
    engine exactly — clamped counters mask the stale rows with exact
    zeros."""
    cfg, params = setup
    shared = _toks(cfg, 21, seed=600)     # 2 full chunks + 5 off-grid
    reqs = [Request("warm", shared + _toks(cfg, 6, seed=601), 5),
            Request("part", shared + _toks(cfg, 9, seed=602), 5)]

    def run(cache_mb):
        eng = _engine(cfg, params, cache_mb=cache_mb, cache_kind="kv",
                      n_slots=1)
        out = {}
        for r in reqs:
            out.update(eng.generate([Request(r.request_id, r.prompt,
                                             r.max_new_tokens)]))
            eng.results.clear()
        return out, eng

    cold, _ = run(0.0)
    hot, eng = run(-1.0)
    assert cold == hot
    s = eng.prefix_cache.stats()
    assert s["partial_hits"] >= 1
    assert s["truncated_tokens"] >= 1
    assert s["hit_tokens"] >= 21
