"""Composed 3D-parallel training (distributed/composed.py).

The composed step runs FSDP × GPipe pipeline × sequence-parallel Taylor
scan in ONE fully-manual shard_map with `value_and_grad` inside the
body. The evidence here mirrors how the step is argued correct:

  1. Parameter layout: `split_params` ⟷ `merge_params` round-trips
     bit-for-bit, and invalid configs fail loudly (single device).
  2. Divisibility contracts raise clear errors instead of shape
     accidents (single device).
  3. Loss AND gradients of the composed step match the single-device
     `model.loss_fn` reference at ≤1e-4 across mesh shapes, causal and
     non-causal, with and without FSDP/remat — this is what certifies
     that the collective transposes (psum/ppermute/all_gather) used by
     the in-body autodiff are the true adjoints on this jax version.
  4. The full jitted train step (grad + adamw) decreases the loss with
     params resting sharded (pipe on dim 0, FSDP over data).

Multi-device cases run under the CI ``train-parallel`` job
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``); they skip on
fewer devices. Pure jnp — no `kernels` marker.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import composed as C
from repro.launch import mesh as MESH
from repro.launch.steps import default_opt_config
from repro.models import model as M

jax.config.update("jax_enable_x64", False)

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8, reason="needs 8 devices (CI train-parallel job sets "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

GB, N = 8, 256


def _cfg(causal=True, n_layers=2, remat=False):
    cfg = get_config("taylorshift-lra").reduced()
    cfg = cfg.with_(n_layers=n_layers, d_model=32, n_heads=2, n_kv_heads=2,
                    d_ff=64, max_seq_len=N, dtype="float32", remat=remat,
                    causal=causal)
    # fp32 + jnp reference attention: parity tolerances are about the
    # parallel decomposition, not mixed-precision noise
    return cfg.with_(taylor=dataclasses.replace(
        cfg.taylor, mode="efficient", use_kernel=False))


def _batch(cfg):
    tok = jax.random.randint(jax.random.PRNGKey(1), (GB, N), 0, cfg.vocab)
    lab = jax.random.randint(jax.random.PRNGKey(2), (GB, N), 0, cfg.vocab)
    return {"tokens": tok, "labels": lab}


# ---------------------------------------------------------------------------
# 1+2. Layout round-trip and loud contracts (single device)
# ---------------------------------------------------------------------------

def test_split_merge_roundtrip():
    cfg = _cfg(n_layers=4)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    split = C.split_params(cfg, params, 2)
    leaf = jax.tree.leaves(split["stages"])[0]
    assert leaf.shape[:2] == (2, 2)          # (S, L_per, ...)
    merged = C.merge_params(split)
    jax.tree.map(np.testing.assert_array_equal, merged, params)


def test_split_rejects_indivisible_layers():
    cfg = _cfg(n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="not divisible"):
        C.split_params(cfg, params, 4)


def test_grad_fn_rejects_bad_batch():
    cfg = _cfg()
    mesh = MESH.make_composed_mesh(data=1, pipe=1, seq=1)
    with pytest.raises(ValueError, match="microbatches"):
        C.build_composed_grad_fn(cfg, mesh, global_batch=7, seq_len=N,
                                 n_microbatches=2)


# ---------------------------------------------------------------------------
# 3. Loss + gradient parity vs the single-device reference
# ---------------------------------------------------------------------------

def _parity(cfg, data, pipe, seq, *, fsdp, mb):
    mesh = MESH.make_composed_mesh(data=data, pipe=pipe, seq=seq)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    split = C.split_params(cfg, params, pipe)
    grad_fn, _ = C.build_composed_grad_fn(
        cfg, mesh, global_batch=GB, seq_len=N, n_microbatches=mb,
        fsdp=fsdp)
    batch = _batch(cfg)
    pshard = C.composed_param_shardings(split, mesh, fsdp=fsdp)
    with mesh:
        loss, grads = jax.jit(grad_fn)(jax.device_put(split, pshard),
                                       batch)
    ref_loss, ref_grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch))(params)
    gm = C.merge_params(grads)
    gerr = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))
                           / (jnp.max(jnp.abs(b)) + 1e-8)),
        gm, ref_grads)))
    return abs(float(loss) - float(ref_loss)), gerr


@needs8
@pytest.mark.parametrize(
    "causal,data,pipe,seq,fsdp,mb,remat,n_layers",
    [
        (True, 2, 2, 2, True, 2, False, 2),    # full 3D + FSDP
        (True, 1, 2, 4, False, 4, False, 2),   # pipe × deep seq
        (True, 1, 1, 8, True, 1, False, 2),    # pure context parallel
        (False, 2, 2, 2, True, 2, False, 2),   # non-causal psum'd sums
        (True, 4, 2, 1, True, 2, True, 2),     # FSDP-heavy + remat
        (False, 1, 4, 2, True, 4, True, 4),    # 4 stages, remat
    ])
def test_composed_matches_single_device(causal, data, pipe, seq, fsdp,
                                        mb, remat, n_layers):
    cfg = _cfg(causal=causal, remat=remat, n_layers=n_layers)
    loss_diff, gerr = _parity(cfg, data, pipe, seq, fsdp=fsdp, mb=mb)
    assert loss_diff <= 1e-4, f"loss diff {loss_diff:.2e}"
    assert gerr <= 1e-4, f"max rel grad err {gerr:.2e}"


# ---------------------------------------------------------------------------
# 4. Full train step: optimization progresses, params rest sharded
# ---------------------------------------------------------------------------

@needs8
def test_composed_train_step_decreases_loss():
    cfg = _cfg(causal=True, remat=True)
    mesh = MESH.make_composed_mesh(data=2, pipe=2, seq=2)
    init_fn, step_fn, _ = C.build_composed_train_step(
        cfg, default_opt_config(cfg), mesh, global_batch=GB, seq_len=N,
        n_microbatches=2, fsdp=True)
    params, opt_state = init_fn(jax.random.PRNGKey(0))

    leaf = jax.tree.leaves(params["stages"])[0]
    assert leaf.sharding.spec[0] == "pipe"

    tok = jax.random.randint(jax.random.PRNGKey(1), (GB, N), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    losses = []
    for _ in range(6):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert int(opt_state["step"]) == 6
    assert losses[-1] < losses[0], losses
    assert {"loss", "grad_norm", "lr"} <= set(metrics)
