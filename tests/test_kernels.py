"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref.py
oracles, run in interpret mode on CPU (kernel bodies execute in Python)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernels

from repro.core import taylor as T
from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.taylor_efficient import _pick_chunk_factor


def rand(key, b, h, n, d, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return tuple(jax.random.normal(k, (b, h, n, d), dtype) for k in ks)


class TestDirectKernel:
    @pytest.mark.parametrize("n,d,bq,bk", [
        (64, 8, 16, 16),
        (128, 16, 32, 64),
        (96, 32, 32, 32),     # n not divisible by 64
        (128, 64, 128, 128),  # single block
    ])
    def test_matches_ref(self, n, d, bq, bk):
        q, k, v = rand(jax.random.PRNGKey(n + d), 2, 2, n, d)
        y = ops.taylor_attention_kernel(q, k, v, mode="direct", block_q=bq,
                                        block_k=bk, interpret=True)
        y_ref = ref.direct_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("n,d", [(64, 8), (128, 16)])
    def test_causal_matches_ref(self, n, d):
        q, k, v = rand(jax.random.PRNGKey(7), 1, 2, n, d)
        y = ops.taylor_attention_kernel(q, k, v, causal=True, block_q=32,
                                        block_k=32, interpret=True)
        y_ref = ref.direct_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16_inputs(self):
        q, k, v = rand(jax.random.PRNGKey(9), 1, 1, 64, 16, jnp.bfloat16)
        y = ops.taylor_attention_kernel(q, k, v, mode="direct", interpret=True)
        assert y.dtype == jnp.bfloat16
        y_ref = ref.direct_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            rtol=0.08, atol=0.08)

    def test_tau_vector(self):
        q, k, v = rand(jax.random.PRNGKey(11), 2, 4, 64, 8)
        tau = jnp.array([0.5, 1.0, 2.0, 3.0]).reshape(1, 4, 1, 1)
        y = ops.taylor_attention_kernel(q, k, v, tau=tau, mode="direct",
                                        interpret=True)
        y_ref = ref.direct_ref(q, k, v, tau=tau)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)


class TestEfficientKernel:
    @pytest.mark.parametrize("n,d", [(64, 8), (128, 16), (64, 32), (256, 64)])
    def test_matches_ref(self, n, d):
        q, k, v = rand(jax.random.PRNGKey(n * d), 2, 2, n, d)
        y = ops.taylor_attention_kernel(q, k, v, mode="efficient",
                                        block_q=32, block_k=32,
                                        interpret=True)
        y_ref = ref.efficient_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("mode,causal", [("direct", False),
                                             ("direct", True),
                                             ("efficient", False)])
    def test_prime_n_pads_instead_of_block1(self, mode, causal):
        """Prime N must not degrade the grid to block size 1: ops pads N
        up to the block multiple and masks the padded keys."""
        from repro.kernels.ops import _good_block
        n, d = 61, 8
        assert _good_block(n, 16) == (16, 64)
        assert _good_block(1021, 128) == (128, 1024)
        q, k, v = rand(jax.random.PRNGKey(61), 1, 2, n, d)
        y = ops.taylor_attention_kernel(q, k, v, mode=mode, causal=causal,
                                        block_q=16, block_k=16,
                                        interpret=True)
        y_ref = ref.direct_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=3e-4, atol=3e-4)

    def test_direct_equals_efficient_kernels(self):
        """The paper's core identity, at the kernel level."""
        q, k, v = rand(jax.random.PRNGKey(3), 1, 2, 128, 16)
        yd = ops.taylor_attention_kernel(q, k, v, mode="direct",
                                         interpret=True)
        ye = ops.taylor_attention_kernel(q, k, v, mode="efficient",
                                         interpret=True)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(ye),
                                   rtol=3e-4, atol=3e-4)

    def test_amod_phase(self):
        """Phase A in isolation against the ⊠-product oracle."""
        from repro.kernels.taylor_efficient import _amod_call
        d = 16
        key = jax.random.PRNGKey(5)
        k = jax.random.normal(key, (3, 64, d))
        v = jax.random.normal(jax.random.fold_in(key, 1), (3, 64, d))
        ones = jnp.ones((3, 64, 1), jnp.float32)
        vh = jnp.concatenate([ones, v], axis=-1)
        cf = _pick_chunk_factor(d)
        a = _amod_call(k, vh, cf=cf, block_k=32, interpret=True)
        a_ref = ref.amod_ref(k, v)
        np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("d,budget,expect_fit", [
        (64, 8 << 20, True), (128, 8 << 20, True), (256, 8 << 20, True),
    ])
    def test_chunk_factor_fits_vmem(self, d, budget, expect_fit):
        cf = _pick_chunk_factor(d, budget)
        assert d % cf == 0
        assert cf * d * (d + 1) * 4 <= budget


class TestKernelVmemFootprint:
    """Structural check: claimed VMEM working set fits a v5e core (~16MB)."""

    @pytest.mark.parametrize("d", [64, 128, 144, 256, 288])
    def test_efficient_tiles_fit(self, d):
        cf = _pick_chunk_factor(d)
        block_k = 128
        tile = cf * d * (d + 1) * 4           # A_mod accumulator
        k2 = block_k * cf * d * 4             # expanded K chunk
        inputs = block_k * (2 * d + 1) * 4
        assert tile + k2 + inputs < 15 * 1024 * 1024, (d, cf)

    @pytest.mark.parametrize("d", [64, 128, 256])
    def test_direct_tiles_fit(self, d):
        bq = bk = 128
        total = (2 * bq * d + 2 * bk * d + bq * bk + bq) * 4
        assert total < 15 * 1024 * 1024


class TestAutoMode:
    def test_auto_picks_direct_below_crossover(self):
        q, k, v = rand(jax.random.PRNGKey(13), 1, 1, 32, 16)
        y_auto = ops.taylor_attention_kernel(q, k, v, mode="auto",
                                             interpret=True)
        y_dir = ops.taylor_attention_kernel(q, k, v, mode="direct",
                                            interpret=True)
        np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dir),
                                   rtol=1e-6)

    def test_auto_picks_efficient_above_crossover(self):
        d = 4  # N0(4) = 87.7 ⇒ N=128 is beyond the crossover
        assert T.crossover_n0(d) < 128
        q, k, v = rand(jax.random.PRNGKey(14), 1, 1, 128, d)
        y = ops.taylor_attention_kernel(q, k, v, mode="auto", interpret=True)
        y_ref = ref.efficient_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=3e-4, atol=3e-4)


class TestDecodeKernel:
    """Fused decode-step kernel vs the core recurrent oracle."""

    @pytest.mark.parametrize("d", [8, 16, 32])
    def test_matches_decode_step(self, d):
        from repro.kernels.taylor_decode import taylor_decode_kernel
        bh, n_steps = 3, 6
        key = jax.random.PRNGKey(d)
        state_k = T.TaylorState.zeros((bh,), d)
        state_r = T.TaylorState.zeros((bh,), d)
        for t in range(n_steps):
            kk = jax.random.fold_in(key, t)
            q, k, v = (jax.random.normal(s, (bh, 1, d))
                       for s in jax.random.split(kk, 3))
            yk, state_k = taylor_decode_kernel(state_k, q, k, v, tau=1.3,
                                               interpret=True)
            yr, state_r = T.taylor_decode_step(state_r, q, k, v, tau=1.3)
            np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                                       rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(state_k.s2),
                                   np.asarray(state_r.s2),
                                   rtol=1e-4, atol=1e-4)

    def test_long_rollout_stable(self):
        from repro.kernels.taylor_decode import taylor_decode_kernel
        d, bh = 8, 1
        state = T.TaylorState.zeros((bh,), d)
        key = jax.random.PRNGKey(0)
        for t in range(40):
            kk = jax.random.fold_in(key, t)
            q, k, v = (jax.random.normal(s, (bh, 1, d))
                       for s in jax.random.split(kk, 3))
            y, state = taylor_decode_kernel(state, q, k, v, interpret=True)
        assert bool(jnp.all(jnp.isfinite(y)))
        assert int(state.n) == 40
