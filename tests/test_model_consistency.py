"""Consistency of the two execution paths every serving system needs:
full-sequence forward (train/prefill) vs token-by-token decode, plus
sequential oracles for the SSD and mLSTM cells."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import mamba2 as M2
from repro.models import model as M
from repro.models import xlstm as XL

SEQ = 24
BATCH = 2


def _logits_forward(cfg, params, tokens):
    hidden, _ = M.forward(params, cfg, {"tokens": tokens})
    return M.logits_from_hidden(params, cfg, hidden)


def _logits_decode(cfg, params, tokens, cache_kind):
    cache = M.init_decode_state(cfg, tokens.shape[0], cache_len=SEQ,
                                cache_kind=cache_kind, dtype=jnp.float32)
    outs = []
    step = jax.jit(lambda b, c: M.decode_step(params, cfg, b, c))
    for t in range(tokens.shape[1]):
        lg, cache = step({"tokens": tokens[:, t:t+1]}, cache)
        outs.append(lg)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("arch,cache_kind", [
    ("stablelm-1.6b", "taylor"),
    ("stablelm-1.6b", "kv"),
    pytest.param("gemma3-1b", "taylor", marks=pytest.mark.slow),
    pytest.param("zamba2-7b", "taylor", marks=pytest.mark.slow),
    # cache_kind ignored for xlstm: state blocks
    pytest.param("xlstm-125m", "taylor", marks=pytest.mark.slow),
])
def test_decode_matches_forward(arch, cache_kind):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                                cfg.vocab)
    lg_fwd = _logits_forward(cfg, params, tokens)
    lg_dec = _logits_decode(cfg, params, tokens, cache_kind)
    np.testing.assert_allclose(np.asarray(lg_fwd), np.asarray(lg_dec),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_whisper_decode_matches_forward():
    cfg = get_config("whisper-large-v3").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (BATCH, cfg.encoder_frames, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, cfg.decoder_len),
                                0, cfg.vocab)
    hidden, _ = M.forward(params, cfg, {"tokens": tokens, "frames": frames})
    lg_fwd = M.logits_from_hidden(params, cfg, hidden)

    cache = M.init_decode_state(cfg, BATCH, cache_len=cfg.decoder_len,
                                cache_kind="taylor", dtype=jnp.float32)
    cache = M.encode_for_decode(params, cfg, frames, cache)
    outs = []
    for t in range(tokens.shape[1]):
        lg, cache = M.decode_step(params, cfg, {"tokens": tokens[:, t:t+1]},
                                  cache)
        outs.append(lg)
    lg_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(lg_fwd), np.asarray(lg_dec),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# Cell-level oracles
# ---------------------------------------------------------------------------

def _ssd_sequential(xh, dt, A, Bm, Cm):
    """Naive O(N) recurrence: h_t = exp(-A dt_t) h_{t-1} + B_t (x_t dt_t)."""
    b, n, h, p = xh.shape
    g = Bm.shape[2]
    rep = h // g
    Bh = np.repeat(np.asarray(Bm), rep, axis=2)
    Ch = np.repeat(np.asarray(Cm), rep, axis=2)
    xh, dt, A = map(np.asarray, (xh, dt, A))
    S = Bh.shape[-1]
    hstate = np.zeros((b, h, S, p))
    ys = np.zeros_like(xh)
    for t in range(n):
        dec = np.exp(-A[None] * dt[:, t])            # (b, h)
        hstate = hstate * dec[..., None, None] + np.einsum(
            "bhs,bhp->bhsp", Bh[:, t], xh[:, t] * dt[:, t][..., None])
        ys[:, t] = np.einsum("bhs,bhsp->bhp", Ch[:, t], hstate)
    return ys


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_sequential(chunk):
    key = jax.random.PRNGKey(3)
    b, n, h, p, s, g = 2, 16, 4, 8, 8, 2
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xh = jax.random.normal(k1, (b, n, h, p))
    dt = jax.nn.softplus(jax.random.normal(k2, (b, n, h)))
    A = jnp.exp(jax.random.normal(k3, (h,)) * 0.5)
    Bm = jax.random.normal(k4, (b, n, g, s))
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (b, n, g, s))
    y_chunked = M2._ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y_seq = _ssd_sequential(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_chunked), y_seq,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_mamba2_decode_matches_prefill():
    cfg = get_config("zamba2-7b").reduced()
    params = M2.mamba2_init(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (BATCH, 16, cfg.d_model))
    y_full = M2.mamba2_apply(params, cfg, x)
    cache = M2.mamba2_init_cache(cfg, BATCH)
    ys = []
    for t in range(16):
        y, cache = M2.mamba2_decode(params, cfg, x[:, t:t+1], cache)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_mlstm_decode_matches_prefill():
    cfg = get_config("xlstm-125m").reduced()
    params = XL.mlstm_init(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (BATCH, 16, cfg.d_model))
    y_full = XL.mlstm_apply(params, cfg, x)
    cache = XL.mlstm_init_cache(cfg, BATCH)
    ys = []
    for t in range(16):
        y, cache = XL.mlstm_decode(params, cfg, x[:, t:t+1], cache)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec),
                               rtol=2e-3, atol=2e-3)


def test_slstm_decode_matches_scan():
    cfg = get_config("xlstm-125m").reduced()
    params = XL.slstm_init(jax.random.PRNGKey(8), cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (BATCH, 12, cfg.d_model))
    y_full = XL.slstm_apply(params, cfg, x)
    cache = XL.slstm_init_cache(cfg, BATCH)
    ys = []
    for t in range(12):
        y, cache = XL.slstm_decode(params, cfg, x[:, t:t+1], cache)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-4)
