"""Chaos/fault-injection suite for the fleet tier (serve/router.py).

The claims under attack:

  * **Migration bit-identity** — a stream drained mid-decode, shipped
    as a ``repro.state/v1`` blob and continued on a peer emits exactly
    the tokens the same request gets on an undisturbed engine, across
    greedy/seeded-sampling × taylor/kv × speculation on/off.
  * **Never half-restore** — truncated/corrupt/foreign blobs are
    refused with the destination engine bit-exactly untouched.
  * **Heartbeat loss** — a hard-killed replica's requests replay on
    survivors with no duplicate token events and identical streams.
  * **Placement** — prefix-affine requests land on the replica
    advertising their longest cached prefix; routing tracks membership
    churn; one ``replica_id`` threads engine, obs and membership.
"""

import jax
import pytest

from repro.configs import SpecConfig, get_config
from repro.models import model as M
from repro.serve import Engine, EngineConfig, Request
from repro.serve import wire
from repro.serve.router import Router

PROMPT, GEN, CHUNK = 10, 8, 4


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _econf(rid, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("prefill_chunk", CHUNK)
    kw.setdefault("max_seq_len", PROMPT + GEN + 6)
    return EngineConfig(replica_id=rid, **kw)


def _prompt(cfg, n, seed):
    return [int(t) for t in jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, cfg.vocab)]


def _req(cfg, rid, seed, n=PROMPT):
    return Request(rid, _prompt(cfg, n, seed), max_new_tokens=GEN)


def _step_until(rt, rid, n_emitted):
    """Step the fleet until request ``rid`` has emitted ``n_emitted``
    tokens (and is still decoding — GEN leaves headroom)."""
    count, events = 0, []
    while count < n_emitted:
        evs = rt.step()
        events += evs
        count += sum(e.request_id == rid for e in evs)
    return events


# ---------------------------------------------------------------------------
# Migration bit-identity matrix
# ---------------------------------------------------------------------------

MATRIX = [  # (cache_kind, temperature, speculate_k) — pairwise coverage
    ("taylor", 0.0, 0),
    ("taylor", 0.8, 2),
    ("kv", 0.0, 2),
    ("kv", 0.8, 0),
]


@pytest.mark.parametrize("cache_kind,temp,spec_k", MATRIX)
def test_migration_bit_identity(setup, cache_kind, temp, spec_k):
    """Kill-free live migration: drain q0 mid-decode, ship it, continue
    on the peer — merged streams equal the undisturbed solo run."""
    cfg, params = setup
    kw = dict(cache_kind=cache_kind, temperature=temp, speculate_k=spec_k)
    ref = Engine(cfg, params, _econf("ref", **kw))
    want = ref.generate([_req(cfg, "q0", 0), _req(cfg, "q1", 1)])

    rt = Router([Engine(cfg, params, _econf("a", **kw)),
                 Engine(cfg, params, _econf("b", **kw))])
    rt.submit(_req(cfg, "q0", 0))
    rt.submit(_req(cfg, "q1", 1))
    _step_until(rt, "q0", 2)
    src = rt._owner["q0"]
    dst = "b" if src == "a" else "a"
    nbytes = rt.migrate("q0", dst)
    assert nbytes > 0 and rt._owner["q0"] == dst
    for _ in rt.run():
        pass
    assert rt.results["q0"].out_tokens == want["q0"]
    assert rt.results["q1"].out_tokens == want["q1"]


def test_migration_with_self_drafter(setup):
    """The self-drafter's shadow pool must re-absorb prompt + emitted
    context on import (not just the prompt) — the on_ready contract a
    migrated mid-generation stream exercises."""
    cfg, params = setup
    kw = dict(speculate_k=2,
              spec=SpecConfig(drafter="self", draft_layers=1))
    ref = Engine(cfg, params, _econf("ref", **kw))
    want = ref.generate([_req(cfg, "q0", 3)])

    rt = Router([Engine(cfg, params, _econf("a", **kw)),
                 Engine(cfg, params, _econf("b", **kw))])
    rt.submit(_req(cfg, "q0", 3))
    _step_until(rt, "q0", 2)
    rt.migrate("q0", "b" if rt._owner["q0"] == "a" else "a")
    for _ in rt.run():
        pass
    assert rt.results["q0"].out_tokens == want["q0"]


def test_double_migration(setup):
    """There and back again: two hops, still bit-identical."""
    cfg, params = setup
    ref = Engine(cfg, params, _econf("ref"))
    want = ref.generate([_req(cfg, "q0", 5)])
    rt = Router([Engine(cfg, params, _econf("a")),
                 Engine(cfg, params, _econf("b"))])
    rt.submit(_req(cfg, "q0", 5))
    _step_until(rt, "q0", 1)
    first = rt._owner["q0"]
    other = "b" if first == "a" else "a"
    rt.migrate("q0", other)
    _step_until(rt, "q0", 3)
    rt.migrate("q0", first)
    for _ in rt.run():
        pass
    assert rt.results["q0"].out_tokens == want["q0"]
    assert int(rt._migrations_c.value) == 2


# ---------------------------------------------------------------------------
# Never half-restore: corrupt / truncated / foreign / mismatched blobs
# ---------------------------------------------------------------------------

def _exported_blob(cfg, params, **kw):
    """A real mid-decode stream blob plus a fresh same-config peer."""
    src = Engine(cfg, params, _econf("src", **kw))
    src.submit(_req(cfg, "q0", 7))
    emitted = 0
    while emitted < 2:
        _, evs = src.step()
        emitted += len(evs)
    return src.export_request("q0"), Engine(cfg, params,
                                            _econf("dst", **kw))


def _engine_untouched(eng):
    return (eng.pool.free_slots == eng.pool.n_slots
            and not eng.sequences and not eng.results
            and all(s is None for s in eng._slots))


def test_corrupt_blob_refused_dst_untouched(setup):
    cfg, params = setup
    blob, dst = _exported_blob(cfg, params)
    for mangled in (blob[:len(blob) // 2],             # truncated
                    bytes([blob[0] ^ 1]) + blob[1:],   # bad magic
                    blob[:-2] + bytes([blob[-2] ^ 1]) + blob[-1:],  # crc
                    blob[:40] + bytes([blob[40] ^ 0x10]) + blob[41:]):
        with pytest.raises(wire.WireError):
            dst.import_request(mangled)
        assert _engine_untouched(dst)
    # the intact blob still restores and runs to completion afterwards
    seq = dst.import_request(blob)
    assert seq.slot is not None and len(seq.out_tokens) == 2
    while not dst.idle:
        dst.step()
    assert len(dst.results["q0"].out_tokens) == GEN


def test_cache_kind_mismatch_refused(setup):
    cfg, params = setup
    blob, _ = _exported_blob(cfg, params, cache_kind="taylor")
    kv_dst = Engine(cfg, params, _econf("kv", cache_kind="kv"))
    with pytest.raises(wire.WireError, match="cache_kind"):
        kv_dst.import_request(blob)
    assert _engine_untouched(kv_dst)


def test_engine_fingerprint_mismatch_refused(setup):
    """A different seed would silently fork sampled streams — refuse."""
    cfg, params = setup
    blob, _ = _exported_blob(cfg, params)
    other = Engine(cfg, params, _econf("o", seed=123))
    with pytest.raises(wire.WireError, match="fingerprint"):
        other.import_request(blob)
    assert _engine_untouched(other)


def test_export_gates(setup):
    cfg, params = setup
    eng = Engine(cfg, params, _econf("e"))
    eng.submit(_req(cfg, "q0", 9))
    with pytest.raises(ValueError, match="waiting"):
        eng.export_request("q0")        # migration only at step
    #   boundaries of a *decoding* stream
    with pytest.raises(KeyError):
        eng.export_request("nope")
    while not eng.idle:
        eng.step()
    with pytest.raises(KeyError):
        eng.export_request("q0")        # finished = gone

    # duplicate import: the id is already live here
    blob, dst = _exported_blob(cfg, params)
    dst.import_request(blob)
    with pytest.raises(ValueError, match="duplicate"):
        dst.import_request(blob)


# ---------------------------------------------------------------------------
# Heartbeat loss / hard kill
# ---------------------------------------------------------------------------

def test_kill_replays_bit_identical_no_duplicates(setup):
    """Hard crash: heartbeats stop, the sweep expires the peer, its
    in-flight requests replay on the survivor. Determinism makes the
    replayed stream identical; index suppression means the merged event
    stream carries each token exactly once."""
    cfg, params = setup
    ref = Engine(cfg, params, _econf("ref"))
    want = ref.generate([_req(cfg, "q0", 11), _req(cfg, "q1", 12)])

    clk = {"t": 0.0}
    rt = Router([Engine(cfg, params, _econf("a")),
                 Engine(cfg, params, _econf("b"))],
                timeout_s=5.0, clock=lambda: clk["t"])
    rt.submit(_req(cfg, "q0", 11))
    rt.submit(_req(cfg, "q1", 12))
    events = _step_until(rt, "q0", 2)
    victim = rt._owner["q0"]
    rt.kill(victim)
    clk["t"] += 10.0                    # silence > timeout
    for ev in rt.run():
        events.append(ev)
    assert int(rt._failures_c.value) == 1
    assert int(rt._resub_c.value) >= 1
    for rid in ("q0", "q1"):
        assert rt.results[rid].out_tokens == want[rid]
        idxs = [e.index for e in events if e.request_id == rid]
        assert idxs == sorted(set(idxs)), f"duplicate events for {rid}"
        assert idxs == list(range(GEN))


def test_preempt_migrates_and_leaves(setup):
    """Cooperative preemption: decoding streams migrate (not replay),
    the replica leaves the membership immediately, streams stay exact."""
    cfg, params = setup
    ref = Engine(cfg, params, _econf("ref"))
    want = ref.generate([_req(cfg, "q0", 13), _req(cfg, "q1", 14)])
    rt = Router([Engine(cfg, params, _econf("a")),
                 Engine(cfg, params, _econf("b"))])
    rt.submit(_req(cfg, "q0", 13))
    rt.submit(_req(cfg, "q1", 14))
    _step_until(rt, "q0", 1)
    victim = rt._owner["q0"]
    epoch = rt.membership.epoch
    moved = rt.preempt(victim)
    assert moved["migrated"] or moved["resubmitted"]
    assert victim not in rt.membership.members
    assert rt.membership.epoch > epoch
    for _ in rt.run():
        pass
    assert rt.results["q0"].out_tokens == want["q0"]
    assert rt.results["q1"].out_tokens == want["q1"]


def test_preempt_without_migration_resubmits_to_peer(setup):
    """With migration off, a drained replica's requests must resubmit
    to a *peer* — never back onto the replica being drained (which
    would orphan them once it's popped) — and replay bit-identically."""
    cfg, params = setup
    ref = Engine(cfg, params, _econf("ref"))
    want = ref.generate([_req(cfg, "q0", 15), _req(cfg, "q1", 16)])
    rt = Router([Engine(cfg, params, _econf("a")),
                 Engine(cfg, params, _econf("b"))],
                migrate_on_preempt=False)
    rt.submit(_req(cfg, "q0", 15))
    rt.submit(_req(cfg, "q1", 16))
    _step_until(rt, "q0", 1)
    victim = rt._owner["q0"]
    moved = rt.preempt(victim)
    assert moved["resubmitted"] and not moved["migrated"]
    assert victim not in rt.replicas
    assert all(o != victim for o in rt._owner.values())
    for _ in range(500):                # bounded: a regression here
        if rt.idle:                     # used to spin forever
            break
        rt.step()
    assert rt.idle, "fleet never drained after no-migrate preempt"
    assert rt.results["q0"].out_tokens == want["q0"]
    assert rt.results["q1"].out_tokens == want["q1"]


# ---------------------------------------------------------------------------
# Placement: prefix affinity, churn, cache federation
# ---------------------------------------------------------------------------

def test_prefix_affine_routing(setup):
    """A request whose prompt extends a prefix cached on replica A must
    route to A even when A is busier; cold prompts go least-loaded."""
    cfg, params = setup
    shared = _prompt(cfg, 2 * CHUNK, 21)
    warm = Engine(cfg, params, _econf("warm", prefix_cache_mb=-1))
    warm.generate([Request("w0", [*shared, *_prompt(cfg, 3, 22)],
                           max_new_tokens=2)])
    assert warm.prefix_cache.stats()["entries"] >= 1
    cold = Engine(cfg, params, _econf("cold", prefix_cache_mb=-1))
    rt = Router([warm, cold])

    affine = Request("aff", [*shared, *_prompt(cfg, 4, 23)],
                     max_new_tokens=2)
    assert rt.route(affine) == "warm"
    prefix_routed = int(rt._prefix_c.value)
    assert rt.submit(affine) == "warm"
    assert int(rt._prefix_c.value) == prefix_routed + 1

    # cold prompt: least-loaded fallback ("warm" now has a live request)
    assert rt.route(_req(cfg, "cold1", 24)) == "cold"
    for _ in rt.run():
        pass
    assert rt.results["aff"].out_tokens is not None


def test_warm_from_peer_federation(setup):
    """Cache export/import: a cold replica warms from a peer's wire
    blobs, serves the shared prefix from cache, and the stream is
    bit-identical to an uncached engine's."""
    cfg, params = setup
    shared = _prompt(cfg, 2 * CHUNK, 31)
    tail = _prompt(cfg, 3, 32)
    nocache = Engine(cfg, params, _econf("ref"))
    want = nocache.generate([Request("f0", [*shared, *tail],
                                     max_new_tokens=GEN)])

    warm = Engine(cfg, params, _econf("w", prefix_cache_mb=-1))
    cold = Engine(cfg, params, _econf("c", prefix_cache_mb=-1))
    warm.generate([Request("seed", [*shared, *_prompt(cfg, 2, 33)],
                           max_new_tokens=2)])
    rt = Router([warm, cold])
    n = rt.warm_from_peer("c", "w")
    assert n >= 1
    assert cold.prefix_cache.stats()["entries"] >= 1
    assert int(rt._cache_import_c.value) == n

    cold.submit(Request("f0", [*shared, *tail], max_new_tokens=GEN))
    while not cold.idle:
        cold.step()
    got = cold.results["f0"]
    assert got.cached_tokens >= 2 * CHUNK       # served from the import
    assert got.out_tokens == want["f0"]


def test_routing_under_churn(setup):
    """Membership churn: joins/leaves bump the epoch and routing only
    ever lands on live, attached replicas."""
    cfg, params = setup
    clk = {"t": 0.0}
    rt = Router([Engine(cfg, params, _econf("a"))],
                timeout_s=5.0, clock=lambda: clk["t"])
    assert rt.route(_req(cfg, "x", 41)) == "a"
    e0 = rt.membership.epoch
    rt.add_replica(Engine(cfg, params, _econf("b")))
    assert rt.membership.epoch == e0 + 1 and set(rt.live) == {"a", "b"}

    rt.submit(_req(cfg, "x", 41))
    victim = rt._owner["x"]
    survivor = "b" if victim == "a" else "a"
    rt.kill(victim)
    clk["t"] += 10.0
    assert rt.route(_req(cfg, "y", 42)) == survivor
    for _ in rt.run():
        pass
    assert rt.route(_req(cfg, "z", 43)) == survivor
    assert set(rt.live) == {survivor}
    assert len(rt.results["x"].out_tokens) == GEN

    with pytest.raises(ValueError, match="replica_id"):
        rt.add_replica(Engine(cfg, params, EngineConfig()))
    with pytest.raises(ValueError, match="duplicate"):
        rt.add_replica(Engine(cfg, params, _econf(survivor)))


# ---------------------------------------------------------------------------
# One replica identity across engine, obs, membership
# ---------------------------------------------------------------------------

def test_replica_id_threads_through_obs_and_membership(setup):
    cfg, params = setup
    e_a = Engine(cfg, params, _econf("ra"))
    e_b = Engine(cfg, params, _econf("rb"))
    assert e_a.replica_id == "ra" == e_a.econf.replica_id
    snap = e_a.snapshot_metrics()       # no per-call string needed
    assert snap["replica"] == "ra"
    assert e_a.snapshot_metrics(replica="override")["replica"] == "override"

    rt = Router([e_a, e_b])
    assert rt.membership.members == ["ra", "rb"]
    rt.submit(_req(cfg, "m0", 51))
    for _ in rt.run():
        pass

    from repro.obs import aggregate as OA
    fleet = rt.fleet_snapshot()
    assert OA.validate_snapshot(fleet) == []
    names = set(fleet["metrics"])
    for fam in ("router_requests_total", "router_migrations_total",
                "router_resubmissions_total", "router_wire_bytes_total",
                "router_replica_failures_total", "router_replicas",
                "router_prefix_routed_total",
                "router_least_loaded_routed_total",
                "ft_members", "ft_heartbeats_total",
                "ft_epoch_changes_total"):
        assert fam in names, f"missing {fam} in fleet snapshot"
