"""GPipe-style pipeline parallelism (distributed/pipeline.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.pipeline import (bubble_fraction, make_pp_mesh,
                                        pipeline_forward)


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(2, 16) < 0.06


def test_pipeline_matches_sequential():
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >= 2 devices (run under dryrun env for more)")
    S = 2
    mesh = make_pp_mesh(S)
    params = {"w": jnp.stack([jnp.full((4, 4), 2.0),
                              jnp.full((4, 4), 0.5)])}

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"])

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    y = pipeline_forward(stage_fn, params, x, mesh, n_microbatches=4)

    # sequential reference
    h = x
    for s in range(S):
        h = stage_fn(jax.tree.map(lambda a: a[s], params), h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(h),
                               rtol=1e-5, atol=1e-5)


def _remainder_setup():
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >= 2 devices (run under dryrun env for more)")
    S = 2
    mesh = make_pp_mesh(S)
    params = {"w": jnp.stack([jnp.full((4, 4), 2.0),
                              jnp.full((4, 4), 0.5)])}

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"])

    x = jax.random.normal(jax.random.PRNGKey(0), (7, 4))   # 7 % 4 != 0
    h = x
    for s in range(S):
        h = stage_fn(jax.tree.map(lambda a: a[s], params), h)
    return mesh, params, stage_fn, x, np.asarray(h)


def test_pipeline_remainder_error_by_default():
    mesh, params, stage_fn, x, _ = _remainder_setup()
    with pytest.raises(ValueError, match="n_microbatches"):
        pipeline_forward(stage_fn, params, x, mesh, n_microbatches=4)


def test_pipeline_remainder_pad_keeps_all_rows():
    mesh, params, stage_fn, x, ref = _remainder_setup()
    y = pipeline_forward(stage_fn, params, x, mesh, n_microbatches=4,
                         remainder="pad")
    assert y.shape == x.shape
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-5)


def test_pipeline_remainder_drop_truncates():
    mesh, params, stage_fn, x, ref = _remainder_setup()
    y = pipeline_forward(stage_fn, params, x, mesh, n_microbatches=4,
                         remainder="drop")
    assert y.shape[0] == 4          # largest multiple of 4 below 7
    np.testing.assert_allclose(np.asarray(y), ref[:4], rtol=1e-5,
                               atol=1e-5)
