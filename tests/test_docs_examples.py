"""The documentation front door must not rot.

Two layers of defense (the CI ``docs`` job runs both, slow included):

  * link/anchor integrity — every relative markdown link in README.md
    and docs/*.md resolves to a real file, every ``#anchor`` matches a
    real heading slug in its target, and every docs page is reachable
    from docs/index.md;
  * executable quickstart — the README's quickstart commands actually
    run: ``examples/quickstart.py`` end to end, and a 2-request engine
    session equivalent to the README's serving snippet.
"""

import re
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _slug(heading: str) -> str:
    """GitHub-style heading slug: lowercase, inline-code/emphasis
    markers stripped, non-word punctuation dropped, then *each* space
    becomes a dash (GitHub does not collapse runs — `a / b` slugs to
    `a--b`). Underscores survive: they are word characters."""
    h = heading.strip().lower()
    h = re.sub(r"[`*]", "", h)
    h = re.sub(r"[^\w\s-]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def _anchors(md_path: Path) -> set[str]:
    text = _CODE_FENCE.sub("", md_path.read_text())
    return {_slug(h) for h in _HEADING.findall(text)}


def _links(md_path: Path) -> list[str]:
    text = _CODE_FENCE.sub("", md_path.read_text())
    return _LINK.findall(text)


def test_docs_exist():
    for f in DOC_FILES:
        assert f.exists(), f
    assert (ROOT / "docs" / "index.md").exists(), "docs need a front door"


@pytest.mark.parametrize("md", DOC_FILES, ids=lambda p: p.name)
def test_markdown_links_and_anchors_resolve(md):
    for link in _links(md):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        target, _, anchor = link.partition("#")
        target_path = (md.parent / target).resolve() if target else md
        assert target_path.exists(), f"{md.name}: dead link {link!r}"
        if anchor:
            assert target_path.suffix == ".md", \
                f"{md.name}: anchor into non-markdown {link!r}"
            anchors = _anchors(target_path)
            assert anchor in anchors, (
                f"{md.name}: anchor {link!r} not found; "
                f"{target_path.name} has {sorted(anchors)}")


def test_every_docs_page_reachable_from_index():
    index = ROOT / "docs" / "index.md"
    linked = {(index.parent / l.partition("#")[0]).resolve()
              for l in _links(index) if not l.startswith("http")
              if l.partition("#")[0]}
    for page in (ROOT / "docs").glob("*.md"):
        if page.name == "index.md":
            continue
        assert page.resolve() in linked, \
            f"docs/{page.name} is not linked from docs/index.md"


def test_readme_quickstart_commands_are_current():
    """Every ``python -m`` module and script path the README tells the
    reader to run must exist in the tree."""
    text = (ROOT / "README.md").read_text()
    for mod in set(re.findall(r"python -m ([\w.]+)", text)):
        if not mod.startswith(("repro", "benchmarks")):
            continue              # stdlib / third-party (e.g. pytest)
        rel = mod.replace(".", "/")
        assert ((ROOT / "src" / (rel + ".py")).exists()
                or (ROOT / (rel + ".py")).exists()
                or (ROOT / "src" / rel).is_dir()
                or (ROOT / rel).is_dir()), f"README names missing {mod}"
    for script in set(re.findall(r"python (\S+\.py)", text)):
        assert (ROOT / script).exists(), f"README names missing {script}"


# ---------------------------------------------------------------------------
# Executable quickstart (CI docs job; slow — compiles a real model)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_quickstart_example_runs():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT / "src"), env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    out = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "quickstart.py")],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_readme_engine_session():
    """The README's serving snippet: build an engine, stream two
    requests (sharing a prompt prefix, prefix cache on), drain results."""
    import jax

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve import Engine, EngineConfig, Request

    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=8, token_budget=32, max_seq_len=64,
        prefix_cache_mb=64))
    prefix = [1, 2, 3, 4, 5, 6, 7, 8]
    eng.submit(Request("a", prefix + [9, 10], max_new_tokens=4))
    eng.submit(Request("b", prefix + [11, 12], max_new_tokens=4))
    events = list(eng.run())
    assert {e.request_id for e in events} == {"a", "b"}
    assert len(eng.pop_result("a").out_tokens) == 4
    assert len(eng.pop_result("b").out_tokens) == 4
    summary = eng.stats.summary()
    assert summary["completed_requests"] == 2
    assert "prefix_cache" in summary
