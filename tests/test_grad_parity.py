"""Gradient parity: every equivalence in this repo, under jax.grad.

The forward suites (test_taylor_core / test_kernels) prove direct ≡
efficient ≡ causal-chunked and kernels ≡ jnp reference. Training through
the fused path additionally requires those identities to hold for the
*cotangents* — the custom VJPs (kernels/taylor_grad.py, the chunked-scan
VJP in core/taylor.py) are hand-written, so nothing but these tests
keeps them honest.

All kernel tests run the Pallas bodies in interpret mode on CPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import taylor as T
from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def rand_qkvw(key, b, h, n, d):
    ks = jax.random.split(key, 4)
    return tuple(jax.random.normal(k, (b, h, n, d)) for k in ks)


def assert_grads_close(g1, g2, *, rtol=1e-4, atol=1e-4, msg=""):
    for name, a, b in zip("qkvt", g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol,
                                   err_msg=f"{msg} grad wrt {name}")


# ---------------------------------------------------------------------------
# Core (pure-jnp) parity: direct ≡ efficient ≡ causal-chunked under grad
# ---------------------------------------------------------------------------

class TestCoreGradParity:
    @pytest.mark.parametrize("n,d", [(32, 8), (64, 16)])
    def test_direct_vs_efficient(self, n, d):
        q, k, v, w = rand_qkvw(jax.random.PRNGKey(n + d), 2, 2, n, d)
        fd = lambda q, k, v, t: jnp.sum(
            T.direct_taylorshift(q, k, v, tau=t) * w)
        fe = lambda q, k, v, t: jnp.sum(
            T.efficient_taylorshift(q, k, v, tau=t) * w)
        gd = jax.grad(fd, argnums=(0, 1, 2, 3))(q, k, v, 1.3)
        ge = jax.grad(fe, argnums=(0, 1, 2, 3))(q, k, v, 1.3)
        assert_grads_close(gd, ge, msg="direct vs efficient")

    @pytest.mark.parametrize("chunk", [4, 8, 32])
    def test_causal_chunked_vs_direct(self, chunk):
        """The chunked scan's recompute-based custom VJP must reproduce
        autodiff of the masked direct oracle."""
        q, k, v, w = rand_qkvw(jax.random.PRNGKey(chunk), 2, 2, 32, 8)
        fc = lambda q, k, v, t: jnp.sum(
            T.causal_taylorshift(q, k, v, tau=t, chunk=chunk) * w)
        fd = lambda q, k, v, t: jnp.sum(
            T.causal_direct_taylorshift(q, k, v, tau=t) * w)
        gc = jax.grad(fc, argnums=(0, 1, 2, 3))(q, k, v, 0.9)
        gd = jax.grad(fd, argnums=(0, 1, 2, 3))(q, k, v, 0.9)
        assert_grads_close(gc, gd, msg=f"causal chunk={chunk}")

    def test_causal_gqa_broadcast(self):
        """GQA lead dims: cotangents must reduce over the broadcast
        group axis, matching autodiff of the broadcast reference."""
        b, kv, g, n, d = 2, 2, 3, 32, 8
        key = jax.random.PRNGKey(31)
        q = jax.random.normal(key, (b, kv, g, n, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, 1, n, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, 1, n, d))
        w = jax.random.normal(jax.random.fold_in(key, 3), (b, kv, g, n, d))
        fc = lambda q, k, v: jnp.sum(
            T.causal_taylorshift(q, k, v, chunk=8) * w)
        fr = lambda q, k, v: jnp.sum(T.causal_direct_taylorshift(
            q, jnp.broadcast_to(k, q.shape), jnp.broadcast_to(v, q.shape))
            * w)
        gc = jax.grad(fc, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
        assert_grads_close(gc, gr, rtol=5e-4, atol=5e-4, msg="causal GQA")

    def test_causal_initial_state_chain(self):
        """Gradients flow through the TaylorState handoff: two chained
        chunked calls ≡ one big call (prefill-style training)."""
        d = 8
        key = jax.random.PRNGKey(7)
        q, k, v, _ = rand_qkvw(key, 1, 2, 16, d)

        def f_chain(q, k, v):
            y1, st = T.causal_taylorshift(q[:, :, :8], k[:, :, :8],
                                          v[:, :, :8], chunk=4,
                                          return_state=True)
            y2 = T.causal_taylorshift(q[:, :, 8:], k[:, :, 8:], v[:, :, 8:],
                                      chunk=4, initial_state=st)
            return jnp.sum(jnp.concatenate([y1, y2], 2) ** 2)

        f_whole = lambda q, k, v: jnp.sum(
            T.causal_taylorshift(q, k, v, chunk=4) ** 2)
        gc = jax.grad(f_chain, argnums=(0, 1, 2))(q, k, v)
        gw = jax.grad(f_whole, argnums=(0, 1, 2))(q, k, v)
        assert_grads_close(gc, gw, rtol=5e-4, atol=5e-4, msg="state chain")

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(4, 64),
        d=st.sampled_from([2, 4, 8]),
        tau=st.floats(0.25, 4.0),
        chunk=st.sampled_from([2, 4, 8, 16]),
        gqa=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_grad_equivalence_property(self, n, d, tau, chunk, gqa, seed):
        """Random (N, d, τ, chunk) incl. GQA shapes: ∇direct ≡ ∇efficient
        and ∇causal-chunked ≡ ∇causal-direct."""
        key = jax.random.PRNGKey(seed)
        kshape = (1, 1, 1, n, d) if gqa else (1, 2, n, d)
        qshape = (1, 1, 3, n, d) if gqa else (1, 2, n, d)
        q = jax.random.normal(key, qshape)
        k = jax.random.normal(jax.random.fold_in(key, 1), kshape)
        v = jax.random.normal(jax.random.fold_in(key, 2), kshape)
        kb = jnp.broadcast_to(k, q.shape)
        vb = jnp.broadcast_to(v, q.shape)

        fd = lambda q, k, v: jnp.sum(
            T.direct_taylorshift(q, k, v, tau=tau) ** 2)
        fe = lambda q, k, v: jnp.sum(
            T.efficient_taylorshift(q, k, v, tau=tau) ** 2)
        assert_grads_close(jax.grad(fd, argnums=(0, 1, 2))(q, kb, vb),
                           jax.grad(fe, argnums=(0, 1, 2))(q, kb, vb),
                           rtol=5e-4, atol=5e-4, msg="prop direct/efficient")

        c = min(chunk, n)
        while n % c:
            c -= 1
        fc = lambda q, k, v: jnp.sum(
            T.causal_taylorshift(q, k, v, tau=tau, chunk=max(c, 1)) ** 2)
        # reference broadcasts k/v inside, so its cotangents reduce to
        # the same GQA shapes the chunked path returns
        fr = lambda q, k, v: jnp.sum(T.causal_direct_taylorshift(
            q, jnp.broadcast_to(k, q.shape),
            jnp.broadcast_to(v, q.shape), tau=tau) ** 2)
        gc = jax.grad(fc, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
        assert_grads_close(gc, gr, rtol=1e-3, atol=1e-3, msg="prop causal")


# ---------------------------------------------------------------------------
# Pallas kernel custom VJPs vs autodiff of the jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.kernels
class TestKernelVJP:
    """Acceptance grid: N ∈ {64, 127, 256}, d ∈ {16, 32}, causal and
    non-causal, ≤1e-4 rtol at fp32. N=127 is prime — a regression for the
    `_good_block` pad-and-mask path (padded queries/keys must contribute
    exactly zero cotangent)."""

    # d=32 and N=256 rows run in the `grad-parity` CI job (which selects
    # `slow` too) rather than the fast default gate.
    N_GRID = [64, 127, pytest.param(256, marks=pytest.mark.slow)]
    D_GRID = [16, pytest.param(32, marks=pytest.mark.slow)]

    @pytest.mark.parametrize("n", N_GRID)
    @pytest.mark.parametrize("d", D_GRID)
    @pytest.mark.parametrize("causal", [False, True])
    def test_direct_kernel_grads_match_ref(self, n, d, causal):
        q, k, v, w = rand_qkvw(jax.random.PRNGKey(n * d), 1, 2, n, d)
        fk = lambda q, k, v, t: jnp.sum(ops.taylor_attention_kernel(
            q, k, v, tau=t, mode="direct", causal=causal,
            block_q=32, block_k=32, interpret=True) * w)
        fr = lambda q, k, v, t: jnp.sum(
            ref.direct_ref(q, k, v, tau=t, causal=causal) * w)
        gk = jax.grad(fk, argnums=(0, 1, 2, 3))(q, k, v, 1.3)
        gr = jax.grad(fr, argnums=(0, 1, 2, 3))(q, k, v, 1.3)
        assert_grads_close(gk, gr, msg=f"direct n={n} d={d} causal={causal}")

    @pytest.mark.parametrize("n", N_GRID)
    @pytest.mark.parametrize("d", D_GRID)
    def test_efficient_kernel_grads_match_ref(self, n, d):
        q, k, v, w = rand_qkvw(jax.random.PRNGKey(n * d + 1), 1, 2, n, d)
        fk = lambda q, k, v, t: jnp.sum(ops.taylor_attention_kernel(
            q, k, v, tau=t, mode="efficient",
            block_q=32, block_k=32, interpret=True) * w)
        fr = lambda q, k, v, t: jnp.sum(
            ref.direct_ref(q, k, v, tau=t) * w)
        gk = jax.grad(fk, argnums=(0, 1, 2, 3))(q, k, v, 1.3)
        gr = jax.grad(fr, argnums=(0, 1, 2, 3))(q, k, v, 1.3)
        assert_grads_close(gk, gr, msg=f"efficient n={n} d={d}")

    def test_good_block_pad_mask_grads(self):
        """Tiny prime N with aggressive padding (61 -> 64 at block 16):
        the pad-and-mask regression, under grad, for both kernels."""
        n, d = 61, 8
        q, k, v, w = rand_qkvw(jax.random.PRNGKey(61), 1, 2, n, d)
        for mode, causal in [("direct", False), ("direct", True),
                             ("efficient", False)]:
            fk = lambda q, k, v: jnp.sum(ops.taylor_attention_kernel(
                q, k, v, mode=mode, causal=causal,
                block_q=16, block_k=16, interpret=True) * w)
            fr = lambda q, k, v: jnp.sum(
                ref.direct_ref(q, k, v, causal=causal) * w)
            gk = jax.grad(fk, argnums=(0, 1, 2))(q, k, v)
            gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
            assert_grads_close(gk, gr, msg=f"pad-mask {mode} causal={causal}")

    def test_value_only_grad_bf16_values(self):
        """bf16 v: cotangent dtype must match the primal (custom_vjp
        contract), and the fp32-internal grads stay close to ref."""
        q, k, v, w = rand_qkvw(jax.random.PRNGKey(5), 1, 1, 64, 16)
        vb = v.astype(jnp.bfloat16)
        fk = lambda v: jnp.sum(ops.taylor_attention_kernel(
            q, k, v, mode="direct", interpret=True).astype(jnp.float32) * w)
        g = jax.grad(fk)(vb)
        assert g.dtype == jnp.bfloat16
        fr = lambda v: jnp.sum(
            ref.direct_ref(q, k, v).astype(jnp.float32) * w)
        gr = jax.grad(fr)(vb)
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(gr, np.float32),
                                   rtol=0.05, atol=0.05)

    @settings(max_examples=8, deadline=None)
    @given(
        n=st.integers(5, 80),
        d=st.sampled_from([4, 8, 16]),
        mode=st.sampled_from(["direct", "efficient"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_kernel_vjp_property(self, n, d, mode, seed):
        """Custom-VJP ≡ autodiff-of-reference for random shapes incl.
        non-divisible N (interpret mode)."""
        q, k, v, w = rand_qkvw(jax.random.PRNGKey(seed), 1, 1, n, d)
        fk = lambda q, k, v: jnp.sum(ops.taylor_attention_kernel(
            q, k, v, mode=mode, block_q=16, block_k=16, interpret=True) * w)
        fr = lambda q, k, v: jnp.sum(ref.direct_ref(q, k, v) * w)
        gk = jax.grad(fk, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
        assert_grads_close(gk, gr, rtol=5e-4, atol=5e-4,
                           msg=f"prop {mode} n={n} d={d}")


# ---------------------------------------------------------------------------
# Training-route integration: model grads through the fused path
# ---------------------------------------------------------------------------

@pytest.mark.kernels
class TestModelTrainRoute:
    @pytest.mark.slow
    def test_classifier_grads_kernel_vs_reference(self):
        """use_kernel=True must give the same classifier loss gradients
        as the pure-jnp route (the paper's §5 training setting)."""
        import dataclasses

        from repro.configs import get_config
        from repro.models import classifier as C

        base = get_config("taylorshift-lra").with_(
            d_model=32, n_layers=2, n_heads=2, n_kv_heads=2, d_ff=64,
            vocab=16, max_seq_len=33, remat=False, dtype="float32")
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(0), (2, 32),
                                         0, 16),
            "label": jnp.array([1, 7]),
        }
        params = C.classifier_init(base, 10, jax.random.PRNGKey(1))

        def grads(cfg):
            return jax.value_and_grad(
                lambda p: C.classifier_loss(p, cfg, batch))(params)

        cfg_k = base.with_(taylor=dataclasses.replace(base.taylor,
                                                      use_kernel=True))
        loss_r, g_r = grads(base)
        loss_k, g_k = grads(cfg_k)
        np.testing.assert_allclose(float(loss_k), float(loss_r), rtol=1e-5)
        flat_r = jax.tree.leaves(g_r)
        flat_k = jax.tree.leaves(g_k)
        for a, b in zip(flat_k, flat_r):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Backward peak memory: linear-memory training claim (§4.2, trained)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.kernels
class TestBackwardMemoryScaling:
    """XLA temp-buffer bytes of the compiled backward must grow
    sub-quadratically in N for the efficient custom-VJP path while the
    jnp reference grows ~N² (benchmarks/train_step_memory.py reports the
    full sweep)."""

    @staticmethod
    def _bwd_temp_bytes(loss_fn, n, d):
        s = jax.ShapeDtypeStruct((1, 2, n, d), jnp.float32)
        c = jax.jit(jax.grad(loss_fn, argnums=(0, 1, 2))).lower(s, s, s) \
            .compile()
        return int(c.memory_analysis().temp_size_in_bytes)

    def test_efficient_backward_subquadratic(self):
        import math
        d, n_lo, n_hi = 16, 128, 512

        def loss_ref(q, k, v):
            return jnp.sum(T.direct_taylorshift(q, k, v) ** 2)

        def loss_eff(q, k, v):
            return jnp.sum(ops.taylor_attention_kernel(
                q, k, v, mode="efficient", interpret=True) ** 2)

        growth = math.log(n_hi / n_lo)
        s_ref = math.log(self._bwd_temp_bytes(loss_ref, n_hi, d)
                         / self._bwd_temp_bytes(loss_ref, n_lo, d)) / growth
        s_eff = math.log(self._bwd_temp_bytes(loss_eff, n_hi, d)
                         / self._bwd_temp_bytes(loss_eff, n_lo, d)) / growth
        assert s_ref > 1.5, f"reference backward unexpectedly cheap: {s_ref}"
        assert s_eff < 1.3, f"efficient backward not sub-quadratic: {s_eff}"


# ---------------------------------------------------------------------------
# l2_normalize safe-norm regression
# ---------------------------------------------------------------------------

class TestL2NormalizeGrad:
    def test_zero_vector_grad_is_zero(self):
        """Regression: the naive x/(||x||+eps) formulation gives a
        spurious O(1/sqrt(eps)) (or NaN) gradient for an all-zero row;
        the safe-norm double-where must give exactly zero."""
        g = jax.grad(lambda x: jnp.sum(T.l2_normalize(x)))(jnp.zeros((3, 4)))
        assert bool(jnp.all(g == 0.0)), np.asarray(g)

    def test_zero_row_in_batch(self):
        """A zero row must not poison the gradients of its neighbors."""
        x = jnp.stack([jnp.zeros(4), jnp.arange(1.0, 5.0)])
        g = jax.grad(lambda x: jnp.sum(T.l2_normalize(x) ** 2))(x)
        assert bool(jnp.all(jnp.isfinite(g)))
        assert bool(jnp.all(g[0] == 0.0))

    def test_forward_still_normalizes(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        n = jnp.linalg.norm(T.l2_normalize(x), axis=-1)
        np.testing.assert_allclose(np.asarray(n), np.ones(8), rtol=1e-5)

    def test_grad_finite_everywhere(self):
        for scale in (1e-18, 1e-6, 1.0, 1e6):
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 8)) * scale
            g = jax.grad(lambda x: jnp.sum(T.l2_normalize(x)))(x)
            assert bool(jnp.all(jnp.isfinite(g))), scale

    def test_normalize_qk_grad_with_zero_rows(self):
        """Through the full attention entry: a zero q row (e.g. fully
        masked padding token) must not produce non-finite grads."""
        q, k, v, w = rand_qkvw(jax.random.PRNGKey(3), 1, 1, 16, 8)
        q = q.at[:, :, 0].set(0.0)
        f = lambda q, k, v: jnp.sum(T.efficient_taylorshift(q, k, v) * w)
        g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        for t in g:
            assert bool(jnp.all(jnp.isfinite(t)))
