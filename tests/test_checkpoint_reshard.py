"""Checkpoint restore across mesh topologies (checkpoint/manager.py).

A composed-mesh run saved under one ``(data, pipe, seq)`` shape must
restore under a *different* shape — elastic restarts change the device
count, and the manager's contract ("works across mesh topologies —
leaves are full arrays re-placed at load") is what makes the composed
3D path restartable at all. Saved from (2, 2, 2) with FSDP, restored
under (1, 2, 4): values identical, shardings follow the new mesh, and
one more optimizer step on the new mesh matches the same step taken on
the old mesh to ≤1e-4.

Runs under the CI ``train-parallel`` job (8 host devices); skips below.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.distributed import composed as C
from repro.launch import mesh as MESH
from repro.launch.steps import default_opt_config
from repro.optim import make_optimizer

N_DEV = len(jax.devices())
pytestmark = pytest.mark.skipif(
    N_DEV < 8, reason="needs 8 devices (CI train-parallel job)")

GB, N = 8, 256


def _cfg():
    cfg = get_config("taylorshift-lra").reduced()
    cfg = cfg.with_(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                    d_ff=64, max_seq_len=N, dtype="float32", causal=True)
    return cfg.with_(taylor=dataclasses.replace(
        cfg.taylor, mode="efficient", use_kernel=False))


def _step_fn_for(cfg, opt_cfg, mesh, *, mb):
    return C.build_composed_train_step(
        cfg, opt_cfg, mesh, global_batch=GB, seq_len=N,
        n_microbatches=mb, fsdp=True)


def test_restore_under_different_mesh_shape(tmp_path):
    cfg = _cfg()
    opt_cfg = default_opt_config(cfg)
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (GB, N), 0, cfg.vocab)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)

    # -- train one step on mesh A = (2, 2, 2), save --------------------
    mesh_a = MESH.make_composed_mesh(data=2, pipe=2, seq=2)
    init_fn, step_a, _ = _step_fn_for(cfg, opt_cfg, mesh_a, mb=2)
    params, opt_state = init_fn(jax.random.PRNGKey(0))
    params, opt_state, _ = step_a(params, opt_state, batch)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, (params, opt_state), blocking=True)
    mgr.wait()
    # host copies before the next step donates the device buffers
    params_host = jax.device_get(params)
    saved_opt_step = int(opt_state["step"])

    # the step we will compare against, continued on mesh A
    p_cont, o_cont, m_cont = step_a(params, opt_state, batch)
    loss_a = float(m_cont["loss"])
    p_cont = jax.device_get(p_cont)

    # -- restore under mesh B = (1, 2, 4) ------------------------------
    mesh_b = MESH.make_composed_mesh(data=1, pipe=2, seq=4)
    split_shapes = jax.eval_shape(C._split_shapes_thunk(cfg, 2))
    init_opt, _ = make_optimizer(opt_cfg)
    oshapes = jax.eval_shape(init_opt, split_shapes)
    pshard_b = C.composed_param_shardings(split_shapes, mesh_b, fsdp=True)
    oshard_b = C.composed_opt_shardings(oshapes, pshard_b, mesh_b)
    step0, (params_b, opt_b) = mgr.restore(
        (split_shapes, oshapes), shardings=(pshard_b, oshard_b))
    assert step0 == 1

    # values identical to what was saved, placed on the new mesh
    leaf_b = jax.tree.leaves(params_b["stages"])[0]
    assert leaf_b.sharding.mesh.shape == {"data": 1, "pipe": 2, "seq": 4}
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        params_b, params_host)
    assert int(opt_b["step"]) == saved_opt_step

    # -- one more step on mesh B matches the mesh-A continuation -------
    _, step_b, _ = _step_fn_for(cfg, opt_cfg, mesh_b, mb=4)
    p_b2, o_b2, m_b = step_b(params_b, opt_b, batch)
    assert abs(float(m_b["loss"]) - loss_a) <= 1e-4
    gerr = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            np.asarray(a) - np.asarray(b)))),
        jax.device_get(p_b2), jax.device_get(p_cont))))
    assert gerr <= 1e-4, f"post-restore step diverged by {gerr:.2e}"
