"""The unified attention-backend dispatch (models/backend.py).

Pins the selection matrix: capability flags × mesh × (N, d) × site must
reproduce every routing decision the old inline heuristics made —
crossovers, the sharding-aware non-causal override, the kernel gates,
the GQA fused-decode constraint — and the new sequence-parallel plan.
"""

import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core import taylor as T
from repro.distributed import ctx
from repro.models import backend as B


class FakeDevices:
    def __init__(self, size):
        self.size = size


class FakeMesh:
    """Just enough mesh for selection: axis_names, shape, device count."""

    def __init__(self, shape: dict, n_devices: int | None = None):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.devices = FakeDevices(
            n_devices if n_devices is not None else
            int(jax.numpy.prod(jax.numpy.asarray(list(shape.values())))))


def cfg_with(arch="stablelm-1.6b", **taylor_kw):
    cfg = get_config(arch).reduced()
    if taylor_kw:
        cfg = cfg.with_(taylor=dataclasses.replace(cfg.taylor, **taylor_kw))
    return cfg


def gqa_cfg(**taylor_kw):
    cfg = cfg_with(**taylor_kw)
    return cfg.with_(n_heads=4, n_kv_heads=2)


class TestRegistry:
    def test_issue_backends_present(self):
        for name in ("direct", "efficient", "causal-scan", "kernel-direct",
                     "kernel-efficient", "fused-decode"):
            assert name in B.REGISTRY, name

    def test_capability_sanity(self):
        r = B.REGISTRY
        # kernels have no GSPMD partitioning rule
        assert not r["kernel-direct"].caps.multi_device
        assert not r["kernel-efficient"].caps.multi_device
        assert not r["fused-decode"].caps.multi_device
        # the fused decode kernel's flat (B·H) layout can't group KV heads
        assert not r["fused-decode"].caps.gqa
        # only the chunk scan can shard the sequence axis
        assert [n for n, b in r.items() if b.caps.seq_parallel] \
            == ["causal-scan"]
        # every full-sequence backend carries the paper's cost model
        for n in ("direct", "efficient", "causal-scan", "kernel-direct",
                  "kernel-efficient"):
            assert r[n].ops is not None and r[n].entries is not None

    def test_cost_model_is_the_papers(self):
        assert B.REGISTRY["direct"].ops is T.ops_direct
        assert B.REGISTRY["efficient"].ops is T.ops_efficient


class TestFullSite:
    def test_crossover_auto(self):
        cfg = cfg_with()
        d = cfg.dim_head
        lo = B.select_backend(cfg, N=64, d=d, site="full", causal=False)
        hi = B.select_backend(cfg, N=int(T.crossover_n0(d)) + 64, d=d,
                              site="full", causal=False)
        assert lo.name == "direct" and lo.mode == "direct"
        assert hi.name == "efficient"
        assert lo.n0 == pytest.approx(T.crossover_n0(d))
        assert lo.n1 == pytest.approx(T.crossover_n1(d))

    def test_causal_beyond_crossover_is_scan(self):
        cfg = cfg_with()
        d = cfg.dim_head
        s = B.select_backend(cfg, N=int(T.crossover_n0(d)) + 64, d=d,
                             site="full", causal=True)
        assert s.name == "causal-scan"
        assert s.scan == "sequential" and s.seq_shards == 1
        assert s.chunk >= 1

    def test_chunk_plan_matches_old_heuristic(self):
        # old inline rule: chunk = min(max(tc.chunk, N // 8), N),
        # halved until it divides
        for n, want in [(256, 128), (1024, 128), (96, 128), (56, 16)]:
            chunk = min(max(want, n // 8), n)
            while n % chunk:
                chunk //= 2
            assert B.plan_chunk(n, want) == max(chunk, 1), (n, want)

    def test_kernel_gate_single_device(self):
        cfg = cfg_with(use_kernel=True)
        d = cfg.dim_head
        s = B.select_backend(cfg, N=64, d=d, site="full", causal=True)
        assert s.name == "kernel-direct"
        s = B.select_backend(cfg, N=int(T.crossover_n0(d)) + 64, d=d,
                             site="full", causal=False)
        assert s.name == "kernel-efficient"

    def test_kernel_gate_multi_device(self):
        """pallas_call has no partitioning rule: a >1-device mesh must
        fall back to the jnp paths (the old _taylor_global_kernel gate,
        now a capability check)."""
        cfg = cfg_with(use_kernel=True)
        mesh = FakeMesh({"data": 4, "model": 2})
        s = B.select_backend(cfg, N=64, d=cfg.dim_head, site="full",
                             causal=True, mesh=mesh)
        assert s.name == "direct"
        assert "partitioning" in s.reason

    def test_causal_efficient_stays_on_scan_core(self):
        cfg = cfg_with(use_kernel=True)
        d = cfg.dim_head
        s = B.select_backend(cfg, N=int(T.crossover_n0(d)) + 64, d=d,
                             site="full", causal=True)
        assert s.name == "causal-scan"

    def test_gqa_efficient_keeps_grouped_core(self):
        cfg = gqa_cfg(use_kernel=True)
        d = cfg.dim_head
        s = B.select_backend(cfg, N=int(T.crossover_n0(d)) + 64, d=d,
                             site="full", causal=False)
        assert s.name == "efficient" and not s.repeat_kv

    def test_gqa_direct_repeats_kv(self):
        cfg = gqa_cfg()
        s = B.select_backend(cfg, N=32, d=cfg.dim_head, site="full",
                             causal=True)
        assert s.name == "direct" and s.repeat_kv

    def test_sharding_aware_override_non_causal_only(self):
        """§Perf iteration 4 (ex-_sharding_aware_mode): uneven heads on
        the model axis push *non-causal* direct to efficient; causal
        keeps the crossover (measured regression)."""
        cfg = cfg_with().with_(n_heads=3, n_kv_heads=3, head_dim=32)
        mesh = FakeMesh({"data": 1, "model": 2}, n_devices=2)
        nc = B.select_backend(cfg, N=64, d=32, site="full", causal=False,
                              mesh=mesh)
        c = B.select_backend(cfg, N=64, d=32, site="full", causal=True,
                             mesh=mesh)
        assert nc.name == "efficient"
        assert c.name == "direct"

    def test_seq_mesh_selects_seq_parallel(self):
        cfg = cfg_with()
        d = cfg.dim_head
        mesh = FakeMesh({"data": 1, "seq": 4, "model": 1}, n_devices=4)
        n = int(T.crossover_n0(d)) + 64 - (int(T.crossover_n0(d)) + 64) % 4
        s = B.select_backend(cfg, N=n, d=d, site="full", causal=True,
                             mesh=mesh)
        assert s.name == "causal-scan"
        assert s.scan == "seq-parallel" and s.seq_shards == 4
        assert (n // 4) % s.chunk == 0

    def test_seq_mesh_indivisible_falls_back(self):
        cfg = cfg_with()
        d = cfg.dim_head
        mesh = FakeMesh({"data": 1, "seq": 4, "model": 1}, n_devices=4)
        s = B.select_backend(cfg, N=int(T.crossover_n0(d)) + 65, d=d,
                             site="full", causal=True, mesh=mesh)
        if s.name == "causal-scan":        # N odd -> can't split over 4
            assert s.seq_shards == 1 and s.scan == "sequential"

    def test_scan_pin_sequential_wins_over_mesh(self):
        cfg = cfg_with(scan="sequential")
        d = cfg.dim_head
        mesh = FakeMesh({"data": 1, "seq": 4, "model": 1}, n_devices=4)
        n = (int(T.crossover_n0(d)) + 64) // 4 * 4
        s = B.select_backend(cfg, N=n, d=d, site="full", causal=True,
                             mesh=mesh)
        assert s.seq_shards == 1 and s.scan == "sequential"


class TestDecodeSite:
    def test_fused_decode_mha(self):
        cfg = cfg_with(use_kernel=True)
        s = B.select_backend(cfg, N=1, d=cfg.dim_head, site="decode")
        assert s.name == "fused-decode"

    def test_gqa_blocks_fused_decode_via_caps(self):
        """The old inline `n_heads == kv_heads` if, now an explicit
        capability miss with the reason recorded."""
        cfg = gqa_cfg(use_kernel=True)
        s = B.select_backend(cfg, N=1, d=cfg.dim_head, site="decode")
        assert s.name == "causal-scan"
        assert "gqa" in s.reason.lower()

    def test_multi_device_blocks_fused_decode(self):
        cfg = cfg_with(use_kernel=True)
        mesh = FakeMesh({"data": 2, "model": 2})
        s = B.select_backend(cfg, N=1, d=cfg.dim_head, site="decode",
                             mesh=mesh)
        assert s.name == "causal-scan"

    def test_kernels_off_recurrent_step(self):
        cfg = cfg_with()
        s = B.select_backend(cfg, N=1, d=cfg.dim_head, site="decode")
        assert s.name == "causal-scan"

    def test_kv_cache_direct(self):
        cfg = cfg_with()
        s = B.select_backend(cfg, N=1, d=cfg.dim_head, site="decode",
                             cache_kind="kv")
        assert s.name == "direct"


class TestPrefillSite:
    def test_taylor_state_handoff(self):
        cfg = cfg_with()
        s = B.select_backend(cfg, N=128, d=cfg.dim_head, site="prefill")
        assert s.name == "causal-scan"
        assert s.chunk == 128          # one pass over the prefill chunk

    def test_seq_mesh_splits_prefill_chunk(self):
        cfg = cfg_with()
        mesh = FakeMesh({"data": 1, "seq": 4, "model": 1}, n_devices=4)
        s = B.select_backend(cfg, N=128, d=cfg.dim_head, site="prefill",
                             mesh=mesh)
        assert s.scan == "seq-parallel" and s.chunk == 32


class TestServePlan:
    def test_auto_cache_uses_memory_crossover(self):
        """Satellite: pick_mode(optimize_for='memory') now drives the
        serving path — short contexts go 'and Back' to the kv cache,
        long contexts to the constant-size Taylor state."""
        cfg = cfg_with()
        d = cfg.dim_head
        n1 = T.crossover_n1(d)
        short = B.select_serve_plan(cfg, max_seq_len=int(n1) // 2,
                                    prefill_chunk=16, cache_kind="auto")
        long = B.select_serve_plan(cfg, max_seq_len=int(n1) * 2,
                                   prefill_chunk=16, cache_kind="auto")
        assert short.cache_kind == "kv"
        assert long.cache_kind == "taylor"
        assert short.prefill.name == "direct"
        assert long.prefill.name == "causal-scan"
        assert "N1" in short.reason

    def test_pinned_cache_respected(self):
        cfg = cfg_with()
        p = B.select_serve_plan(cfg, max_seq_len=64, prefill_chunk=16,
                                cache_kind="taylor")
        assert p.cache_kind == "taylor"
        assert p.decode.name == "causal-scan"


class TestLauncherHelpers:
    def test_configure_for_training(self):
        cfg = cfg_with()
        assert not cfg.taylor.use_kernel
        on = B.configure_for_training(cfg)
        assert on.taylor.use_kernel
        off = B.configure_for_training(cfg, use_kernels=False)
        assert not off.taylor.use_kernel
        soft = B.configure_for_training(
            cfg.with_(attn_backend="softmax"))
        assert not soft.taylor.use_kernel

    def test_report_shape(self):
        cfg = cfg_with()
        r = B.report(cfg, N=4096, d=cfg.dim_head)
        assert set(r) == {"crossover_n0", "crossover_n1", "full",
                          "prefill", "decode"}
        for site in ("full", "prefill", "decode"):
            assert r[site]["backend"] in B.REGISTRY


class TestCalibratedOverrides:
    """repro.tune measured overrides vs the analytic Eq. (7)/(9)
    fallback: an installed table must move the routing thresholds AND
    stamp ``Selection.provenance = "calibrated"``; uninstalling must
    restore the analytic world bit-for-bit."""

    @pytest.fixture(autouse=True)
    def clean_install(self):
        from repro.tune import table as TU
        TU.uninstall()
        yield
        TU.uninstall()

    def _install(self, *entries):
        from repro.tune.table import TuneEntry, TuningTable
        from repro.tune import table as TU
        TU.install(TuningTable(backend=jax.default_backend(),
                               entries=[TuneEntry(**e) for e in entries]))

    def test_calibrated_n0_overrides_routing(self):
        cfg = cfg_with()
        d = cfg.dim_head
        n = int(T.crossover_n0(d)) + 64       # analytically "efficient"
        base = B.select_backend(cfg, N=n, d=d, site="full", causal=False)
        assert base.name == "efficient" and base.provenance == "analytic"
        self._install({"d": d, "n0": float(n + 128)})
        cal = B.select_backend(cfg, N=n, d=d, site="full", causal=False)
        assert cal.name == "direct"           # measured threshold moved
        assert cal.provenance == "calibrated"
        assert cal.n0 == pytest.approx(n + 128)
        assert cal.n1 == pytest.approx(T.crossover_n1(d))  # not measured

    def test_uninstall_restores_analytic(self):
        from repro.tune import table as TU
        cfg = cfg_with()
        d = cfg.dim_head
        self._install({"d": d, "n0": 1e9})
        TU.uninstall()
        s = B.select_backend(cfg, N=int(T.crossover_n0(d)) + 64, d=d,
                             site="full", causal=False)
        assert s.name == "efficient" and s.provenance == "analytic"
        assert s.n0 == pytest.approx(T.crossover_n0(d))

    def test_site_specific_entry_beats_wildcard(self):
        cfg = cfg_with()
        d = cfg.dim_head
        n = int(T.crossover_n0(d)) + 64
        self._install({"d": d, "n0": 1.0},                     # wildcard
                      {"d": d, "site": "full", "n0": float(n + 128)})
        s = B.select_backend(cfg, N=n, d=d, site="full", causal=False)
        assert s.name == "direct" and s.n0 == pytest.approx(n + 128)

    def test_unmeasured_head_dim_stays_analytic(self):
        cfg = cfg_with()
        d = cfg.dim_head
        self._install({"d": d + 1, "n0": 1e9})    # wrong head dim
        s = B.select_backend(cfg, N=int(T.crossover_n0(d)) + 64, d=d,
                             site="full", causal=False)
        assert s.name == "efficient" and s.provenance == "analytic"

    def test_calibrated_n1_moves_serve_plan_cache(self):
        """The 'and Back' memory resolution (cache_kind='auto') runs on
        the measured N1 when one is installed — through the taylor
        crossover hook, the same global select_backend reads."""
        cfg = cfg_with()
        d = cfg.dim_head
        L = int(T.crossover_n1(d)) // 2       # analytically kv territory
        assert B.select_serve_plan(cfg, max_seq_len=L, prefill_chunk=16,
                                   cache_kind="auto").cache_kind == "kv"
        self._install({"d": d, "n1": float(L // 2)})
        assert B.select_serve_plan(cfg, max_seq_len=L, prefill_chunk=16,
                                   cache_kind="auto").cache_kind == "taylor"

    def test_decision_log_carries_provenance(self):
        from repro.obs import decisions as D
        cfg = cfg_with()
        d = cfg.dim_head
        self._install({"d": d, "n0": 1e9})
        D.log.enable()
        try:
            B.select_backend(cfg, N=64, d=d, site="full", causal=False)
            recs = list(D.log.records)
        finally:
            D.log.disable()
            D.log.clear()
        assert recs and recs[-1]["provenance"] == "calibrated"


class TestAmbientContext:
    def test_defaults_to_ctx(self):
        """select_backend with no mesh reads the ambient sharding ctx
        (the in-jit path attention layers take)."""
        cfg = cfg_with(use_kernel=True)
        mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
        s0 = B.select_backend(cfg, N=64, d=cfg.dim_head, site="full",
                              causal=True)
        assert s0.name == "kernel-direct"
        with ctx.use(mesh):
            s1 = B.select_backend(cfg, N=64, d=cfg.dim_head, site="full",
                                  causal=True)
        # single local device: kernels stay in play under ctx.use
        if len(jax.devices()) == 1:
            assert s1.name == "kernel-direct"
        else:
            assert s1.name == "direct"
