"""Serving engine: chunked prefill, continuous batching, slot pool.

The invariants the engine's correctness rests on:
  * chunked prefill + recurrent decode ≡ token-by-token decode loop;
  * batching is invisible: staggered arrivals sharing decode batches
    produce exactly the tokens each request gets when run alone;
  * a released slot carries nothing into its next occupant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import naive_generate
from repro.models import model as M
from repro.serve import Engine, EngineConfig, QueueFullError, Request
from repro.serve.prefill import plan_chunks

SEQ = 24


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, n, seed):
    return [int(t) for t in jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, cfg.vocab)]


# ---------------------------------------------------------------------------
# Chunked prefill ≡ token-by-token
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("cache_kind", ["taylor", "kv"])
@pytest.mark.parametrize("chunks", [(SEQ,), (16, 8), (8, 8, 8), (13, 11)])
def test_prefill_chunk_logit_equivalent(setup, cache_kind, chunks):
    """prefill_chunk over any chunking must reproduce the logits of the
    teacher-forced single-token loop (the old serve.py prefill)."""
    cfg, params = setup
    assert sum(chunks) == SEQ
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, SEQ), 0, cfg.vocab)

    cache = M.init_decode_state(cfg, 1, cache_len=SEQ + 4,
                                cache_kind=cache_kind, dtype=jnp.float32)
    outs = []
    for t in range(SEQ):
        lg, cache = M.decode_step(params, cfg, {"tokens": tokens[:, t:t+1]},
                                  cache)
        outs.append(lg)
    lg_loop = jnp.concatenate(outs, axis=1)

    c2 = M.init_decode_state(cfg, 1, cache_len=SEQ + 4,
                             cache_kind=cache_kind, dtype=jnp.float32)
    outs, lo = [], 0
    for c in chunks:
        lg, c2 = M.prefill_chunk(params, cfg,
                                 {"tokens": tokens[:, lo:lo+c]}, c2)
        outs.append(lg)
        lo += c
    lg_chunked = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(np.asarray(lg_loop), np.asarray(lg_chunked),
                               rtol=1e-4, atol=1e-4)

    # and decode continues identically from either state
    nxt = jnp.full((1, 1), 3, jnp.int32)
    lg_a, _ = M.decode_step(params, cfg, {"tokens": nxt}, cache)
    lg_b, _ = M.decode_step(params, cfg, {"tokens": nxt}, c2)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_engine_matches_naive_baseline(setup):
    """Engine generation (chunked prefill + pooled decode) == naive
    token-by-token generation, exactly, at temperature 0."""
    cfg, params = setup
    prompt = _prompt(cfg, 19, seed=3)
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=8, token_budget=32, max_seq_len=64))
    out = eng.generate([Request("r", prompt, max_new_tokens=8)])["r"]
    ref = naive_generate(cfg, params, jnp.asarray([prompt], jnp.int32),
                         gen_tokens=8)
    assert out == [int(t) for t in ref[0, len(prompt):]]


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_staggered_arrivals_match_solo_runs(setup):
    """Requests admitted mid-flight share decode batches with running
    sequences yet produce exactly the solo-run tokens."""
    cfg, params = setup
    prompts = {f"r{i}": _prompt(cfg, 10 + 3 * i, seed=10 + i)
               for i in range(3)}
    reqs = {rid: Request(rid, p, max_new_tokens=6)
            for rid, p in prompts.items()}

    eng = Engine(cfg, params, EngineConfig(
        n_slots=3, prefill_chunk=8, token_budget=24, max_seq_len=64))
    eng.submit(reqs["r0"])
    shared = 0
    arrivals = {3: "r1", 5: "r2"}
    while not eng.idle or arrivals:
        due = [s for s in arrivals if s <= eng.step_idx]
        for s in due:
            eng.submit(reqs[arrivals.pop(s)])
        m, _ = eng.step()
        shared = max(shared, m.active_decoding)
    assert shared >= 2, "late arrivals never joined a shared decode batch"

    for rid, p in prompts.items():
        solo = Engine(cfg, params, EngineConfig(
            n_slots=1, prefill_chunk=8, token_budget=24, max_seq_len=64))
        want = solo.generate([Request(rid, p, max_new_tokens=6)])[rid]
        assert eng.results[rid].out_tokens == want, rid


def test_engine_rejects_unsupported_patterns():
    """Local-window (ring cache) and SSM blocks have no chunked-prefill
    state handoff yet: the engine must refuse them up front rather than
    silently prefilling their windows as global context."""
    for arch in ("gemma3-1b", "zamba2-7b"):
        cfg = get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(NotImplementedError):
            Engine(cfg, params, EngineConfig(n_slots=1, max_seq_len=64))


def test_admission_backpressure(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(n_slots=1, max_queue=2,
                                           max_seq_len=64))
    for i in range(2):
        eng.submit(Request(f"q{i}", _prompt(cfg, 4, seed=i)))
    with pytest.raises(QueueFullError):
        eng.submit(Request("q2", _prompt(cfg, 4, seed=9)))


# ---------------------------------------------------------------------------
# Slot pool hygiene
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_slot_reuse_does_not_leak_state(setup):
    """A slot that served a long request must serve a later request
    identically to a fresh engine — and is zeroed right at release."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        n_slots=1, prefill_chunk=8, token_budget=16, max_seq_len=64))
    p1 = _prompt(cfg, 21, seed=40)
    eng.generate([Request("a", p1, max_new_tokens=5)])
    leftovers = sum(float(jnp.sum(jnp.abs(x)))
                    for x in jax.tree.leaves(eng.pool.gather(0)))
    assert leftovers == 0.0, "released slot not zero-reset"

    p2 = _prompt(cfg, 9, seed=41)
    reused = eng.generate([Request("b", p2, max_new_tokens=5)])["b"]
    fresh_eng = Engine(cfg, params, EngineConfig(
        n_slots=1, prefill_chunk=8, token_budget=16, max_seq_len=64))
    fresh = fresh_eng.generate([Request("b", p2, max_new_tokens=5)])["b"]
    assert reused == fresh


@pytest.mark.slow
def test_engine_restart_mid_stream(setup):
    """Kill an engine mid-generation and restart from scratch: the fresh
    engine must produce exactly the clean-run tokens (no state survives
    outside the engine), and the abandoned engine's partial output must
    be a prefix of the clean run (greedy decode is deterministic)."""
    cfg, params = setup
    prompts = {"r0": _prompt(cfg, 17, seed=50), "r1": _prompt(cfg, 9, seed=51)}
    mk = lambda: Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=8, token_budget=24, max_seq_len=64))

    clean = mk().generate(
        [Request(rid, p, max_new_tokens=8) for rid, p in prompts.items()])

    crashed = mk()
    for rid, p in prompts.items():
        crashed.submit(Request(rid, p, max_new_tokens=8))
    for _ in range(4):           # mid-stream: prefill done, decode underway
        crashed.step()
    partial = {rid: list(s.out_tokens)
               for rid, s in crashed.sequences.items()}
    assert any(partial.values()), "restart happened before any token"
    for rid, toks in partial.items():
        assert toks == clean[rid][:len(toks)], rid

    restarted = mk()             # the old engine is simply dropped
    out = restarted.generate(
        [Request(rid, p, max_new_tokens=8) for rid, p in prompts.items()])
    assert out == clean


def test_restart_released_slots_are_reset(setup):
    """After a mid-stream abandon, finishing the remaining work through
    the same pool must leave every slot zeroed once drained — the
    release path, not scatter, is what guarantees a clean slot."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=8, token_budget=24, max_seq_len=64))
    eng.submit(Request("a", _prompt(cfg, 12, seed=60), max_new_tokens=4))
    eng.submit(Request("b", _prompt(cfg, 7, seed=61), max_new_tokens=4))
    for _ in range(3):
        eng.step()
    for _ in eng.run():          # drain to idle
        pass
    assert eng.idle
    for slot in range(eng.pool.n_slots):
        leftovers = sum(float(jnp.sum(jnp.abs(x)))
                        for x in jax.tree.leaves(eng.pool.gather(slot)))
        assert leftovers == 0.0, f"slot {slot} not zero-reset"


def test_long_prefill_does_not_starve_decode(setup):
    """Scheduler starvation: while a long prompt prefills, every
    DECODING sequence still gets exactly one token per step, and prefill
    work per step stays within the token budget (modulo the one-chunk
    minimum that guarantees progress)."""
    cfg, params = setup
    budget, chunk = 12, 4
    eng = Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=chunk, token_budget=budget, max_seq_len=64))

    # short request reaches DECODING first
    eng.submit(Request("short", _prompt(cfg, 4, seed=70), max_new_tokens=24))
    eng.step()
    assert eng.sequences["short"].out_tokens, "short prompt not prefilled"

    # long prompt needs many chunked-prefill steps under this budget
    eng.submit(Request("long", _prompt(cfg, 40, seed=71), max_new_tokens=2))
    decode_starved = []
    while ("long" in eng.sequences
           and not eng.sequences["long"].prefill_done
           and "short" in eng.sequences):
        before = len(eng.sequences["short"].out_tokens)
        m, _ = eng.step()
        after = len(eng.sequences["short"].out_tokens) \
            if "short" in eng.sequences else before + 1
        decode_starved.append(after - before == 0)
        # decode goes first; prefill spends at most the leftover budget,
        # except the guaranteed first chunk
        assert m.prefill_tokens <= max(budget - m.decode_tokens, chunk)
    assert decode_starved, "long prefill finished before any shared step"
    assert not any(decode_starved), \
        "a decoding sequence was starved during a long prefill"
    for _ in eng.run():
        pass
    assert eng.results["short"].out_tokens and eng.results["long"].out_tokens


# ---------------------------------------------------------------------------
# Sequence-parallel serving (multi-device CI job)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device host platform "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")
def test_prefill_state_parity_under_seq_mesh(setup):
    """The engine's chunked prefill must produce the same TaylorState —
    and then the same tokens — whether the model runs under a
    `seq`-sharded mesh (sequence-parallel causal scan + boundary-state
    exchange) or on a single device."""
    from repro.distributed import ctx
    from repro.launch.mesh import make_seq_mesh
    from repro.serve.request import SequenceStatus

    cfg, params = setup
    # 19 = 2×8 + 2 + 1: full chunks split over the seq axis, the
    # power-of-two tail falls back to the sequential scan
    prompt = _prompt(cfg, 19, seed=77)

    def prefilled_state_and_tokens(use_mesh):
        eng = Engine(cfg, params, EngineConfig(
            n_slots=1, prefill_chunk=8, token_budget=32, max_seq_len=64))
        eng.submit(Request("r", prompt, max_new_tokens=4))
        while ("r" in eng.sequences
               and eng.sequences["r"].status != SequenceStatus.DECODING):
            eng.step()
        state = jax.tree.map(lambda x: np.asarray(x), eng.pool.gather(0))
        for _ in eng.run():
            pass
        return state, eng.results["r"].out_tokens

    mesh = make_seq_mesh()
    with mesh, ctx.use(mesh):
        st_mesh, toks_mesh = prefilled_state_and_tokens(True)
    st_ref, toks_ref = prefilled_state_and_tokens(False)

    flat_m = jax.tree_util.tree_flatten_with_path(st_mesh)[0]
    flat_r = jax.tree_util.tree_flatten_with_path(st_ref)[0]
    for (path, a), (_, b) in zip(flat_m, flat_r):
        np.testing.assert_allclose(
            a, b, rtol=1e-5, atol=1e-5,
            err_msg="/".join(str(p) for p in path))
    assert toks_mesh == toks_ref


def test_observability_is_purely_observational(setup):
    """The §4.6 contract: tracing, metrics resets, and the decision log
    never leak into scheduling/selection/sampling. A greedy 2-request
    session with reset_metrics() mid-run AND tracing/decision-logging
    toggled mid-run streams bit-identical tokens vs an uninstrumented
    engine."""
    from repro.obs import decisions as OD
    from repro.obs.trace import tracer

    cfg, params = setup
    prompts = {f"r{i}": _prompt(cfg, 12 + 5 * i, seed=40 + i)
               for i in range(2)}
    ecfg = EngineConfig(n_slots=2, prefill_chunk=8, token_budget=24,
                        max_seq_len=64)

    def session(instrumented):
        eng = Engine(cfg, params, ecfg)
        for rid, p in prompts.items():
            eng.submit(Request(rid, p, max_new_tokens=8))
        step = 0
        while not eng.idle:
            if instrumented:          # toggle everything mid-stream
                if step == 1:
                    tracer.enable()
                    OD.log.enable()
                if step == 3:
                    eng.reset_metrics()
                if step == 5:
                    tracer.disable()
                    OD.log.disable()
            eng.step()
            step += 1
        return {rid: eng.results[rid].out_tokens for rid in prompts}

    plain = session(instrumented=False)
    try:
        traced = session(instrumented=True)
    finally:                          # never leak global switches
        tracer.disable()
        tracer.clear()
        OD.log.disable()
        OD.log.records.clear()
    assert traced == plain


def test_plan_chunks():
    assert plan_chunks(24, 8) == [8, 8, 8]
    assert plan_chunks(21, 8) == [8, 8, 4, 1]
    assert plan_chunks(5, 8) == [4, 1]
    assert plan_chunks(1, 128) == [1]
    # bounded retrace surface: only powers of two below the chunk size
    for n in range(1, 70):
        for c in plan_chunks(n, 16):
            assert c == 16 or (c & (c - 1)) == 0
        assert sum(plan_chunks(n, 16)) == n
