"""Unit tests for the distribution layer: param sharding rules, ZeRO-1
augmentation, cache shardings, greedy sharder, HLO cost model."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as S
from repro.distributed.hlo_cost import analyze
from repro.launch.steps import param_shapes


@pytest.fixture(scope="module")
def mesh():
    # a miniature (data, model) mesh with the same axis names
    return jax.make_mesh((1, 1), ("data", "model"))


def spec_of(tree, mesh):
    shard = S.param_shardings(tree, mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, s: (S._path_str(p), s.spec), shard)


class TestParamRules:
    def test_core_rules(self, mesh):
        sds = jax.ShapeDtypeStruct
        tree = {
            "embed": {"emb": sds((3200, 64), jnp.bfloat16)},
            "groups": [{
                "attn": {"wq": {"w": sds((64, 128), jnp.bfloat16)},
                         "wo": {"w": sds((128, 64), jnp.bfloat16)},
                         "tau": sds((4,), jnp.float32)},
                "mlp": {"up": {"w": sds((64, 256), jnp.bfloat16)},
                        "down": {"w": sds((256, 64), jnp.bfloat16)}},
                "norm1": {"scale": sds((64,), jnp.float32)},
            }],
        }
        sh = S.param_shardings(tree, mesh)
        g = sh["groups"][0]
        assert sh["embed"]["emb"].spec == P("model", None)
        assert g["attn"]["wq"]["w"].spec == P(None, "model")
        assert g["attn"]["wo"]["w"].spec == P("model", None)
        assert g["mlp"]["up"]["w"].spec == P(None, "model")
        assert g["mlp"]["down"]["w"].spec == P("model", None)
        assert g["attn"]["tau"].spec == P(None)
        assert g["norm1"]["scale"].spec == P(None)

    def test_stacked_layer_dim_padded(self, mesh):
        tree = {"groups": [{"attn": {"wq": {"w": jax.ShapeDtypeStruct(
            (12, 64, 128), jnp.bfloat16)}}}]}
        sh = S.param_shardings(tree, mesh)
        assert sh["groups"][0]["attn"]["wq"]["w"].spec == P(None, None, "model")

    def test_moe_ep_vs_fsdp(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}

        # 128 experts divide the 16-way model axis => EP placement
        spec = S._spec_for_param("groups/0/moe/w_up", (128, 64, 256),
                                 FakeMesh())
        assert spec[0] == "model" and spec[2] == "data"
        # 8 experts do NOT divide 16 => FSDP-style 2D weight sharding
        spec = S._spec_for_param("groups/0/moe/w_up", (8, 64, 256),
                                 FakeMesh())
        assert spec[0] is None
        assert spec[1] == "data" or spec[2] == "model"
        spec = S._spec_for_param("groups/0/moe/w_down", (8, 256, 64),
                                 FakeMesh())
        assert spec[0] is None and spec[1] == "model"

    def test_full_arch_no_unsharded_giants(self, mesh):
        """No parameter > 200M elements may be fully replicated."""
        cfg = get_config("grok-1-314b")
        shapes = param_shapes(cfg)
        sh = S.param_shardings(shapes, mesh)
        flat_sh = jax.tree_util.tree_flatten_with_path(sh)[0]
        flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for (path, shard), (_, leaf) in zip(flat_sh, flat_s):
            n = 1
            for d in leaf.shape:
                n *= d
            if n > 200e6:
                assert any(s is not None for s in shard.spec), \
                    f"{S._path_str(path)} {leaf.shape} replicated"


class TestZero1:
    def test_adds_data_axis(self, mesh):
        sds = jax.ShapeDtypeStruct
        shapes = {"w": sds((64, 128), jnp.float32)}
        psh = S.param_shardings({"mlp": {"up": {"w": shapes["w"]}}}, mesh)
        zsh = S.zero1_shardings(psh, {"mlp": {"up": {"w": shapes["w"]}}}, mesh)
        spec = zsh["mlp"]["up"]["w"].spec
        assert "data" in [a for s in spec for a in
                          ((s,) if not isinstance(s, tuple) else s) if a]


class TestGreedySharder:
    def test_batch_then_biggest(self, mesh):
        spec = S.greedy_spec((8, 4, 1024), mesh, batch_dim=0)
        assert spec[0] in ("data", ("data",))
        assert spec[2] == "model"

    def test_indivisible_skipped(self, mesh):
        spec = S.greedy_spec((7, 3), mesh, batch_dim=0)
        # 7 % 1 == 0 for this mini-mesh; structural check only
        assert len(spec) <= 2


class TestHloCostModel:
    def test_while_trip_multiplier(self):
        hlo = """
%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %y = f32[8,8]{1,0} multiply(%x, %x)
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %y)
}
%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
        a = analyze(hlo)
        assert a["flops"] == pytest.approx(7 * 64 + 64, rel=0.2)

    def test_collective_wire_model(self):
        hlo = """
ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %a = f32[16,16]{1,0} parameter(0)
  ROOT %ar = f32[16,16]{1,0} all-reduce(%a), replica_groups={{0,1,2,3}}, to_apply=%add
}
%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}
"""
        a = analyze(hlo)
        # ring all-reduce: 2 * 1024B * 3/4
        assert a["coll_wire_bytes"] == pytest.approx(2 * 1024 * 0.75)

    def test_dynamic_slice_charged_at_slice_size(self):
        hlo = """
ENTRY %main (p0: f32[4096,16,48], p1: s32[]) -> f32[1,16,48] {
  %p0 = f32[4096,16,48]{2,1,0} parameter(0)
  %p1 = s32[] parameter(1)
  %c = s32[] constant(0)
  ROOT %ds = f32[1,16,48]{2,1,0} dynamic-slice(%p0, %p1, %c, %c), dynamic_slice_sizes={1,16,48}
}
"""
        a = analyze(hlo)
        assert a["bytes"] == 2 * 16 * 48 * 4
