"""Speculative generation (src/repro/spec/ + engine integration).

The invariants:
  * greedy speculative decoding emits token streams bit-identical to
    the non-speculative engine — for every speculate_k, both drafters,
    both cache kinds, on the mixed-arrival serving workload;
  * StatePool.snapshot → mutate → restore round-trips bit-exactly for
    Taylor state, decode caches, and pos/n counters, across slot reuse;
  * per-request sampling (temperature / top-k / top-p) is seeded-RNG
    deterministic and independent of batching;
  * drafters always return exactly k tokens; the adaptive controller
    stays within [1, cap].
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import SpecConfig
from repro.launch.serve import mixed_arrival_workload, run_workload
from repro.models import backend as B
from repro.models import model as M
from repro.serve import Engine, EngineConfig, Request
from repro.serve.engine import _filter_logits
from repro.serve.pool import StatePool
from repro.serve.scheduler import Scheduler
from repro.spec.controller import DraftController
from repro.spec.drafter import ngram_propose, truncate_params
from repro.spec.verify import accepted_prefix

from tests._hypothesis_compat import given, settings, st


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompt(cfg, n, seed):
    return [int(t) for t in jax.random.randint(
        jax.random.PRNGKey(seed), (n,), 0, cfg.vocab)]


# ---------------------------------------------------------------------------
# Pure units: verification, drafting, controller, scheduler accounting
# ---------------------------------------------------------------------------

def test_accepted_prefix():
    # full acceptance: all k drafts match, bonus = greedy[k]
    assert accepted_prefix([3, 5, 7], [3, 5, 7, 9]) == (3, [3, 5, 7, 9])
    # first mismatch stops acceptance; the model's token there is free
    assert accepted_prefix([3, 5, 7], [3, 6, 1, 9]) == (1, [3, 6])
    assert accepted_prefix([3, 5], [4, 5, 7]) == (0, [4])
    # k = 0 degenerates to plain decode: bonus only
    assert accepted_prefix([], [8]) == (0, [8])


def test_ngram_propose_lookup_and_padding():
    # suffix [7, 8] occurred earlier, followed by 9, 1
    ctx = [7, 8, 9, 1, 2, 7, 8]
    assert ngram_propose(ctx, 2) == [9, 1]
    # long continuations may run into the suffix region — still history
    assert ngram_propose(ctx, 4) == [9, 1, 2, 7]
    # continuation shorter than k: padded by repeating the last token
    assert ngram_propose([5, 6, 9, 5, 6], 4) == [9, 5, 6, 6]
    # cyclic context: proposal continues the cycle
    cyc = [4, 5, 6] * 4
    assert ngram_propose(cyc, 3) == [4, 5, 6]
    # no match anywhere: repeat the last token, still exactly k tokens
    assert ngram_propose([1, 2, 3, 4], 3) == [4, 4, 4]
    with pytest.raises(ValueError):
        ngram_propose([], 2)


def test_ngram_drafter_index_matches_reference():
    """The drafter's incremental per-slot index must propose exactly
    what the reference rescan proposes, over growing contexts and
    across slot reuse."""
    from repro.spec.drafter import NgramDrafter

    class FakeSeq:
        def __init__(self, slot, prompt):
            self.slot = slot
            self.request = Request(f"f{slot}", prompt)
            self.out_tokens = []

    rng = np.random.RandomState(0)
    d = NgramDrafter()
    for round_ in range(2):                     # second round reuses slot 0
        seq = FakeSeq(0, [int(t) for t in rng.randint(0, 7, size=10)])
        for _ in range(30):
            ctx = [*seq.request.prompt, *seq.out_tokens]
            want = ngram_propose(ctx, 3)
            got = d.draft([seq], 3)[0]
            assert got == want, (round_, ctx)
            seq.out_tokens.append(int(rng.randint(0, 7)))
        d.release(0)


def test_ngram_prefers_longest_then_most_recent_match():
    # suffix [2, 9]: the length-2 match (-> 5) must beat the more
    # recent length-1 match of [9] (-> 7)
    ctx = [2, 9, 5, 3, 9, 7, 2, 9]
    assert ngram_propose(ctx, 1) == [5]
    # two length-1 matches of [9]: the most recent one (-> 7) wins
    ctx2 = [9, 5, 9, 7, 1, 9]
    assert ngram_propose(ctx2, 1, ngram_max=1) == [7]


def test_controller_adapts_within_bounds():
    c = DraftController(8, SpecConfig(ewma=1.0))   # rate = last observation
    assert c.k == 8
    c.update(0, 8)                                 # bad step: halve
    assert c.k == 4
    c.update(0, 4)
    c.update(0, 2)
    c.update(0, 1)
    assert c.k == 1                                # floor
    for _ in range(4):
        c.update(1, 1)                             # perfect: double to cap
    assert c.k == 8
    assert c.acceptance_rate == pytest.approx(4 / 19)

    fixed = DraftController(4, SpecConfig(adaptive=False, ewma=1.0))
    fixed.update(0, 4)
    assert fixed.k == 4                            # adaptivity off

    with pytest.raises(ValueError):
        DraftController(0)
    with pytest.raises(ValueError):
        c.update(5, 4)


def test_scheduler_decode_cost_counts_drafted_tokens():
    assert Scheduler.decode_cost(3) == 3           # one token per slot
    assert Scheduler.decode_cost(3, 4) == 15       # k+1 scored per slot


def test_verify_backend_selection(setup):
    cfg, _ = setup
    plan = B.select_serve_plan(cfg, max_seq_len=64, prefill_chunk=16,
                               cache_kind="taylor", speculate_k=4)
    assert plan.verify is not None
    assert plan.verify.name == "causal-scan"
    assert plan.verify.chunk == 5                  # one chunk of k+1
    kvplan = B.select_serve_plan(cfg, max_seq_len=64, prefill_chunk=16,
                                 cache_kind="kv", speculate_k=2)
    assert kvplan.verify.name == "direct"
    noplan = B.select_serve_plan(cfg, max_seq_len=64, prefill_chunk=16,
                                 cache_kind="taylor")
    assert noplan.verify is None


def test_truncate_params_views_first_layers(setup):
    cfg, params = setup                            # pattern ("global",)
    j = 1
    tp = truncate_params(params, cfg, j)
    for g_full, g_trunc in zip(params["groups"], tp["groups"]):
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(g_full)[0],
                jax.tree_util.tree_flatten_with_path(g_trunc)[0]):
            np.testing.assert_array_equal(np.asarray(a[:j]), np.asarray(b),
                                          err_msg=str(path))
    # shared (non-stacked) params are the same objects — no copies
    assert tp["embed"] is params["embed"]
    assert tp["final_norm"] is params["final_norm"]
    # full-depth truncation is the identity on structure and values
    full = truncate_params(params, cfg, cfg.n_layers)
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(full)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(path))
    with pytest.raises(ValueError):
        truncate_params(params, cfg, cfg.n_layers + 1)
    with pytest.raises(ValueError):
        truncate_params(params, cfg, 0)


def test_truncate_params_pattern_remainder():
    """P=2 pattern with odd truncation: the extra layer's params come
    from stack index j//P of the right pattern position."""
    cfg = get_config("stablelm-1.6b").reduced().with_(
        layer_pattern=("global", "global"), n_layers=4)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    tp = truncate_params(params, cfg, 3)           # 1 full group + 1 rem
    assert len(tp["rem"]) == 1
    leaves_rem = jax.tree_util.tree_leaves(tp["rem"][0])
    leaves_src = jax.tree_util.tree_leaves(
        jax.tree.map(lambda a: a[1], params["groups"][0]))
    for a, b in zip(leaves_src, leaves_rem):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Sampling: top-k / top-p filtering + seeded determinism
# ---------------------------------------------------------------------------

def test_filter_logits_top_k():
    lg = jnp.asarray([0.1, 2.0, -1.0, 3.0, 0.5])
    out = np.asarray(_filter_logits(lg, top_k=2, top_p=1.0))
    assert np.isfinite(out[[1, 3]]).all()
    assert np.isneginf(out[[0, 2, 4]]).all()
    # top_k larger than vocab keeps everything
    assert np.isfinite(np.asarray(_filter_logits(lg, 99, 1.0))).all()


def test_filter_logits_top_p():
    # softmax([~9, ~0, ...]) puts ~all mass on index 0: tiny top_p
    # keeps only the argmax (the first sorted token always survives)
    lg = jnp.asarray([9.0, 0.0, -1.0, 0.5])
    out = np.asarray(_filter_logits(lg, 0, 0.1))
    assert np.isfinite(out[0]) and np.isneginf(out[1:]).all()
    # top_p = 1 keeps everything
    assert np.isfinite(np.asarray(_filter_logits(lg, 0, 1.0))).all()
    # near-uniform logits with top_p=0.5 keep about half the tokens
    lg2 = jnp.zeros((8,)).at[0].add(1e-3)
    kept = np.isfinite(np.asarray(_filter_logits(lg2, 0, 0.5))).sum()
    assert 1 <= kept <= 5


def test_request_sampling_validation():
    with pytest.raises(ValueError):
        Request("r", [1], top_p=0.0)
    with pytest.raises(ValueError):
        Request("r", [1], top_p=1.5)
    with pytest.raises(ValueError):
        Request("r", [1], top_k=-1)


@pytest.mark.slow
def test_per_request_sampling_deterministic_and_batch_invariant(setup):
    """Same seed => same sampled streams, across engine rebuilds AND
    across batching (solo vs shared engine), with per-request
    temperature/top-k/top-p overriding the greedy engine default."""
    cfg, params = setup
    mk = lambda seed: Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=8, token_budget=32, max_seq_len=64,
        seed=seed))
    reqs = lambda: [
        Request("a", _prompt(cfg, 9, 1), max_new_tokens=6,
                temperature=0.8, top_k=7),
        Request("b", _prompt(cfg, 12, 2), max_new_tokens=6,
                temperature=1.2, top_p=0.9),
    ]
    out1 = mk(0).generate(reqs())
    out2 = mk(0).generate(reqs())
    assert out1 == out2, "same seed must reproduce sampled streams"
    solo_a = mk(0).generate([reqs()[0]])["a"]
    assert solo_a == out1["a"], "sampling must not depend on batching"
    out3 = mk(123).generate(reqs())
    assert out3 != out1, "different seed should move sampled streams"


# ---------------------------------------------------------------------------
# StatePool snapshot/restore bit-exactness
# ---------------------------------------------------------------------------

def _random_seq_cache(pool, seed):
    """A batch=1 cache with every leaf randomized (counters included)."""
    base = pool.new_sequence_cache()
    leaves, treedef = jax.tree_util.tree_flatten(base)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jax.random.randint(k, leaf.shape, 0, 97,
                                          dtype=leaf.dtype))
        else:
            out.append(jax.random.normal(k, leaf.shape, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def _assert_tree_bitexact(a, b, msg=""):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (path, x), (_, y) in zip(fa, fb):
        px = "/".join(str(p) for p in path)
        assert x.dtype == y.dtype, f"{msg}{px}: dtype"
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg}{px}")


@pytest.mark.parametrize("cache_kind", ["taylor", "kv"])
def test_snapshot_restore_roundtrip_bitexact(setup, cache_kind):
    """snapshot -> mutate -> restore is the identity, bit for bit, for
    Taylor state / kv rows / pos counters — including across release
    and slot reuse by another sequence."""
    cfg, _ = setup
    pool = StatePool(cfg, 3, cache_len=32, cache_kind=cache_kind)
    for slot in range(3):
        pool.scatter(_random_seq_cache(pool, 10 + slot), slot)
    snap = pool.snapshot(1)
    before = pool.gather(1)

    pool.scatter(_random_seq_cache(pool, 99), 1)     # overwrite
    pool.release(1)                                  # zero + free
    s = pool.alloc()                                 # reuse the slot
    assert s == 1
    pool.scatter(_random_seq_cache(pool, 123), 1)    # new occupant

    pool.restore(1, snap)
    _assert_tree_bitexact(pool.gather(1), before, "restored ")
    # neighbours untouched by the whole dance
    for slot in (0, 2):
        _assert_tree_bitexact(pool.gather(slot),
                              pool.snapshot(slot), f"slot{slot} ")


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_snapshot_restore_property(data):
    """Hypothesis: any interleaving of scatter/release/restore on any
    slot leaves a restored slot bit-identical to its snapshot."""
    cfg = get_config("stablelm-1.6b").reduced()
    n_slots = data.draw(st.integers(min_value=1, max_value=3), label="slots")
    kind = data.draw(st.sampled_from(["taylor", "kv"]), label="kind")
    pool = StatePool(cfg, n_slots, cache_len=16, cache_kind=kind)
    for slot in range(n_slots):
        pool.scatter(_random_seq_cache(pool, data.draw(
            st.integers(0, 2**16), label=f"fill{slot}")), slot)
    target = data.draw(st.integers(0, n_slots - 1), label="target")
    snap = pool.snapshot(target)
    want = pool.gather(target)
    for i in range(data.draw(st.integers(1, 4), label="n_mutations")):
        slot = data.draw(st.integers(0, n_slots - 1), label=f"mut{i}")
        if data.draw(st.booleans(), label=f"kindmut{i}"):
            pool.scatter(_random_seq_cache(pool, data.draw(
                st.integers(0, 2**16), label=f"seed{i}")), slot)
        else:
            pool.reset(slot)
    pool.restore(target, snap)
    _assert_tree_bitexact(pool.gather(target), want)


# ---------------------------------------------------------------------------
# Greedy speculative decoding == non-speculative engine, bit for bit
# ---------------------------------------------------------------------------

def _spec_engine(cfg, params, *, k, drafter, cache_kind="taylor",
                 n_slots=3, adaptive=True):
    return Engine(cfg, params, EngineConfig(
        n_slots=n_slots, prefill_chunk=8, token_budget=64,
        max_seq_len=64, cache_kind=cache_kind, speculate_k=k,
        spec=SpecConfig(drafter=drafter, draft_layers=1,
                        adaptive=adaptive)))


def test_speculative_parity_quick(setup):
    """Tier-1 smoke: one k, both drafters, random + repetitive prompts
    (the repetitive one actually exercises accepted drafts)."""
    cfg, params = setup
    reqs = lambda: [
        Request("r", _prompt(cfg, 13, 7), max_new_tokens=6),
        Request("s", ([5, 9, 2, 7] * 5)[:18], max_new_tokens=6),
    ]
    ref = _spec_engine(cfg, params, k=0, drafter="ngram").generate(reqs())
    for drafter in ("ngram", "self"):
        eng = _spec_engine(cfg, params, k=2, drafter=drafter)
        assert eng.generate(reqs()) == ref, drafter
        assert sum(m.rollbacks for m in eng.stats.steps) > 0, \
            "parity must be exercised through real rollbacks"


@pytest.mark.slow
@pytest.mark.parametrize("speculate_k", [1, 2, 4, 8])
def test_speculative_parity_mixed_arrivals(setup, speculate_k):
    """Acceptance criterion: greedy speculative decoding on the
    mixed-arrival serving workload is bit-identical to the
    non-speculative engine for every speculate_k."""
    cfg, params = setup
    mk = lambda k, drafter: Engine(cfg, params, EngineConfig(
        n_slots=3, prefill_chunk=8, token_budget=48, max_seq_len=64,
        speculate_k=k, spec=SpecConfig(drafter=drafter, draft_layers=1)))

    reqs, arrivals = mixed_arrival_workload(cfg, 4, 24, 8)
    base = run_workload(mk(0, "ngram"), reqs, arrivals)
    want = {rid: s.out_tokens for rid, s in base.items()}
    for drafter in ("ngram", "self"):
        reqs2, arrivals2 = mixed_arrival_workload(cfg, 4, 24, 8)
        got = run_workload(mk(speculate_k, drafter), reqs2, arrivals2)
        assert {rid: s.out_tokens for rid, s in got.items()} == want, drafter


@pytest.mark.slow
def test_speculative_parity_kv_cache(setup):
    """The verify/rollback path over a classic KV pool (per-slot masked
    direct attend + pos counters) matches the non-speculative engine."""
    cfg, params = setup
    reqs = lambda: [Request("r", _prompt(cfg, 17, 31), max_new_tokens=8),
                    Request("s", ([3, 1, 4] * 8)[:15], max_new_tokens=8)]
    ref = _spec_engine(cfg, params, k=0, drafter="ngram",
                       cache_kind="kv").generate(reqs())
    for k in (2, 4):
        eng = _spec_engine(cfg, params, k=k, drafter="ngram",
                           cache_kind="kv")
        assert eng.generate(reqs()) == ref, k


@pytest.mark.slow
def test_speculative_sampling_deterministic(setup):
    """Sampled requests under speculation: drafts always roll back and
    the stream is drawn from the verify logits — reproducible per seed
    (spec-vs-nonspec float paths differ, so only spec-vs-spec equality
    is pinned)."""
    cfg, params = setup
    mk = lambda: Engine(cfg, params, EngineConfig(
        n_slots=2, prefill_chunk=8, token_budget=48, max_seq_len=64,
        speculate_k=2, spec=SpecConfig(drafter="ngram")))
    reqs = lambda: [Request("a", _prompt(cfg, 11, 5), max_new_tokens=6,
                            temperature=0.9, top_p=0.9),
                    Request("b", _prompt(cfg, 9, 6), max_new_tokens=6)]
    out1, out2 = mk().generate(reqs()), mk().generate(reqs())
    assert out1 == out2
    # the greedy request in the pair must still match the non-spec engine
    base = _spec_engine(cfg, params, k=0, drafter="ngram",
                        n_slots=2).generate(reqs())
    assert out1["b"] == base["b"]


@pytest.mark.slow
def test_drafter_slot_reuse_is_clean(setup):
    """A drafter (shadow pool) slot must carry nothing into its next
    occupant: running a long request then a short one through a 1-slot
    speculative engine matches a fresh engine exactly."""
    cfg, params = setup
    mk = lambda: _spec_engine(cfg, params, k=2, drafter="self", n_slots=1)
    eng = mk()
    eng.generate([Request("a", _prompt(cfg, 21, 40), max_new_tokens=5)])
    reused = eng.generate([Request("b", _prompt(cfg, 9, 41),
                                   max_new_tokens=5)])["b"]
    fresh = mk().generate([Request("b", _prompt(cfg, 9, 41),
                                   max_new_tokens=5)])["b"]
    assert reused == fresh
