"""Elastic rescale: checkpoint on one mesh topology, resume on another.

Runs when multiple host devices are available, e.g.:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 pytest tests/test_elastic.py
(Single-device CI sees a graceful skip; the dry-run environment exercises it.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.distributed import sharding as S
from repro.distributed.ft import elastic_remesh
from repro.launch.steps import param_shapes


@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs >= 4 devices")
def test_checkpoint_restores_across_topologies(tmp_path):
    cfg = get_config("taylorshift-lra").with_(
        d_model=64, n_layers=2, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, remat=False, dtype="float32")
    from repro.models import model as M

    # "before failure": 2x2 mesh
    mesh_a = jax.make_mesh((2, 2), ("data", "model"),
                           devices=jax.devices()[:4])
    shapes = param_shapes(cfg)
    sh_a = S.param_shardings(shapes, mesh_a)
    params = jax.device_put(M.init_params(cfg, jax.random.PRNGKey(0)), sh_a)

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, params, blocking=True)

    # "after losing half the hosts": 2x1 mesh via elastic_remesh
    mesh_b = elastic_remesh(n_devices=2, model_parallel=1)
    assert mesh_b.size == 2
    sh_b = S.param_shardings(shapes, mesh_b)
    step, restored = mgr.restore(shapes, shardings=sh_b)
    assert step == 7

    # same numbers, new placement
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored arrays actually live on the new mesh
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.size == 2

    # and the model still runs under the new mesh
    with mesh_b:
        tokens = jnp.zeros((2, 16), jnp.int32)
        hidden, _ = M.forward(restored, cfg, {"tokens": tokens})
        assert bool(jnp.all(jnp.isfinite(hidden)))
