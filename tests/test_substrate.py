"""Substrate tests: optimizer, checkpoint, data pipeline, fault tolerance."""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataLoader, listops_like, lm_synthetic
from repro.distributed.ft import (PreemptionHandler, StragglerDetector,
                                  elastic_remesh, run_with_restarts)
from repro.optim import (OptConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule, lamb_init,
                         lamb_update)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def quad_params():
    return {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.zeros((2, 2))}


def quad_loss(p):
    return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "lamb"])
    def test_converges_on_quadratic(self, name):
        cfg = OptConfig(name=name, lr=0.1, warmup_steps=0, total_steps=200,
                        weight_decay=0.0)
        params = quad_params()
        state = (adamw_init if name == "adamw" else lamb_init)(cfg, params)
        update = adamw_update if name == "adamw" else lamb_update
        for _ in range(150):
            grads = jax.grad(quad_loss)(params)
            params, state, _ = update(cfg, params, grads, state)
        assert float(quad_loss(params)) < 0.05

    def test_grad_clip(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) > 100
        n2 = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(clipped)))
        np.testing.assert_allclose(float(n2), 1.0, rtol=1e-4)

    def test_cosine_schedule(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(cosine_schedule(cfg, jnp.asarray(0))) == 0.0
        np.testing.assert_allclose(
            float(cosine_schedule(cfg, jnp.asarray(10))), 1.0, rtol=1e-5)
        assert float(cosine_schedule(cfg, jnp.asarray(100))) < 1e-3

    def test_bf16_moments_and_stochastic_rounding(self):
        cfg = OptConfig(moment_dtype="bfloat16", master=False,
                        stochastic_round=True, lr=0.05, warmup_steps=0,
                        weight_decay=0.0)
        params = {"w": jnp.ones((64, 64), jnp.bfloat16)}
        state = adamw_init(cfg, params)
        assert state["mu"]["w"].dtype == jnp.bfloat16
        assert "master" not in state
        for i in range(20):
            grads = {"w": jnp.full((64, 64), 0.5, jnp.bfloat16)}
            params, state, _ = adamw_update(cfg, params, grads, state,
                                            rng=jax.random.PRNGKey(i))
        assert params["w"].dtype == jnp.bfloat16
        assert float(jnp.mean(params["w"].astype(jnp.float32))) < 1.0

    def test_stochastic_rounding_unbiased(self):
        """Mean of many SR casts approximates the fp32 value better than
        round-to-nearest can for sub-ulp increments."""
        from repro.optim.optimizers import _stochastic_round_bf16
        x = jnp.full((20000,), 1.0 + 1e-3, jnp.float32)  # between bf16 ulps
        r = _stochastic_round_bf16(x, jax.random.PRNGKey(0))
        mean = float(jnp.mean(r.astype(jnp.float32)))
        assert abs(mean - (1.0 + 1e-3)) < 5e-4
        deterministic = float(jnp.mean(x.astype(jnp.bfloat16)
                                       .astype(jnp.float32)))
        assert abs(mean - 1.001) < abs(deterministic - 1.001) + 1e-4

    def test_zero1_master_fp32(self):
        cfg = OptConfig()
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        state = adamw_init(cfg, params)
        assert state["master"]["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3),
                                                           jnp.bfloat16)}}
        mgr.save(5, tree, blocking=True)
        step, out = mgr.restore(tree)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.arange(10.0))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_atomicity_tmp_never_visible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.zeros(4)}, blocking=True)
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in range(5):
            mgr.save(s, {"x": jnp.zeros(2)}, blocking=True)
        steps = sorted(d for d in os.listdir(tmp_path))
        assert len(steps) == 2
        assert mgr.latest_step() == 4

    def test_restore_across_shardings(self, tmp_path):
        """Checkpoint taken under one mesh restores under another —
        the elastic-rescale path."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        mgr.save(1, tree, blocking=True)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = {"w": NamedSharding(mesh, P(None, None))}
        _, out = mgr.restore(tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.arange(16.0).reshape(4, 4))

    def test_async_save_overlaps(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.zeros((256, 256))})
        # returns before the write necessarily finished; wait() must block
        mgr.wait()
        assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_determinism_and_step_addressing(self):
        cfg = DataConfig(vocab=100, global_batch=4, seq_len=16, seed=7)
        b1 = lm_synthetic(cfg, 3)
        b2 = lm_synthetic(cfg, 3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = lm_synthetic(cfg, 4)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_host_sharding_disjoint(self):
        full = DataConfig(vocab=50, global_batch=8, seq_len=8, n_hosts=1)
        h0 = DataConfig(vocab=50, global_batch=8, seq_len=8, n_hosts=2,
                        host_id=0)
        h1 = DataConfig(vocab=50, global_batch=8, seq_len=8, n_hosts=2,
                        host_id=1)
        assert lm_synthetic(h0, 0)["tokens"].shape[0] == 4
        assert not np.array_equal(lm_synthetic(h0, 0)["tokens"],
                                  lm_synthetic(h1, 0)["tokens"])

    def test_loader_prefetch_order(self):
        cfg = DataConfig(vocab=10, global_batch=2, seq_len=4)
        loader = DataLoader(cfg, start_step=5)
        try:
            steps = [next(loader)[0] for _ in range(3)]
            assert steps == [5, 6, 7]
        finally:
            loader.close()

    def test_listops_labels_valid(self):
        cfg = DataConfig(vocab=16, global_batch=16, seq_len=64,
                         kind="listops")
        b = listops_like(cfg, 0)
        assert b["label"].min() >= 0 and b["label"].max() <= 9
        assert b["tokens"].max() <= 15

    @settings(max_examples=10, deadline=None)
    @given(step=st.integers(0, 1000), seed=st.integers(0, 100))
    def test_generator_purity(self, step, seed):
        cfg = DataConfig(vocab=64, global_batch=2, seq_len=8, seed=seed)
        np.testing.assert_array_equal(lm_synthetic(cfg, step)["tokens"],
                                      lm_synthetic(cfg, step)["tokens"])


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

class TestFaultTolerance:
    def test_straggler_detector(self):
        det = StragglerDetector(threshold=2.0)
        for _ in range(10):
            det.observe(1.0)
        assert det.stragglers == 0
        assert det.observe(5.0)
        assert det.stragglers == 1

    def test_run_with_restarts_recovers(self):
        calls = {"n": 0}

        def run_fn(_):
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("simulated worker failure")
            return "done"

        assert run_with_restarts(lambda: None, run_fn) == "done"
        assert calls["n"] == 3

    def test_run_with_restarts_gives_up(self):
        def run_fn(_):
            raise RuntimeError("poison pill")

        with pytest.raises(RuntimeError):
            run_with_restarts(lambda: None, run_fn, max_failures=2)

    def test_elastic_remesh(self):
        mesh = elastic_remesh(model_parallel=1)
        assert mesh.axis_names == ("data", "model")
        assert mesh.size >= 1

    def test_preemption_handler_flags(self):
        h = PreemptionHandler(signals=())
        with h:
            assert not h.preempted
            h._handle(15, None)
            assert h.preempted


# ---------------------------------------------------------------------------
# End-to-end: tiny train run with checkpoint/restart (the full FT loop)
# ---------------------------------------------------------------------------

class TestTrainLoop:
    @pytest.mark.slow
    def test_loss_decreases_and_restart_resumes(self, tmp_path):
        from repro.configs import get_config
        from repro.launch.train import train

        cfg = get_config("taylorshift-lra").with_(
            d_model=32, n_layers=1, n_heads=2, n_kv_heads=2, d_ff=64,
            vocab=64, max_seq_len=33, remat=False, dtype="float32")
        out = train(cfg, steps=30, global_batch=4, seq_len=32,
                    ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100)
        assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])

        # restart: resumes from the latest checkpoint, not step 0
        out2 = train(cfg, steps=35, global_batch=4, seq_len=32,
                     ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100)
        assert len(out2["losses"]) <= 15  # resumed at >= step 21


class TestGradAccumulation:
    @pytest.mark.slow
    def test_microbatched_grads_match_full_batch(self):
        """M-way gradient accumulation == single big batch (same math)."""
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.steps import build_train_step
        from repro.optim import OptConfig, make_optimizer

        cfg = get_config("taylorshift-lra").with_(
            d_model=32, n_layers=1, n_heads=2, n_kv_heads=2, d_ff=64,
            vocab=64, max_seq_len=17, remat=False, dtype="float32")
        opt_cfg = OptConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0)
        init_opt, _ = make_optimizer(opt_cfg)
        from repro.models import model as M
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16),
                                         0, 64),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16),
                                         0, 64),
        }
        outs = {}
        for m in (1, 4):
            step = build_train_step(cfg, opt_cfg, microbatches=m)
            p2, _, metrics = step(params, init_opt(params), batch)
            outs[m] = (metrics["loss"], p2)
        import numpy as np
        np.testing.assert_allclose(float(outs[1][0]), float(outs[4][0]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(outs[1][1]),
                        jax.tree.leaves(outs[4][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
