"""serve/wire.py — the ``repro.state/v1`` integrity contract.

Two halves, both pinned with hypothesis where it pays:

  * **Identity**: decode(encode(tree)) returns every leaf bit-for-bit
    and dtype-for-dtype, for arbitrary nested dict/list/tuple/
    TaylorState structures over arbitrary dtypes (bfloat16 included).
  * **Refusal**: foreign schema versions, truncations, and single-byte
    mutations anywhere in a blob always raise WireError — a blob either
    restores completely or not at all (never half-restored).
"""

import json
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.taylor import TaylorState
from repro.models import model as M
from repro.serve import wire
from repro.serve.pool import StatePool
from tests._hypothesis_compat import given, settings, st


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _assert_leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(la, lb):
        if hasattr(x, "dtype"):
            assert np.asarray(x).dtype == np.asarray(y).dtype
            assert np.asarray(x).tobytes() == np.asarray(y).tobytes()
        else:
            assert x == y and type(x) is type(y)


def _rebuild(blob: bytes, **header_updates) -> bytes:
    """Re-pack a valid blob with a modified header and a *correct* crc —
    isolates the schema/kind checks from the crc check."""
    body = blob[len(wire._MAGIC):-4]
    hlen = int.from_bytes(body[:4], "little")
    header = json.loads(body[4:4 + hlen].decode())
    header.update(header_updates)
    hdr = json.dumps(header, sort_keys=True).encode()
    nbody = len(hdr).to_bytes(4, "little") + hdr + body[4 + hlen:]
    return wire._MAGIC + nbody + zlib.crc32(nbody).to_bytes(4, "little")


# ---------------------------------------------------------------------------
# Round-trip identity
# ---------------------------------------------------------------------------

def test_roundtrip_mixed_tree():
    tree = {
        "groups": [TaylorState(jnp.ones((2, 3), jnp.bfloat16),
                               jnp.arange(3, dtype=jnp.float32),
                               jnp.ones((), jnp.float32),
                               jnp.array(7, jnp.int32))],
        "rem": [np.arange(6, dtype=np.int32).reshape(2, 3)],
        "pos": jnp.array([1, 2], jnp.int32),
        "scalars": (1, 2.5, None, True, "x"),
        "empty": np.zeros((0, 4), np.float16),
        "zero_d": np.full((), 3.25, np.float32),
    }
    kind, meta, out = wire.decode(wire.encode("snapshot", tree, {"m": 1}))
    assert kind == "snapshot" and meta == {"m": 1}
    assert isinstance(out["groups"][0], TaylorState)
    assert out["scalars"] == (1, 2.5, None, True, "x")
    _assert_leaves_equal(tree, out)


@pytest.mark.parametrize("dtype", ["float32", "float16", "bfloat16",
                                   "int32", "uint8", "bool"])
def test_roundtrip_dtypes(dtype):
    dt = jnp.dtype(dtype) if dtype != "bfloat16" else jnp.bfloat16
    a = jnp.arange(12).reshape(3, 4).astype(dt)
    _, _, out = wire.decode(wire.encode("snapshot", a))
    assert np.asarray(out).dtype == np.asarray(a).dtype
    assert np.asarray(out).tobytes() == np.asarray(a).tobytes()


def test_roundtrip_wide_dtypes_stay_exact():
    """int64/float64 leaves survive bit-exactly even with jax x64 off
    (decode falls back to numpy instead of letting jnp narrow them)."""
    tree = {"i": np.arange(4, dtype=np.int64) * 2**40,
            "f": np.array([1e300, -2.5], np.float64)}
    _, _, out = wire.decode(wire.encode("snapshot", tree))
    _assert_leaves_equal(tree, out)


def test_roundtrip_real_slot_state(setup):
    """A real StatePool slot snapshot (the migration payload) ships and
    returns bit-exactly, both cache kinds."""
    cfg, params = setup
    for kind in ("taylor", "kv"):
        pool = StatePool(cfg, 2, cache_len=24, cache_kind=kind)
        toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                                  cfg.vocab)
        _, cache = M.prefill_from_state(params, cfg, {"tokens": toks},
                                        pool.new_sequence_cache())
        slot = pool.alloc()
        pool.scatter(cache, slot)
        snap = pool.snapshot(slot)
        _, _, out = wire.decode(wire.encode("snapshot", snap))
        _assert_leaves_equal(snap, out)


def test_stream_and_trie_conveniences(setup):
    cfg, params = setup
    pool = StatePool(cfg, 1, cache_len=24, cache_kind="taylor")
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0, cfg.vocab)
    logits, cache = M.prefill_from_state(params, cfg, {"tokens": toks},
                                         pool.new_sequence_cache())
    blob = wire.encode_stream(cache, request={"request_id": "r"},
                              out_tokens=[5, 6], cache_kind="taylor",
                              cache_len=24)
    meta, state = wire.decode_stream(blob)
    assert meta["out_tokens"] == [5, 6] and meta["cache_kind"] == "taylor"
    _assert_leaves_equal(cache, state)

    path = [int(t) for t in toks[0]]
    tblob = wire.encode_trie_entry(path, 8, cache, logits[:, -1:])
    toks2, n, state2, lg2 = wire.decode_trie_entry(tblob)
    assert toks2 == path and n == 8
    _assert_leaves_equal(cache, state2)
    _assert_leaves_equal(logits[:, -1:], lg2)

    with pytest.raises(wire.WireError):
        wire.decode_stream(tblob)       # kind pinning
    with pytest.raises(wire.WireError):
        wire.decode_trie_entry(blob)


def test_unserializable_node_refused():
    with pytest.raises(wire.WireError):
        wire.encode("snapshot", {"bad": object()})
    with pytest.raises(wire.WireError):
        wire.encode("snapshot", {1: "non-str key"})


# ---------------------------------------------------------------------------
# Refusal: foreign versions, truncation, corruption
# ---------------------------------------------------------------------------

BLOB = wire.encode("snapshot",
                   {"s": TaylorState(jnp.ones((2, 2)), jnp.zeros((2,)),
                                     jnp.ones(()), jnp.array(3, jnp.int32)),
                    "pos": jnp.array([4], jnp.int32)},
                   {"tag": "refusal-fixture"})


def test_foreign_version_refused_with_clear_error():
    alien = _rebuild(BLOB, schema="repro.state/v2")
    with pytest.raises(wire.WireError, match="repro.state/v1"):
        wire.decode(alien)
    ancient = _rebuild(BLOB, schema="somebody.else/v9")
    with pytest.raises(wire.WireError, match="foreign"):
        wire.decode(ancient)


def test_kind_mismatch_refused():
    with pytest.raises(wire.WireError, match="kind"):
        wire.decode(BLOB, expect_kind="stream")


def test_every_truncation_refused():
    for cut in range(len(BLOB)):
        with pytest.raises(wire.WireError):
            wire.decode(BLOB[:cut])


def test_every_single_byte_mutation_refused():
    """Exhaustive, not sampled: flip each byte of the blob in turn —
    magic, length, header, payload, crc — and every variant must be
    refused. There is no mutable region the checks miss."""
    for i in range(len(BLOB)):
        bad = bytearray(BLOB)
        bad[i] ^= 0xFF
        with pytest.raises(wire.WireError):
            wire.decode(bytes(bad))


def test_not_bytes_refused():
    with pytest.raises(wire.WireError):
        wire.decode("not bytes")


# ---------------------------------------------------------------------------
# Hypothesis properties
# ---------------------------------------------------------------------------

_DTYPES = ("float32", "float16", "int32", "int8", "uint8", "bool")


def _array_from(dtype, shape, fill):
    n = int(np.prod(shape, dtype=np.int64))
    flat = np.asarray([fill[i % len(fill)] for i in range(n)], np.int64)
    return flat.astype(np.dtype(dtype)).reshape(shape)


_leaf = st.one_of(
    st.integers(min_value=-2**31, max_value=2**31 - 1),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
    st.booleans(), st.none(), st.text(max_size=8),
    st.builds(_array_from,
              st.sampled_from(_DTYPES),
              st.lists(st.integers(min_value=0, max_value=3), min_size=0,
                       max_size=3).map(tuple),
              st.lists(st.integers(min_value=-100, max_value=100),
                       min_size=1, max_size=8)),
)

_tree = st.recursive(
    _leaf,
    lambda kids: st.one_of(
        st.dictionaries(st.text(max_size=6), kids, max_size=3),
        st.lists(kids, max_size=3),
        st.lists(kids, max_size=3).map(tuple),
        st.builds(lambda a, b: TaylorState(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            np.full((), 1.0, np.float32),
            np.array(2, np.int32)),
            st.lists(st.floats(allow_nan=False, allow_infinity=False,
                               width=32), min_size=1, max_size=4),
            st.lists(st.floats(allow_nan=False, allow_infinity=False,
                               width=32), min_size=1, max_size=4)),
    ),
    max_leaves=8)


@given(tree=_tree)
@settings(max_examples=40, deadline=None)
def test_prop_roundtrip_identity(tree):
    _, _, out = wire.decode(wire.encode("snapshot", tree))
    _assert_leaves_equal(tree, out)


@given(idx=st.integers(min_value=0), flip=st.integers(min_value=1,
                                                      max_value=255))
@settings(max_examples=60, deadline=None)
def test_prop_any_mutation_refused(idx, flip):
    bad = bytearray(BLOB)
    bad[idx % len(bad)] ^= flip
    with pytest.raises(wire.WireError):
        wire.decode(bytes(bad))


@given(cut=st.integers(min_value=0))
@settings(max_examples=40, deadline=None)
def test_prop_any_truncation_refused(cut):
    with pytest.raises(wire.WireError):
        wire.decode(BLOB[:cut % len(BLOB)])


@given(ver=st.text(min_size=1, max_size=20))
@settings(max_examples=40, deadline=None)
def test_prop_foreign_versions_refused(ver):
    if ver == wire.SCHEMA:
        return
    with pytest.raises(wire.WireError):
        wire.decode(_rebuild(BLOB, schema=ver))
